"""Accuracy under unavailability, across coding schemes (paper §4's A_a /
A_d methodology applied to the scheme registry).

One shared pipeline — train a deployed model on the resnet18_cifar task
family, then for each scheme train its parity/backup models through
``train_parity_models`` and measure

* ``A_a`` — available accuracy (deployed model, no unavailability), and
* ``A_d`` — degraded accuracy: with ONE unavailable query per coding group,
  the accuracy of the scheme's *reconstructed* predictions only.

Every scheme flows through the same registry entry points the serving
layers use, so this is also an end-to-end exercise of the plugin API:

* ``sum`` / ``concat``  — the paper's codes, parity model distilled per §3.3;
* ``learned``           — joint encoder+parity training
                          (``repro.core.parity._train_joint``);
* ``approx_backup``     — k=1 groups; "parity training" degenerates to
                          distilling a *cheaper* backup architecture
                          (``backup_model``), and A_d is the backup's
                          accuracy — the §5.2.6 baseline as a scheme;
* ``approxifer``        — the rational-interpolation code: NO parity
                          training at all (``model_agnostic`` — the
                          deployed model serves the encoded queries), A_d
                          is pure interpolation quality;
* ``fisher``            — training-free Fisher-merged parity models
                          (``provision_parity`` merges the deployed
                          checkpoints leaf-wise; with one deployed
                          checkpoint the merged parity model IS the
                          deployed model on convex parity queries);
* ``invnet``            — the invertible-coupling code: the deployed model
                          serves g^-1-space parity queries, decode is the
                          linear output code (exact when the model factors
                          through g).

``accuracy_under_errors`` extends the methodology to the Byzantine fault
class: all responses arrive, but a fraction of the member responses is
*erroneous* (garbage at ``CORRUPTION_SCALE``).  A ``detects_errors``
scheme (approxifer) votes the corrupted responses out using its surplus
parity responses and re-decodes them; schemes without detection serve the
garbage — sweeping the error rate across sum / learned / approxifer shows
the robustness gap the straggler-only A_a/A_d metrics cannot.

Used by ``benchmarks/accuracy.py`` (``bench_unavailability_schemes``) and
locked by ``tests/test_learned_scheme.py`` (learned >= sum on
resnet18_cifar, the ROADMAP acceptance bar for learned codes) and
``tests/test_approxifer_eval.py`` (approxifer A_d within 5 points of sum,
and error-sweep robustness).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.resnet18_cifar import IMAGE_SHAPE
from repro.core.metrics import degraded_accuracy, topk_accuracy
from repro.core.parity import fused_parity_outputs, train_parity_models
from repro.core.scheme import scheme_capabilities
from repro.data.pipeline import batched, cluster_images
from repro.models.cnn import build
from repro.training.loss import softmax_xent
from repro.training.optim import AdamConfig, adam_init, adam_update

DEFAULT_SCHEMES = ("sum", "concat", "learned", "approx_backup",
                   "approxifer", "fisher", "invnet")


def _train_deployed(x, y, model, image_shape, n_classes, epochs, seed):
    params, fwd = build(model, jax.random.PRNGKey(seed),
                        image_shape=image_shape, n_out=n_classes)
    opt = AdamConfig(lr=1e-3)
    st = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        l, g = jax.value_and_grad(lambda p: softmax_xent(fwd(p, xb), yb))(p)
        p, s = adam_update(g, s, p, opt)
        return p, s, l

    for xb, yb in batched(x, y, 64, seed=seed, epochs=epochs):
        params, st, _ = step(params, st, xb, yb)
    return params, fwd


def _degraded(scheme, parity_params, parity_fwd, deployed_params, fwd,
              xt, yt, n_classes):
    """A_d with one unavailable member per group, every position simulated
    (the paper's evaluation loop), via the scheme's own encode/decode."""
    gk = scheme.k
    n = (len(xt) // gk) * gk
    groups = xt[:n].reshape(-1, gk, *xt.shape[1:])              # [G, gk, ...]
    glabels = yt[:n].reshape(-1, gk)
    member = np.asarray(fwd(deployed_params, jnp.asarray(
        groups.reshape(n, *xt.shape[1:])))).reshape(-1, gk, n_classes)
    # the fused coded hot path: encode + first parity matmul in one launch
    # for linear/MLP substrates, the exact encode + per-row forward fallback
    # for everything else (DESIGN.md §12)
    pouts = np.asarray(fused_parity_outputs(
        scheme, jnp.asarray(np.moveaxis(groups, 1, 0)), parity_params,
        parity_fwd))                                            # [r, G, V]
    parity_outs = np.moveaxis(pouts, 0, 1)                      # [G, r, V]
    return degraded_accuracy(parity_outs, member, glabels, scheme)


def accuracy_under_unavailability(schemes=DEFAULT_SCHEMES, *, model="resnet",
                                  backup_model="mlp",
                                  image_shape=IMAGE_SHAPE, n_classes=10,
                                  k=2, n_train=1500, n_test=600, noise=2.0,
                                  deployed_epochs=3, parity_epochs=5,
                                  seed=0):
    """Returns ``{"A_a": float, "schemes": {name: A_d}}`` on the
    resnet18_cifar task family (CIFAR-shaped Gaussian-cluster images — no
    datasets ship with the container)."""
    x, y, tmpl = cluster_images(n_train, noise=noise, seed=seed,
                                image_shape=image_shape, n_classes=n_classes)
    xt, yt, _ = cluster_images(n_test, noise=noise, seed=seed + 1,
                               templates=tmpl, image_shape=image_shape,
                               n_classes=n_classes)
    params, fwd = _train_deployed(x, y, model, image_shape, n_classes,
                                  deployed_epochs, seed)
    a_a = topk_accuracy(np.asarray(fwd(params, jnp.asarray(xt))), yt)

    results = {}
    for name in schemes:
        if name == "approx_backup":
            # the backup is a cheaper architecture; the k=1 "parity
            # training" is plain distillation of the deployed model into it
            init_fn = lambda kk: build(backup_model, kk,
                                       image_shape=image_shape,
                                       n_out=n_classes)[0]
            pfwd = build(backup_model, jax.random.PRNGKey(0),
                         image_shape=image_shape, n_out=n_classes)[1]
        else:
            # parity models share the deployed architecture (§3.3)
            init_fn = lambda kk: build(model, kk, image_shape=image_shape,
                                       n_out=n_classes)[0]
            pfwd = fwd
        pp, scheme = train_parity_models(
            params, fwd, init_fn, x, k=k, scheme=name,
            epochs=parity_epochs, seed=seed, parity_fwd=pfwd)
        results[name] = _degraded(scheme, pp, pfwd, params, fwd, xt, yt,
                                  n_classes)
    return {"A_a": a_a, "schemes": results}


def _served_under_errors(scheme, member, parity_outs, corrupt):
    """Predictions actually served for one error realization.

    member [G, k, V] true member outputs; parity_outs [G, r, V];
    ``corrupt`` [G, k] marks erroneous member responses (replaced by
    garbage at CORRUPTION_SCALE).  A ``detects_errors`` scheme votes the
    garbage out per group and re-decodes the flagged members from the
    clean remainder; every other scheme serves the garbage as-is."""
    from repro.serving.scenarios import CORRUPTION_SCALE
    g_n, k, v = member.shape
    served = member.copy()
    served[corrupt] = CORRUPTION_SCALE
    if not scheme_capabilities(scheme).detects_errors:
        return served
    r = scheme.r
    ones_m = np.ones(k, bool)
    ones_p = np.ones(r, bool)
    for g in np.nonzero(corrupt.any(axis=1))[0]:
        mflags, pflags = scheme.flag_errors(served[g], ones_m,
                                            parity_outs[g], ones_p)
        if not mflags.any():
            continue                      # below the voting margin: served
        recon = np.asarray(scheme.decode(
            jnp.asarray(parity_outs[g] * ~pflags[:, None]),
            jnp.asarray(served[g]), jnp.asarray(mflags),
            jnp.asarray(~pflags)))
        served[g][mflags] = recon[mflags]
    return served


def accuracy_under_errors(schemes=("sum", "learned", "approxifer", "fisher",
                                   "invnet"), *,
                          error_rates=(0.0, 0.1, 0.25), model="resnet",
                          image_shape=IMAGE_SHAPE, n_classes=10, k=2, r=2,
                          n_train=1500, n_test=600, noise=2.0,
                          deployed_epochs=3, parity_epochs=5, seed=0):
    """Accuracy when member responses are *erroneous* (Byzantine), swept
    over the per-response error rate.  All responses arrive (the straggler
    axis is ``accuracy_under_unavailability``); each member response is
    independently corrupted with probability ``rate``.  ``r`` extra
    responses per group give a ``detects_errors`` scheme the surplus it
    needs to vote garbage out (r >= 2 corrects one error per group).

    Returns ``{"A_a": float, "schemes": {name: {rate: accuracy}}}`` —
    accuracy of the predictions actually served, over all members."""
    x, y, tmpl = cluster_images(n_train, noise=noise, seed=seed,
                                image_shape=image_shape, n_classes=n_classes)
    xt, yt, _ = cluster_images(n_test, noise=noise, seed=seed + 1,
                               templates=tmpl, image_shape=image_shape,
                               n_classes=n_classes)
    params, fwd = _train_deployed(x, y, model, image_shape, n_classes,
                                  deployed_epochs, seed)
    a_a = topk_accuracy(np.asarray(fwd(params, jnp.asarray(xt))), yt)

    results = {}
    for name in schemes:
        init_fn = lambda kk: build(model, kk, image_shape=image_shape,
                                   n_out=n_classes)[0]
        pp, scheme = train_parity_models(
            params, fwd, init_fn, x, k=k, r=r, scheme=name,
            epochs=parity_epochs, seed=seed)
        gk = scheme.k
        n = (len(xt) // gk) * gk
        groups = xt[:n].reshape(-1, gk, *xt.shape[1:])
        glabels = yt[:n].reshape(-1, gk)
        member = np.asarray(fwd(params, jnp.asarray(
            groups.reshape(n, *xt.shape[1:])))).reshape(-1, gk, n_classes)
        pouts = np.asarray(fused_parity_outputs(
            scheme, jnp.asarray(np.moveaxis(groups, 1, 0)), pp, fwd))
        parity_outs = np.moveaxis(pouts, 0, 1)             # [G, r, V]
        per_rate = {}
        for rate in error_rates:
            rng = np.random.default_rng(seed + int(rate * 1000))
            corrupt = rng.random(member.shape[:2]) < rate
            served = _served_under_errors(scheme, member, parity_outs,
                                          corrupt)
            per_rate[rate] = float(
                (np.argmax(served, -1) == glabels).mean())
        results[name] = per_rate
    return {"A_a": a_a, "schemes": results}
