"""Train-step builders for the LM substrate (used by examples, smoke tests
and the train_4k dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.training.loss import lm_loss, parity_mse
from repro.training.optim import AdamConfig, adam_init, adam_update


def make_train_step(cfg, opt_cfg: AdamConfig, remat=True):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``batch`` = {"tokens": [B, S] int32} plus, per family,
    "cross_embeds": [B, n_modality_tokens, D] (vlm) or
    "frames": [B, S_src, D] (audio enc-dec).
    """

    def loss_fn(params, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["cross_embeds"] = batch["cross_embeds"]
        if cfg.enc_dec:
            kw["cross_embeds"] = batch["frames"]
        logits, aux = T.forward(cfg, params, tokens=batch["tokens"],
                                remat=remat, **kw)
        return lm_loss(logits, batch["tokens"], aux, cfg.router_aux_coef)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adam_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss}

    return train_step


def make_parity_train_step(cfg, opt_cfg: AdamConfig, coeffs=None, remat=False):
    """Parity-model training step for LM serving (paper §3.3 adapted to
    embedding-space queries, DESIGN.md §3).

    batch = {"embeds": [k, B, S, D] member-query embeddings,
             "teacher": [k, B, S, V] deployed-model logits}
    The parity model learns F_P(sum_i c_i emb_i) ~= sum_i c_i F(X_i).
    """

    def loss_fn(params, batch):
        k = batch["embeds"].shape[0]
        c = (jnp.ones((k,)) if coeffs is None else jnp.asarray(coeffs))
        parity_q = jnp.einsum("k,kbsd->bsd", c.astype(batch["embeds"].dtype),
                              batch["embeds"])
        target = jnp.einsum("k,kbsv->bsv", c, batch["teacher"])
        out, aux = T.forward(cfg, params, embeds=parity_q, remat=remat)
        return parity_mse(out, target) + cfg.router_aux_coef * aux

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adam_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss}

    return step


def make_joint_parity_train_step(cfg, opt_cfg: AdamConfig, scheme,
                                 remat=False):
    """Joint encoder+parity training step for the LM substrate: the learned
    scheme's encoder (``repro.core.learned.LearnedScheme``) combines member-
    query *embeddings* and is trained together with the r parity LMs against
    the linear output code (DESIGN.md §7) — the embedding-space analogue of
    ``repro.core.parity._train_joint``.

    params = {"enc": scheme.enc_params,
              "parity": [transformer params] * scheme.r}
    batch  = {"embeds": [k, B, S, D], "teacher": [k, B, S, V]}

    After training, serve with ``scheme.with_params(params["enc"])``.
    """
    coeffs = jnp.asarray(scheme.coeffs)                        # [r, k]

    def loss_fn(params, batch):
        enc_q = scheme.encode_with_params(
            params["enc"], batch["embeds"])                    # [r, B, S, D]
        target = jnp.einsum("rk,kbsv->rbsv", coeffs, batch["teacher"])
        total = 0.0
        for j in range(scheme.r):
            out, aux = T.forward(cfg, params["parity"][j], embeds=enc_q[j],
                                 remat=remat)
            total = total + parity_mse(out, target[j]) + \
                cfg.router_aux_coef * aux
        return total / scheme.r

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adam_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss}

    return step


def init_train_state(cfg, key, opt_cfg: AdamConfig):
    params = T.init_params(cfg, key)
    return params, adam_init(params, opt_cfg)
