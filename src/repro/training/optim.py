"""Optimizers from scratch (no optax dependency).

Adam/AdamW with configurable moment dtype: moments shard like the parameters
(see repro.distributed.sharding) and can be stored in bf16 so the >=100B-param
architectures fit 16 GB/chip HBM during the train_4k dry-run — the tradeoff is
recorded in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass
import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0            # AdamW when > 0
    moment_dtype: str = "float32"
    grad_clip: float = 0.0               # global-norm clip; 0 = off


def adam_init(params, cfg: AdamConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adam_update(grads, state, params, cfg: AdamConfig):
    step = state["step"] + 1
    if cfg.grad_clip:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g32 * g32 * (1 - cfg.b2)
        mhat = m32 / (1 - cfg.b1 ** step)
        vhat = v32 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - cfg.lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    # flatten to avoid treating structural tuples in the param tree as leaves
    g_flat, tdef = jax.tree.flatten(grads)
    m_flat = jax.tree.leaves(state["mu"])
    v_flat = jax.tree.leaves(state["nu"])
    p_flat = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(g_flat, m_flat, v_flat, p_flat)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}


def sgd_update(grads, params, lr):
    return jax.tree.map(lambda p, g: p - lr * g, params, grads)
