"""Loss functions: next-token cross-entropy for LM training and the paper's
parity-distillation MSE (§3.3 / §4.1 — MSE keeps ParM task-agnostic)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def softmax_xent(logits, labels, mask=None):
    """logits [..., V] float32; labels [...] int. Mean over valid tokens."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()


def lm_loss(logits, tokens, aux=0.0, aux_coef=0.01):
    """Shifted next-token loss; ``aux`` is the MoE load-balance term."""
    return (softmax_xent(logits[:, :-1], tokens[:, 1:])
            + aux_coef * aux)


def parity_mse(parity_out, target_sum):
    """Paper §4.1: MSE between the parity model's output and the desired
    linear combination of deployed-model outputs."""
    d = (parity_out.astype(jnp.float32) - target_sum.astype(jnp.float32))
    return jnp.mean(d * d)
