"""The paper's own deployed-model family (ResNet-18 on CIFAR-10-like tasks).
Used by the accuracy-reproduction benches; see repro.models.cnn."""
PAPER_MODELS = {
    # name: (kind, hidden sizes / stages, num classes)
    "mlp": ("mlp", (200, 100), 10),          # the paper's 2-hidden-layer MLP
    "lenet5": ("cnn", (6, 16), 10),          # LeNet-5-style
    "resnet18s": ("resnet", (16, 32, 64), 10),  # small ResNet for CIFAR-size inputs
}
IMAGE_SHAPE = (32, 32, 3)
