"""Llama-3.2-11B-Vision — text backbone with cross-attention image layers
every 5th layer; vision encoder is a stub providing patch embeddings.
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    cross_attn_every=5, n_modality_tokens=1600, rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
