"""SeamlessM4T-medium — encoder-decoder multimodal translation backbone;
the speech frontend (mel + conv) is a stub providing frame embeddings.
[arXiv:2308.11596]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206,
    enc_dec=True, n_enc_layers=12, n_modality_tokens=1024,
    act="relu",
    source="arXiv:2308.11596",
)
