"""Architecture + input-shape configuration system.

Every assigned architecture gets one module in ``repro/configs`` exposing a
``CONFIG`` (the exact full-size config from the assignment) and a ``REDUCED``
variant (<=2 superblock-periods of layers, d_model<=512, <=4 experts) used by
the CPU smoke tests. The FULL configs are only ever lowered via
ShapeDtypeStructs (never allocated) by ``repro.launch.dryrun``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    source: str = ""                 # citation from the assignment pool

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1               # a MoE FFN every `moe_every` layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: one attention layer per period

    # --- flavour knobs ---
    qk_norm: bool = False
    qkv_bias: bool = False
    nonparametric_ln: bool = False   # OLMo: LayerNorm without learned params
    rope_theta: float = 10000.0
    act: str = "silu"
    tie_embeddings: bool = False

    # --- structure ---
    enc_dec: bool = False
    n_enc_layers: int = 0
    cross_attn_every: int = 0        # VLM: cross-attn layer each period
    n_modality_tokens: int = 0       # stubbed frontend: patches / audio frames
    sliding_window: int = 0          # 0 = full attention

    # --- execution backend ---
    # "jnp": XLA online-softmax paths (default, runs everywhere);
    # "pallas": route prefill/decode attention through the Pallas TPU
    # kernels (interpret mode off-TPU), falling back to XLA where the
    # kernel lacks a feature (q_offset prefill, non-causal cross-attn).
    attn_backend: str = "jnp"

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def period(self) -> int:
        """Length of the repeating superblock the layer stack scans over."""
        p = 1
        if self.attn_every:
            p = self.attn_every
        if self.cross_attn_every:
            p = max(p, self.cross_attn_every)
        if self.moe_every > 1:
            import math
            p = p * self.moe_every // math.gcd(p, self.moe_every)
        return p

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers, self.period)
        return self.n_layers // self.period

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is native (SSM/hybrid-lite caches)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """<=2 periods of layers, d_model<=512, <=4 experts — CPU smoke size."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads, 2))
        # Shrink interleave periods so a 2-layer model still contains one full
        # superblock of the family (attn+mamba for hybrids, self+cross for VLM).
        attn_every = 2 if self.attn_every else 0
        cross_every = 2 if self.cross_attn_every else 0
        period = 2 if (attn_every or cross_every or self.moe_every > 1) else 1
        kw = dict(
            name=self.name + "-reduced",
            attn_every=attn_every,
            cross_attn_every=cross_every,
            n_layers=2 if period == 1 else period,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            head_dim=64 if self.head_dim else 0,
            n_modality_tokens=min(self.n_modality_tokens, 16),
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_chunk=32,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            dtype="float32",
        )
        if self.n_experts:
            kw.update(
                n_experts=min(self.n_experts, 4),
                moe_top_k=min(self.moe_top_k, 2),
                moe_d_ff=min(self.moe_d_ff, 256),
                n_shared_experts=min(self.n_shared_experts, 1),
            )
        if self.enc_dec:
            kw.update(n_enc_layers=2)
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "deepseek-moe-16b",
    "llama-3.2-vision-11b",
    "seamless-m4t-medium",
    "jamba-1.5-large-398b",
    "smollm-135m",
    "olmo-1b",
    "qwen3-moe-235b-a22b",
    "qwen3-4b",
    "qwen2-0.5b",
    "mamba2-780m",
]


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
