"""Qwen3-MoE-235B-A22B — 128 routed experts, top-8, qk-norm, GQA kv=4.
[hf:Qwen/Qwen3-30B-A3B scaled per assignment]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    n_experts=128, moe_top_k=8, moe_d_ff=1536,
    qk_norm=True, rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-30B-A3B",
)
