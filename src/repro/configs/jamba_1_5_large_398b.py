"""Jamba-1.5-Large — hybrid Mamba+attention (1:7 interleave), MoE 16e top-2
every other layer. [arXiv:2403.19887]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536,
    n_experts=16, moe_top_k=2, moe_d_ff=24576, moe_every=2,
    attn_every=8,                 # 1 attention layer per 8 (1:7 mamba)
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
    source="arXiv:2403.19887",
)
