"""The one typed serving report shared by BOTH serving layers.

``ServingReport`` replaces the two hand-rolled result dicts the threaded
``ParMFrontend.stats()`` and the DES ``simulate()`` used to return.  It is a
frozen dataclass — fields are the contract, and a field added here shows up
in both engines at once — but it also implements the ``Mapping`` protocol, so
every existing ``report["p999_ms"]``-style call site keeps working unchanged.

New in this report (vs the old dicts):

* ``engine``                           — ``"threads"`` or ``"sim"``;
* ``completed_by``                     — per-completion-path counts from the
                                         DES too (the runtime always had them);
* ``cancelled_queries`` / ``cancelled_parities`` — redundant-work
  cancellation: originals tombstoned after a parity decode beat them (and
  mirror copies of already-answered queries), and undispatched parity queries
  dropped because every original in their group already finished;
* ``batches`` / ``mean_batch_size``    — adaptive-batching bookkeeping: how
  many main-pool inference calls ran and how many queries each carried;
* ``corrupted_detected`` / ``corrected`` — Byzantine bookkeeping: erroneous
  responses a ``detects_errors`` scheme (approxifer) voted out, and how
  many of the affected predictions were nonetheless served from a clean
  reconstruction.  Both default to 0, so report consumers and schemes that
  never inject or detect errors are unaffected;
* ``controller`` / ``windows`` / ``adjustments`` / ``parity_served`` —
  closed-loop bookkeeping (``repro.serving.controller``): which controller
  watched the run, how many ``ReportWindow`` snapshots it observed, the
  ``(window, scheme, r, batch_max_size)`` adjustment log it produced, and
  how many parity-pool inference items the run actually served (the
  resource axis of the adaptive-vs-static frontier).

``ReportWindow`` is the *incremental* snapshot the same two engines hand a
``Controller`` every ``window_ms``: per-window p50/p999 plus the straggler /
corruption / cancellation rates, all guarded by ``_safe_rate`` so a window
that closes with zero completed queries reports 0.0 rates instead of raising.
"""

from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field, fields
from typing import Dict, Optional

import numpy as np


def _safe_rate(num, den) -> float:
    """``num / den`` with the empty-window guard both report types share:
    zero completions means "no evidence", reported as a 0.0 rate — never a
    ZeroDivisionError out of a quiet window."""
    return float(num) / float(den) if den else 0.0


@dataclass(frozen=True, eq=True)
class ReportWindow:
    """One closed observation window of a serving run.

    The sliding-window counterpart of ``ServingReport``: both engines close
    one every ``Controller.window_ms`` (simulated ms in the DES, scaled
    wall-clock in the threads engine) and hand it to
    ``Controller.observe``.  ``n`` counts queries *completed* inside
    [``t0_ms``, ``t1_ms``); the rates are relative to it, empty-window safe
    via ``_safe_rate``.
    """

    index: int = 0
    t0_ms: float = 0.0
    t1_ms: float = 0.0
    n: int = 0
    p50_ms: float = float("nan")
    p999_ms: float = float("nan")
    reconstructions: int = 0
    corrupted_detected: int = 0
    cancellations: int = 0

    @property
    def straggler_rate(self) -> float:
        """Fraction of this window's completions served by a parity
        reconstruction — i.e. whose original was unavailable in time."""
        return _safe_rate(self.reconstructions, self.n)

    @property
    def corruption_rate(self) -> float:
        return _safe_rate(self.corrupted_detected, self.n)

    @property
    def cancellation_rate(self) -> float:
        return _safe_rate(self.cancellations, self.n)


def build_window(index, t0_ms, t1_ms, records, *, corrupted_detected=0,
                 cancellations=0) -> ReportWindow:
    """Assemble a ``ReportWindow`` from per-completion records — the one
    construction path both engines share, so their window semantics cannot
    drift.  ``records`` is a sequence of ``(latency_ms, is_reconstruction)``
    pairs for queries completed inside the window; the counter deltas are
    per-window (not cumulative)."""
    n = len(records)
    lats = np.asarray([rec[0] for rec in records], dtype=float)
    return ReportWindow(
        index=int(index), t0_ms=float(t0_ms), t1_ms=float(t1_ms), n=n,
        p50_ms=float(np.percentile(lats, 50)) if n else float("nan"),
        p999_ms=float(np.percentile(lats, 99.9)) if n else float("nan"),
        reconstructions=sum(1 for rec in records if rec[1]),
        corrupted_detected=int(corrupted_detected),
        cancellations=int(cancellations))


@dataclass(frozen=True, eq=True)
class ServingReport(Mapping):
    """Latency percentiles + completion bookkeeping for one serving run.

    Queries flushed at shutdown appear in ``completed_by`` but are excluded
    from the latency percentiles and ``n`` — their finish time is a shutdown
    artifact, not a latency.
    """

    engine: str = "threads"
    strategy: str = ""
    scheme: Optional[str] = None
    scenario: Optional[str] = None
    n: int = 0
    median_ms: float = float("nan")
    p99_ms: float = float("nan")
    p999_ms: float = float("nan")
    mean_ms: float = float("nan")
    max_ms: float = float("nan")
    # hash=False: the dict would break the frozen dataclass's generated
    # __hash__; equality still compares it field-wise
    completed_by: Dict[str, int] = field(default_factory=dict, hash=False)
    reconstructions: int = 0
    cancelled_queries: int = 0
    cancelled_parities: int = 0
    batches: int = 0
    mean_batch_size: float = 1.0
    corrupted_detected: int = 0
    corrected: int = 0
    # closed-loop bookkeeping (repro.serving.controller); all defaulted, so
    # controller-less runs are unaffected
    controller: Optional[str] = None
    windows: int = 0
    adjustments: tuple = ()     # of (window_index, scheme, r, batch_max_size)
    parity_served: int = 0      # parity-pool inference items actually served
    # DES instrumentation: how many discrete events the run processed
    # (arrivals + finishes + control); 0 from the threads engine, which has
    # no event loop.  events / wall-time is the simulator's throughput
    # metric, gated in BENCH_baseline.json.
    events: int = 0
    # multi-tenant breakdown (DESIGN.md §11): tenant name -> {"n", "share",
    # "median_ms", "p999_ms", "slo_ms", "slo_violations"}.  Empty for
    # single-tenant runs; hash=False for the same reason as completed_by.
    per_tenant: Dict[str, dict] = field(default_factory=dict, hash=False)
    # per-token generation metrics (serving/generation.py, DESIGN.md §13):
    # for an LM run a "completion" is ONE decode step of one stream, so
    # median/p999 above ARE inter-token latencies; these fields surface
    # them under their serving-facing names plus the aggregate decode rate.
    # All defaulted — one-shot runs are unaffected.
    tokens_per_s: float = 0.0
    inter_token_p50_ms: float = float("nan")
    inter_token_p999_ms: float = float("nan")
    reconstructed_steps: int = 0

    # -- Mapping protocol: old ``stats()["p999_ms"]`` call sites keep
    # working.  The view is exactly the dataclass fields plus the derived
    # ``cancellations`` total and the three rates — NOT arbitrary
    # attributes, so methods are not "in" the report and ``dict(report)``
    # round-trips every readable key (including the one the examples read
    # as ``stats["cancellations"]``)
    def _key_names(self):
        return [f.name for f in fields(self)] + [
            "cancellations", "straggler_rate", "corruption_rate",
            "cancellation_rate"]

    def __getitem__(self, key):
        if key in self._key_names():
            return getattr(self, key)
        raise KeyError(key)

    def __iter__(self):
        return iter(self._key_names())

    def __len__(self):
        return len(self._key_names())

    @property
    def cancellations(self) -> int:
        """Total redundant work skipped at dequeue, both directions."""
        return self.cancelled_queries + self.cancelled_parities

    # whole-run rates, sharing ReportWindow's empty-window guard: a report
    # over zero completed queries (n == 0) yields 0.0, never a
    # ZeroDivisionError
    @property
    def straggler_rate(self) -> float:
        """Fraction of completions served by a parity reconstruction."""
        return _safe_rate(self.reconstructions, self.n)

    @property
    def corruption_rate(self) -> float:
        return _safe_rate(self.corrupted_detected, self.n)

    @property
    def cancellation_rate(self) -> float:
        return _safe_rate(self.cancellations, self.n)

    def summary(self) -> str:
        """One human-readable line (examples, launchers)."""
        return (
            f"[{self.engine}] {self.strategy}"
            f"{'/' + self.scheme if self.scheme else ''}"
            f" n={self.n} median={self.median_ms:.1f}ms"
            f" p99={self.p99_ms:.1f}ms p99.9={self.p999_ms:.1f}ms"
            f" recon={self.reconstructions} cancelled={self.cancellations}"
            + (f" corrupted={self.corrupted_detected}"
               f"/corrected={self.corrected}"
               if self.corrupted_detected else "")
        )
