"""The one typed serving report shared by BOTH serving layers.

``ServingReport`` replaces the two hand-rolled result dicts the threaded
``ParMFrontend.stats()`` and the DES ``simulate()`` used to return.  It is a
frozen dataclass — fields are the contract, and a field added here shows up
in both engines at once — but it also implements the ``Mapping`` protocol, so
every existing ``report["p999_ms"]``-style call site keeps working unchanged.

New in this report (vs the old dicts):

* ``engine``                           — ``"threads"`` or ``"sim"``;
* ``completed_by``                     — per-completion-path counts from the
                                         DES too (the runtime always had them);
* ``cancelled_queries`` / ``cancelled_parities`` — redundant-work
  cancellation: originals tombstoned after a parity decode beat them (and
  mirror copies of already-answered queries), and undispatched parity queries
  dropped because every original in their group already finished;
* ``batches`` / ``mean_batch_size``    — adaptive-batching bookkeeping: how
  many main-pool inference calls ran and how many queries each carried;
* ``corrupted_detected`` / ``corrected`` — Byzantine bookkeeping: erroneous
  responses a ``detects_errors`` scheme (approxifer) voted out, and how
  many of the affected predictions were nonetheless served from a clean
  reconstruction.  Both default to 0, so report consumers and schemes that
  never inject or detect errors are unaffected.
"""

from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field, fields
from typing import Dict, Optional


@dataclass(frozen=True, eq=True)
class ServingReport(Mapping):
    """Latency percentiles + completion bookkeeping for one serving run.

    Queries flushed at shutdown appear in ``completed_by`` but are excluded
    from the latency percentiles and ``n`` — their finish time is a shutdown
    artifact, not a latency.
    """

    engine: str = "threads"
    strategy: str = ""
    scheme: Optional[str] = None
    scenario: Optional[str] = None
    n: int = 0
    median_ms: float = float("nan")
    p99_ms: float = float("nan")
    p999_ms: float = float("nan")
    mean_ms: float = float("nan")
    max_ms: float = float("nan")
    # hash=False: the dict would break the frozen dataclass's generated
    # __hash__; equality still compares it field-wise
    completed_by: Dict[str, int] = field(default_factory=dict, hash=False)
    reconstructions: int = 0
    cancelled_queries: int = 0
    cancelled_parities: int = 0
    batches: int = 0
    mean_batch_size: float = 1.0
    corrupted_detected: int = 0
    corrected: int = 0

    # -- Mapping protocol: old ``stats()["p999_ms"]`` call sites keep
    # working.  The view is exactly the dataclass fields plus the derived
    # ``cancellations`` total — NOT arbitrary attributes, so methods are
    # not "in" the report and ``dict(report)`` round-trips every readable
    # key (including the one the examples read as ``stats["cancellations"]``)
    def _key_names(self):
        return [f.name for f in fields(self)] + ["cancellations"]

    def __getitem__(self, key):
        if key in self._key_names():
            return getattr(self, key)
        raise KeyError(key)

    def __iter__(self):
        return iter(self._key_names())

    def __len__(self):
        return len(self._key_names())

    @property
    def cancellations(self) -> int:
        """Total redundant work skipped at dequeue, both directions."""
        return self.cancelled_queries + self.cancelled_parities

    def summary(self) -> str:
        """One human-readable line (examples, launchers)."""
        return (
            f"[{self.engine}] {self.strategy}"
            f"{'/' + self.scheme if self.scheme else ''}"
            f" n={self.n} median={self.median_ms:.1f}ms"
            f" p99={self.p99_ms:.1f}ms p99.9={self.p999_ms:.1f}ms"
            f" recon={self.reconstructions} cancelled={self.cancellations}"
            + (f" corrupted={self.corrupted_detected}"
               f"/corrected={self.corrected}"
               if self.corrupted_detected else "")
        )
