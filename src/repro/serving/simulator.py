"""Discrete-event simulator of the ParM serving cluster (paper §5).

Reproduces the paper's tail-latency methodology without EC2: Poisson query
arrivals, single-queue load balancing (optimal for mean response time, §5.1),
background *network-shuffle* load that transiently inflates the service time
of randomly chosen instance pairs (§5.1 "Background traffic"), and 100k-query
runs reporting median / p99 / p99.9.

Strategies are ``ResilienceStrategy`` objects from
``repro.serving.strategy`` — the SAME objects the threaded runtime consumes,
so the two serving layers cannot drift.  ``simulate(cfg, strategy)`` accepts
either an instance or a registered name (``parm``, ``equal_resources``,
``approx_backup``, ``replication``, ``default_slo``, ``none``); the strategy
owns pool layout (the paper's m + m/k apples-to-apples budget, §5.1), group
assembly and on-unavailability behavior, and a strategy registered from any
other file runs here untouched.

Codes are ``CodingScheme`` objects resolved through ``get_scheme`` — again
the same objects ``ParMFrontend`` serves.  For a coded strategy the DES runs
one parity pool per parity model (r pools, paper §3.5), assembles coding
groups of ``scheme.k`` queries (a ``fixes_k`` scheme — approx_backup — owns
its group size; ``cfg.k`` stays the redundancy budget that sizes the pools),
and reconstruction follows the scheme's own recoverability rule via the
shared ``recoverable_rows`` (MDS all-or-nothing for linear codes: up to r
concurrent unavailabilities per group; per-row replica arrival for
replication and approximate backups), with encode/decode latency scaled by
the scheme's ``encode_cost`` / ``decode_cost`` hints.  A scheme marked
``approximate`` (the approx_backup scheme) runs its parity pool at
``cfg.approx_speedup`` times the deployed service rate — the §5.2.6
cheap-backup economics, now scheme-owned instead of a dedicated backup-pool
special case.

Fault injection beyond the built-in shuffle load comes from ``Scenario``
objects (``repro.serving.scenarios``): ``simulate(cfg, strategy,
scenario="crash")`` realizes the scenario's hazards — instance crash/restart,
correlated pool slowdowns, bursty MMPP arrivals, heterogeneous service rates
— into per-server slowdown windows.  With ``scenario=None`` the legacy
cfg-driven shuffle process runs unchanged.  The ``byzantine`` hazard family
(``CorruptOutputs``) is a different fault class: responses computed inside a
corrupt window are *erroneous* rather than late.  For a ``detects_errors``
scheme (approxifer) the DES re-runs a joint vote whenever a response
touches a group: all corrupt responses the group holds are evicted
together once ``n_held >= k + 2 * n_candidates`` (the classical 2e-surplus
error-correction margin, the same one the frontend's numeric
``flag_errors`` enforces) — caught in time, the affected query is served
from a clean reconstruction; caught late, the garbage was already served
and only the detection is recorded.  Counts surface as
``ServingReport.corrupted_detected`` / ``corrected``.  Schemes without
detection accept the garbage silently, with identical latency.

This module is the **sim engine** behind the declarative serving surface in
``repro.serving.api``: ``deploy(spec, engine="sim").replay(trace)`` builds a
``SimConfig`` from (spec, trace) and calls ``simulate``.  Two serving-policy
behaviors mirror the threaded runtime exactly:

* **adaptive batching** (``cfg.batch_max_size > 1``): the main pool dequeues
  up to that many waiting queries per free server and charges one service
  interval on the calibrated per-batch curve
  ``service * (1 + batch_cost * (b - 1))`` with the *actual* batch size b —
  so tail-latency studies can sweep ``BatchingPolicy`` settings.  (The
  legacy ``cfg.batch_size`` static multiplier is unchanged for old studies.)
* **redundant-work cancellation**: queued originals whose query already
  completed (a parity decode beat them, a mirror replica won, the SLO
  default fired) and queued parity queries whose whole group already
  finished are tombstoned — skipped at dequeue without occupying a server —
  and counted in ``ServingReport.cancelled_queries`` /
  ``cancelled_parities``, matching the runtime's dequeue-time semantics.

Workload axis (DESIGN.md §11):

* **arrival processes** — a scenario hazard with an ``arrival_times`` hook
  replaces the Poisson default: MMPP bursts (``bursty``), sinusoidal
  day/night load (``diurnal``), exponentially-decaying rate spikes
  (``flash_crowd``), explicit timestamp replay (``TraceArrivals``).
  ``cfg.arrival_times_ms`` short-circuits all of that with a raw timestamp
  array.
* **multi-tenant mode** (``cfg.tenants``, a tuple of ``TenantClass``):
  arrivals are tagged with a tenant drawn from the classes' traffic shares;
  the main pool dequeues by weighted fair queueing over per-tenant queues
  (stride scheduling on virtual time — a tenant with weight 2 drains twice
  as fast under contention), per-class SLOs override ``cfg.slo_ms``, and
  ``ServingReport.per_tenant`` carries the per-class breakdown.

Performance: the event loop runs two ways.  Eligible configurations — no
controller, no tenants, no batching, mirror-free strategies, and a realized
``FaultPlan`` with no windows or rate skews (e.g. ``calm``, or any pure
arrival-process scenario) — take ``_fast_sim``, a fully inlined hot loop
over primitive-tuple heap entries and bytearray group state that sustains
millions of events per second (a seeded 10M-query ``sum``/r=1 run completes
in well under 30 s; ``BENCH_baseline.json`` locks the events/sec floor).
Everything else takes the general loop.  Both paths draw service times from
per-pool ``default_rng([seed, stream])`` child streams in pre-drawn blocks
and share dispatch order, so for an eligible config the two paths are
**bit-identical** — ``_FORCE_PATH = "general"`` pins that in tests.
``ServingReport.events`` counts processed events on either path.
"""
from __future__ import annotations

import gc
import heapq
import math
from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.scheme import (ReplicationScheme, decode_cost, encode_cost,
                               get_scheme, recoverable_rows,
                               scheme_capabilities)
from repro.serving.controller import Adjustment, get_controller
from repro.serving.report import ServingReport, build_window
from repro.serving.scenarios import TenantClass, get_scenario
from repro.serving.strategy import get_strategy

# service-time draws come in pre-drawn blocks of this many per pool; one
# block refill replaces tens of thousands of per-event Generator calls
_CHUNK = 1 << 15

# test hook: None = auto (fast loop when eligible), "general" forces the
# general loop, "fast" asserts eligibility (raises if the config cannot
# take the fast path).  The bit-equality test runs both and compares.
_FORCE_PATH: Optional[str] = None

# test hook for the general loop's batch-decode drain: None/"batched" =
# gather every touched group's reconstruction plan first, then complete them
# (the DES twin of the frontend's one-launch multigroup decode — decode time
# is still charged PER GROUP via decode_cost, so the drains are bit-equal);
# "pergroup" = interleave plan and completion per group (the pre-fusion
# path).  The fused/unfused differential test runs both and asserts
# identical ServingReports.
_FORCE_DECODE: Optional[str] = None


@dataclass
class SimConfig:
    m: int = 12                     # deployed-model instances
    k: int = 2                      # coding-group size (redundancy 1/k)
    r: int = 1                      # parity models per group (paper §3.5);
                                    # schemes may fix their own (replication)
    qps: float = 270.0
    n_queries: int = 100_000
    service_ms: float = 25.0        # mean inference time (ResNet-18 on K80)
    service_cv: float = 0.05        # coefficient of variation (lognormal)
    # background load: concurrent network shuffles, each congesting the
    # link of one randomly chosen instance for its duration; queries served
    # by a congested instance incur an additional transfer delay
    n_shuffles: int = 4
    shuffle_ms: tuple = (300.0, 700.0)   # duration ~ U[a, b]
    shuffle_gap_ms: tuple = (800.0, 2400.0)  # idle gap between shuffles
    shuffle_delay_ms: tuple = (10.0, 40.0)   # added per-query delay when slow
    shuffle_slowdown: float = 1.0        # optional multiplicative part
    encode_ms: float = 0.153        # paper §5.2.5 (k=3 median), in ms
    decode_ms: float = 0.014        # one r=1 subtraction decode; multi-row
                                    # decodes pay scheme.decode_cost() times it
    approx_speedup: float = 1.15    # §5.2.6, GPU cluster value
    slo_ms: float = 200.0           # default-prediction deadline
                                    # (default_slo); None disables the
                                    # deadline, matching a threads-engine
                                    # deployment with no slo_ms set
    batch_size: int = 1             # §5.2.3 legacy static model: every
                                    # service interval is charged for a fixed
                                    # batch of this size
    batch_cost: float = 0.2         # service(b) = service * (1 + cost*(b-1));
                                    # GPUs batch well (paper scaled qps by the
                                    # observed throughput gain)
    batch_max_size: int = 1         # adaptive batching (BatchingPolicy
                                    # .max_size): main pool dequeues up to
                                    # this many queries per free server and
                                    # charges the per-batch curve at the
                                    # ACTUAL batch size
    seed: int = 0
    # multi-tenant mode: TenantClass tuple (or dicts of its fields) tagging
    # traffic with shares / WFQ weights / per-class SLOs; empty tuple =
    # single-tenant.  DESIGN.md §11
    tenants: tuple = ()
    # explicit arrival timestamps (ms), overriding both the Poisson default
    # and any scenario arrival process; must hold >= n_queries
    # non-decreasing times (TenantClass-style cycling of short traces is
    # TraceArrivals' job)
    arrival_times_ms: Optional[tuple] = None


def _as_tenant(tc) -> TenantClass:
    """Normalize a tenant entry: ``TenantClass`` passes through, a dict of
    its fields (a JSON config, or an ``asdict``-flattened trace) is
    rehydrated."""
    if isinstance(tc, TenantClass):
        return tc
    if isinstance(tc, dict):
        return TenantClass(**tc)
    raise TypeError(f"not a TenantClass or dict of its fields: {tc!r}")


class _Pool:
    """Single-queue pool of n servers with per-server slowdown windows.

    ``batch_max`` — adaptive batching: a free server takes up to this many
    queued items per dispatch (1 = no batching).  ``skip`` — redundant-work
    tombstone check applied at dequeue; skipped items never occupy a server.

    Service times are drawn from a dedicated ``default_rng([seed, stream])``
    child stream in pre-drawn blocks of ``_CHUNK`` (``draw``) — the parent
    generator is reserved for setup-time draws (arrivals, hazard
    realization, tenant assignment), which keeps seeded arrival patterns
    stable across simulator changes and lets the fast path share the exact
    draw sequence.

    ``use_wfq(weights)`` switches the queue to weighted fair queueing over
    per-tenant deques (stride scheduling: each dequeue advances the chosen
    tenant's virtual time by 1/weight; a tenant going from idle to busy
    catches its virtual time up to the pool's, so idle periods bank no
    credit).  Tombstoned items are charged like real ones — cancellation
    cost lands on the tenant that queued the work.
    """

    def __init__(self, name, n, stream, cfg, mean_ms, batch_max=1,
                 skip=None):
        self.name = name
        self.n = n
        self.free = list(range(n))
        self.queue = deque()
        self.rng = np.random.default_rng([cfg.seed, stream])
        self.cfg = cfg
        self.mean = mean_ms
        self.batch_max = batch_max
        self.skip = skip
        self.n_calls = 0                # inference calls (batches) served
        self.n_items = 0                # queries those calls carried
        self.slow_until = [0.0] * n
        self.plan = None                # FaultPlan from a Scenario, if any
        self._hazardous = False         # plan has windows/rates on THIS pool
        self._corruptible = False       # ... including corrupt windows
        self.sigma = math.sqrt(math.log(1 + cfg.service_cv ** 2))
        self.mu = math.log(mean_ms) - self.sigma ** 2 / 2
        self._blk = ()                  # pre-drawn lognormal block
        self._bi = _CHUNK               # read cursor (== len -> refill)
        # WFQ state (None until use_wfq)
        self._tq = None
        self._vt = None
        self._stride = None
        self._vnow = 0.0

    def set_plan(self, plan):
        """Attach a realized FaultPlan, pre-answering the two hot-path
        questions (any hazard here at all? any corrupt window?) so calm and
        narrowly-targeted scenarios skip the per-dispatch window lookup."""
        self.plan = plan
        self._hazardous = plan.relevant(self.name)
        self._corruptible = self._hazardous and plan.n_corrupt > 0

    def use_wfq(self, weights):
        self._tq = [deque() for _ in weights]
        self._vt = [0.0] * len(weights)
        self._stride = [1.0 / w for w in weights]

    def draw(self):
        """Next lognormal service draw off the pre-drawn block."""
        i = self._bi
        if i >= _CHUNK:
            self._blk = self.rng.lognormal(self.mu, self.sigma,
                                           _CHUNK).tolist()
            i = 0
        self._bi = i + 1
        return self._blk[i]

    def service_time(self, server, now, b=1):
        base = self.draw()
        # batching curve: adaptive batching charges the ACTUAL batch size;
        # the legacy static model charges cfg.batch_size for every interval
        eff_b = b if self.batch_max > 1 else self.cfg.batch_size
        if eff_b > 1:
            base *= 1.0 + self.cfg.batch_cost * (eff_b - 1)
        if now < self.slow_until[server]:
            base = base * self.cfg.shuffle_slowdown + \
                self.rng.uniform(*self.cfg.shuffle_delay_ms)
        if self._hazardous:
            base = self.plan.adjust_service_ms(self.name, server, now, base,
                                               self.rng)
        return base

    def corrupts(self, server, now) -> bool:
        return self._corruptible and self.plan.corrupts(self.name, server,
                                                        now)

    def submit(self, item, tenant=None):
        if self._tq is None:
            self.queue.append(item)
            return
        q = self._tq[tenant]
        if not q:
            # idle -> busy: catch the tenant's virtual time up to the
            # pool's, so idle periods bank no scheduling credit
            if self._vt[tenant] < self._vnow:
                self._vt[tenant] = self._vnow
        q.append(item)

    def _nonempty(self):
        if self._tq is None:
            return bool(self.queue)
        return any(self._tq)

    def _pop_next(self):
        if self._tq is None:
            return self.queue.popleft()
        best, bvt = -1, math.inf
        for i, q in enumerate(self._tq):
            if q and self._vt[i] < bvt:
                bvt = self._vt[i]
                best = i
        self._vnow = bvt
        self._vt[best] = bvt + self._stride[best]
        return self._tq[best].popleft()

    def try_dispatch(self, now):
        """Returns list of (server, items, finish_time); ``items`` is the
        batch one server serves in one inference call."""
        out = []
        while self.free and self._nonempty():
            batch = []
            while len(batch) < self.batch_max and self._nonempty():
                item = self._pop_next()
                if self.skip is not None and self.skip(item):
                    continue            # tombstoned while queued
                batch.append(item)
            if not batch:
                break                   # queue drained by tombstones
            s = self.free.pop()
            self.n_calls += 1
            self.n_items += len(batch)
            out.append((s, batch,
                        now + self.service_time(s, now, len(batch))))
        return out


def _finalize_report(cfg, strat, cur, scen, ctl, n_windows, adjust_log,
                     latency, how, cancelled_q, cancelled_p, main_calls,
                     main_items, parity_served, corrupted_detected,
                     corrected, n_events, tenant_of=None, classes=None):
    """Completeness check + ServingReport assembly shared by both loop
    implementations, so the two paths cannot drift in what they report."""
    n = cfg.n_queries
    finite = np.isfinite(latency)
    if int(finite.sum()) != n:
        # a hard error, not an assert: an event-handling bug that drops
        # queries must fail loudly even under ``python -O`` — percentiles
        # over a silently-shortened array are exactly the kind of wrong
        # answer a capacity-planning instrument must never produce
        missing = np.nonzero(~finite)[0]
        head = ", ".join(str(int(q)) for q in missing[:10])
        more = ", ..." if missing.size > 10 else ""
        raise RuntimeError(
            f"simulator dropped {missing.size} of {n} queries "
            f"(unanswered qids: {head}{more}) — every query must complete "
            f"by model, parity reconstruction, or SLO default")
    lat = latency
    how = np.asarray(how, dtype=np.int8)
    per_tenant = {}
    if classes:
        for ti, tc in enumerate(classes):
            mask = tenant_of == ti
            cnt = int(mask.sum())
            lt = lat[mask]
            eff = tc.slo_ms if tc.slo_ms is not None else cfg.slo_ms
            # a default-served query finishes AT the deadline (latency ==
            # slo, not >), but it was answered with the default prediction
            # — that is a violation, so count how==2 explicitly
            if eff is not None:
                viol = int(((lt > eff) | (how[mask] == 2)).sum())
            else:
                viol = int((how[mask] == 2).sum())
            per_tenant[tc.name] = {
                "n": cnt,
                "share": cnt / n if n else 0.0,
                "median_ms": float(np.percentile(lt, 50)) if cnt
                else float("nan"),
                "p999_ms": float(np.percentile(lt, 99.9)) if cnt
                else float("nan"),
                "slo_ms": eff,
                "slo_violations": viol,
            }
    by = {}
    for code, name in ((0, "model"), (1, "parity"), (2, "default")):
        c = int((how == code).sum())
        if c:
            by[name] = c
    return ServingReport(
        engine="sim",
        strategy=strat.name,
        # the report names the scheme the run ENDED on (post-adjustments)
        scheme=cur["schm"].name if strat.coded else None,
        scenario=scen.name if scen is not None else None,
        n=n,
        median_ms=float(np.percentile(lat, 50)),
        p99_ms=float(np.percentile(lat, 99)),
        p999_ms=float(np.percentile(lat, 99.9)),
        mean_ms=float(lat.mean()),
        max_ms=float(lat.max()),
        completed_by=by,
        reconstructions=int((how == 1).sum()),
        cancelled_queries=cancelled_q,
        cancelled_parities=cancelled_p,
        batches=main_calls,
        mean_batch_size=(main_items / main_calls) if main_calls else 1.0,
        corrupted_detected=corrupted_detected,
        corrected=corrected,
        controller=ctl.name if ctl is not None else None,
        windows=n_windows,
        adjustments=tuple(adjust_log),
        parity_served=parity_served,
        events=n_events,
        per_tenant=per_tenant)


def _fast_sim(cfg, strat, cur, pred, pools, arrivals, scen):
    """The inlined hot loop for eligible configurations.

    Preconditions (checked by ``simulate``): no controller, no tenants, no
    adaptive batching, ``strat.mirror == 1``, no SLO defaults, a realized
    ``FaultPlan`` with zero windows and no rate skews, and — for coded
    strategies — a scheme whose recoverability rule is one of the three
    closed forms (``mds`` all-or-nothing, ``row`` per-replica,
    ``count`` dynamic-arity).

    Bit-identical to the general loop on these configs: same per-pool child
    RNG streams read through the same ``_CHUNK``-block discipline, same
    dispatch order, same float arithmetic.  All state lives in locals —
    primitive-tuple heap entries ``(finish_t, seq, pool_code, item)``,
    bytearray group counters, list-backed queues — which is what buys the
    order-of-magnitude over the object-per-event general loop.

    The cyclic GC is paused for the duration (restored on exit): the loop
    allocates tens of millions of short-lived tuples but no cycles, and in
    a process with a large live graph (the bench suite imports jax) each
    generational scan over it costs real wall time.
    """
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        return _fast_sim_inner(cfg, strat, cur, pred, pools, arrivals, scen)
    finally:
        if gc_was_enabled:
            gc.enable()


def _fast_sim_inner(cfg, strat, cur, pred, pools, arrivals, scen):
    n = cfg.n_queries
    arr = arrivals.tolist()
    INF = float("inf")
    coded = strat.coded
    gk, r = cur["gk"], cur["r"]
    enc, dec = cur["enc_ms"], cfg.decode_ms
    schm = cur["schm"]
    bmul = 1.0 + cfg.batch_cost * (cfg.batch_size - 1)
    scaled = cfg.batch_size > 1

    CHUNK = _CHUNK                  # local alias for the hot refill checks

    main = pools["main"]
    mrng, mmu, msig = main.rng, main.mu, main.sigma
    mblk = mrng.lognormal(mmu, msig, CHUNK).tolist()
    mbi = 0
    mfree = main.n
    mq = deque()
    mq_append, mq_popleft = mq.append, mq.popleft

    # r1/is_mds defaults keep the uncoded loop's branch tests well-defined
    r1 = is_mds = is_row = False
    full_g = 0
    if coded:
        pp = [pools[f"parity{j}"] for j in range(r)]
        prngs = [p.rng for p in pp]
        pmus = [p.mu for p in pp]
        psigs = [p.sigma for p in pp]
        pblk = [prngs[j].lognormal(pmus[j], psigs[j], CHUNK).tolist()
                for j in range(r)]
        pbi = [0] * r
        pfree = [p.n for p in pp]
        pqs = [deque() for _ in pp]
        full_g = n // gk
        g_resp = bytearray(full_g + 1)
        g_done = bytearray(full_g + 1)
        g_par = bytearray(full_g + 1)
        g_pmask = [0] * (full_g + 1)            # row-predicate parity mask
        dct = [float(decode_cost(schm, i)) for i in range(gk + 1)]
        kneed = schm.k if pred == "count" else 0
        gk1 = gk - 1
        is_mds = pred == "mds"
        is_row = pred == "row"
        # r == 1 (the benchmark case, and every built-in coded strategy's
        # default) gets scalar parity locals — one server-count int, one
        # service block, one queue — instead of per-j list indexing
        r1 = r == 1
        if r1:
            prng0, pmu0, psig0 = prngs[0], pmus[0], psigs[0]
            pblk0 = pblk[0]
            pbi0 = 0
            pfree0 = pfree[0]
            pq0 = pqs[0]
            pq0_append, pq0_popleft = pq0.append, pq0.popleft

    done = bytearray(n)
    member_resp = bytearray(n)
    done_t = [0.0] * n
    how = bytearray(n)
    cancelled_q = cancelled_p = 0

    heap = []
    push, pop = heapq.heappush, heapq.heappop
    seq = n            # runtime events; arrivals own virtual seqs 0..n-1
    ai = 0
    next_arr = arr[0] if n else INF

    while True:
        if heap:
            take_arr = ai < n and next_arr <= heap[0][0]
        elif ai < n:
            take_arr = True
        else:
            break
        if take_arr:
            qi = ai
            t = next_arr
            ai += 1
            next_arr = arr[ai] if ai < n else INF
            # invariant: a free server implies an empty queue (every finish
            # drains tombstones until it dispatches or idles), so a direct
            # dispatch here matches the general submit-then-try_dispatch
            if mfree:
                mfree -= 1
                if mbi == CHUNK:
                    mblk = mrng.lognormal(mmu, msig, CHUNK).tolist()
                    mbi = 0
                svc = mblk[mbi]
                mbi += 1
                if scaled:
                    svc *= bmul
                push(heap, (t + svc, seq, 0, qi))
                seq += 1
            else:
                mq_append(qi)
            if coded and qi % gk == gk1:
                # group boundary: encode + dispatch r parity queries.  The
                # gk-th member just arrived, so the group cannot be fully
                # done — no tombstone check on this direct dispatch
                g = qi // gk
                if r1:
                    if pfree0:
                        pfree0 -= 1
                        if pbi0 == CHUNK:
                            pblk0 = prng0.lognormal(
                                pmu0, psig0, CHUNK).tolist()
                            pbi0 = 0
                        svc = pblk0[pbi0]
                        pbi0 += 1
                        if scaled:
                            svc *= bmul
                        push(heap, (t + enc + svc, seq, 1, g))
                        seq += 1
                    else:
                        pq0_append(g)
                else:
                    tenc = t + enc
                    for j in range(r):
                        if pfree[j]:
                            pfree[j] -= 1
                            bi = pbi[j]
                            if bi == CHUNK:
                                pblk[j] = prngs[j].lognormal(
                                    pmus[j], psigs[j], CHUNK).tolist()
                                bi = 0
                            svc = pblk[j][bi]
                            pbi[j] = bi + 1
                            if scaled:
                                svc *= bmul
                            push(heap, (tenc + svc, seq, j + 1, g))
                            seq += 1
                        else:
                            pqs[j].append(g)
            continue
        ev = pop(heap)
        t = ev[0]
        code = ev[2]
        if code == 0:                           # main-pool finish
            qi = ev[3]
            if coded:
                member_resp[qi] = 1
                g = qi // gk
                g_resp[g] += 1
                if not done[qi]:
                    done[qi] = 1
                    done_t[qi] = t
                    g_done[g] += 1
                if g_par[g] and g_done[g] < gk:
                    # mds (the default predicate) is inlined: on the 10M
                    # benchmark the call overhead of _fast_recon alone is
                    # seconds of wall time
                    if is_mds:
                        missing = gk - g_resp[g]
                        if missing and g_par[g] >= missing:
                            ready = t + dec * dct[missing]
                            base = g * gk
                            for i2 in range(base, base + gk):
                                if not member_resp[i2] and not done[i2]:
                                    done[i2] = 1
                                    aq = arr[i2]
                                    done_t[i2] = (ready if ready > aq
                                                  else aq)
                                    how[i2] = 1
                                    g_done[g] += 1
                    else:
                        _fast_recon(pred, g, gk, t, dec, dct, kneed,
                                    g_resp, g_done, g_par, g_pmask,
                                    member_resp, done, done_t, how, arr)
            elif not done[qi]:
                done[qi] = 1
                done_t[qi] = t
            while mq:
                nqi = mq_popleft()
                if done[nqi]:
                    cancelled_q += 1
                    continue
                if mbi == CHUNK:
                    mblk = mrng.lognormal(mmu, msig, CHUNK).tolist()
                    mbi = 0
                svc = mblk[mbi]
                mbi += 1
                if scaled:
                    svc *= bmul
                push(heap, (t + svc, seq, 0, nqi))
                seq += 1
                break
            else:
                mfree += 1
        elif r1:                                # parity finish, scalar path
            g = ev[3]
            g_par[g] += 1
            if is_row:
                g_pmask[g] |= 1
            if g_done[g] < gk:
                if is_mds:
                    missing = gk - g_resp[g]
                    if missing and g_par[g] >= missing:
                        ready = t + dec * dct[missing]
                        base = g * gk
                        for i2 in range(base, base + gk):
                            if not member_resp[i2] and not done[i2]:
                                done[i2] = 1
                                aq = arr[i2]
                                done_t[i2] = ready if ready > aq else aq
                                how[i2] = 1
                                g_done[g] += 1
                else:
                    _fast_recon(pred, g, gk, t, dec, dct, kneed, g_resp,
                                g_done, g_par, g_pmask, member_resp, done,
                                done_t, how, arr)
            while pq0:
                ng = pq0_popleft()
                if g_done[ng] >= gk:
                    cancelled_p += 1
                    continue
                if pbi0 == CHUNK:
                    pblk0 = prng0.lognormal(pmu0, psig0, CHUNK).tolist()
                    pbi0 = 0
                svc = pblk0[pbi0]
                pbi0 += 1
                if scaled:
                    svc *= bmul
                push(heap, (t + svc, seq, 1, ng))
                seq += 1
                break
            else:
                pfree0 += 1
        else:                                   # parity-pool finish, r > 1
            j = code - 1
            g = ev[3]
            g_par[g] += 1
            g_pmask[g] |= 1 << j
            if g_done[g] < gk:
                _fast_recon(pred, g, gk, t, dec, dct, kneed, g_resp,
                            g_done, g_par, g_pmask, member_resp, done,
                            done_t, how, arr)
            q = pqs[j]
            while q:
                ng = q.popleft()
                if g_done[ng] >= gk:
                    cancelled_p += 1
                    continue
                bi = pbi[j]
                if bi == CHUNK:
                    pblk[j] = prngs[j].lognormal(
                        pmus[j], psigs[j], CHUNK).tolist()
                    bi = 0
                svc = pblk[j][bi]
                pbi[j] = bi + 1
                if scaled:
                    svc *= bmul
                push(heap, (t + svc, seq, j + 1, ng))
                seq += 1
                break
            else:
                pfree[j] += 1

    done_arr = np.frombuffer(bytes(done), dtype=np.uint8).astype(bool)
    latency = np.where(done_arr, np.asarray(done_t) - arrivals, np.inf)
    # call/item counters are derived, not tracked per event: every query is
    # dequeued exactly once (dispatched or tombstone-cancelled), and every
    # assembled group enqueues exactly r parity items, so at drain-out
    # main calls = n - cancelled_q and parity items = full_g*r - cancelled_p
    main_calls = n - cancelled_q
    parity_served = full_g * r - cancelled_p if coded else 0
    # likewise events = arrivals + finish pops; no per-event increment needed
    n_ev = n + main_calls + parity_served
    return _finalize_report(
        cfg, strat, cur, scen, None, 0, (), latency,
        np.frombuffer(bytes(how), dtype=np.uint8), cancelled_q,
        cancelled_p, main_calls, main_calls, parity_served, 0, 0, n_ev)


def _fast_recon(pred, g, gk, t, dec, dct, kneed, g_resp, g_done, g_par,
                g_pmask, member_resp, done, done_t, how, arr):
    """Closed-form ``maybe_reconstruct`` for the three supported
    recoverability rules.  Caller guarantees ``g_par[g] > 0`` and
    ``g_done[g] < gk`` — which also keeps never-assembled trailing groups
    out (their g_par stays 0).  ``dct`` is indexed by the TOTAL number of
    rows the decode touches (resp-missing members, done or not), matching
    ``recoverable_rows(...).sum()`` in the general loop."""
    base = g * gk
    if pred == "row":
        mask = g_pmask[g]
        nrows = 0
        for i in range(gk):
            if not member_resp[base + i] and (mask >> i) & 1:
                nrows += 1
        if not nrows:
            return
        ready = t + dec * dct[nrows]
        for i in range(gk):
            qi = base + i
            if not member_resp[qi] and (mask >> i) & 1 and not done[qi]:
                done[qi] = 1
                aq = arr[qi]
                done_t[qi] = ready if ready > aq else aq
                how[qi] = 1
                g_done[g] += 1
        return
    missing = gk - g_resp[g]
    if not missing:
        return
    if pred == "mds":
        if g_par[g] < missing:
            return
    elif g_resp[g] + g_par[g] < kneed:           # pred == "count"
        return
    ready = t + dec * dct[missing]
    for i in range(base, base + gk):
        if not member_resp[i] and not done[i]:
            done[i] = 1
            aq = arr[i]
            done_t[i] = ready if ready > aq else aq
            how[i] = 1
            g_done[g] += 1


def simulate(cfg: SimConfig, strategy="parm", scheme=None, scenario=None,
             backend=None, controller=None):
    """Run the DES under a ``ResilienceStrategy`` (instance or registered
    name).  ``scheme`` (instance or name) overrides the strategy's default
    code for coded strategies; ``scenario`` (instance or name) overrides the
    built-in shuffle background load with a hazard set from
    ``repro.serving.scenarios``.  ``backend`` is validated through the same
    ``get_scheme`` resolution the threads engine applies — the DES runs no
    kernel math, but an identical spec must pass or fail identically on both
    engines.  ``controller`` (instance or registered name from
    ``repro.serving.controller``) closes the loop: every
    ``controller.window_ms`` of simulated time a ``ctl`` event builds a
    ``ReportWindow`` from the completions inside the window and applies any
    returned ``Adjustment`` at the next coding-group boundary — on this
    clock, as events, so the differential battery can assert identical
    decision sequences against the threads engine.  Returns a
    ``ServingReport`` (typed, dict-compatible) with latency percentiles and
    bookkeeping."""
    strat = get_strategy(strategy)
    rng = np.random.default_rng(cfg.seed)
    k = cfg.k                               # redundancy budget (pool sizing)
    parity_service_ms = cfg.service_ms
    # resolve the scheme UNCONDITIONALLY, exactly like ParMFrontend._build:
    # an invalid scheme/backend must fail identically on both engines even
    # under a non-coded strategy (which then simply never uses the code)
    want = scheme if scheme is not None else (strat.scheme or "sum")
    # cfg.r sizes registry-name schemes; an instance carries its own r
    # (mirrors ParMFrontend, which defaults r to the instance's value)
    resolved = get_scheme(want, k=k,
                          r=cfg.r if isinstance(want, str) else None,
                          backend=backend)
    # the CURRENT deployment knobs — mutable, because a controller may
    # retune them mid-run; new coding groups capture them at assembly
    cur = {"schm": None, "r": cfg.r, "gk": k, "enc_ms": cfg.encode_ms,
           "det": False, "batch_max": max(1, cfg.batch_max_size)}
    if strat.coded:
        caps = scheme_capabilities(resolved)
        cur["schm"] = resolved
        cur["r"] = resolved.r               # a scheme may fix its own r
        cur["gk"] = resolved.k              # ... and its own group size
        cur["enc_ms"] = cfg.encode_ms * encode_cost(resolved)
        # capability read hoisted out of the per-group hot loop
        cur["det"] = caps.detects_errors
        if caps.approximate:
            # approx_backup scheme: the parity pool runs cheap backup models
            parity_service_ms = cfg.service_ms / cfg.approx_speedup
    # the deployment's own resolved scheme OBJECT and r: controller
    # de-escalation restores this instance (not a fresh registry default
    # under the same name), and group dispatch routes by identity against
    # it — the same contract as ParMFrontend._base_scheme
    base_schm, base_r = cur["schm"], cur["r"]

    ctl = None
    if controller is not None:
        ctl = get_controller(controller)

    # multi-tenant mode (DESIGN.md §11): normalize classes, validate names
    classes = tuple(_as_tenant(tc) for tc in cfg.tenants)
    if classes:
        names = [tc.name for tc in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {sorted(names)}")
        slo_of = [tc.slo_ms if tc.slo_ms is not None else cfg.slo_ms
                  for tc in classes]

    n = cfg.n_queries
    latency = np.full(n, np.inf)
    done = np.zeros(n, bool)
    how = np.zeros(n, np.int8)              # 0 model | 1 parity | 2 default
    cancelled = {"q": 0, "p": 0}
    # Byzantine bookkeeping (detects_errors schemes under corrupt-output
    # hazards): responses voted out, and affected predictions served clean
    corrupted = {"detected": 0, "corrected": 0}
    member_resp = np.zeros(n, bool)         # member responses the decoder
                                            # currently holds (clean, or
                                            # corrupt but not yet voted out)
    corrupt_members = {}                    # gid -> set of qi: corrupt member
                                            # responses held, not yet evicted
    corrupt_parities = {}                   # gid -> set of j: likewise
    corrupt_stash = {}                      # qi -> finish_t: voted-out member
                                            # responses whose query is still
                                            # unanswered

    # dynamic coding-group bookkeeping (coded strategies only): groups
    # assemble from consecutive arrivals and CAPTURE the scheme / r / error
    # detection active at assembly, so a controller adjustment applies at
    # the next group boundary without touching in-flight groups — the same
    # contract the threaded frontend honors.  Member availability is read
    # off ``done`` — a reconstructed member counts as available for the
    # next decode decision, exactly as in the runtime's _maybe_decode
    groups = {}      # gid -> {"members", "schm", "r", "det", "parity_t"}
    gid_of = {}      # qi -> gid, assigned at arrival
    pending = []     # members of the group currently assembling
    next_gid = 0

    def tombstoned(item):
        """Dequeue-time redundant-work cancellation — the DES mirror of the
        runtime's ``ParMFrontend._should_skip``: an original whose query
        already completed, or a parity query whose whole group did, is
        skipped without occupying a server."""
        kind, idx = item
        if kind == "q":
            if done[idx]:
                cancelled["q"] += 1
                return True
            return False
        if done[groups[idx[0]]["members"]].all():
            cancelled["p"] += 1
            return True
        return False

    # A controller may escalate at runtime: parity pools come in TWO
    # families, mirroring ParMFrontend._build.  Pools 0..base_r-1 are the
    # deployment's own parity pools; Controller.escalation_r extra pools
    # model workers running the *deployed* parameters (plain service time,
    # never the approx-backup speedup), and every adjustment that is not an
    # exact return to the base dispatches there.
    agn_base, agn_r = cur["r"], 0
    if ctl is not None and strat.coded:
        esc = getattr(ctl, "escalation_r", ctl.max_r)
        agn_r = max(0, int(esc(cur["r"])))
    r_pools = cur["r"] + agn_r
    layout = strat.layout(cfg.m, k, cur["r"])
    # per-pool child RNG streams ([seed, 0] = main, [seed, 1 + j] = parity
    # pool j): service draws come off these in pre-drawn blocks, leaving
    # the parent generator to setup-time draws only
    pools = {"main": _Pool("main", layout.main, 0, cfg, cfg.service_ms,
                           batch_max=cur["batch_max"],
                           skip=tombstoned)}
    if layout.parity:
        for j in range(r_pools):
            svc = parity_service_ms if j < cur["r"] else cfg.service_ms
            pools[f"parity{j}"] = _Pool(f"parity{j}", layout.parity, 1 + j,
                                        cfg, svc,
                                        skip=tombstoned)
    if classes:
        pools["main"].use_wfq([tc.weight for tc in classes])

    # pre-draw arrivals (a scenario may replace Poisson with another
    # arrival process; cfg.arrival_times_ms overrides everything)
    scen = None
    if scenario is None:
        scenario = strat.scenario
    if scenario is not None:
        scen = get_scenario(scenario)
    arrivals = None
    if cfg.arrival_times_ms is not None:
        ats = np.asarray(cfg.arrival_times_ms, dtype=float)
        if ats.ndim != 1 or ats.size < n:
            raise ValueError(
                f"arrival_times_ms holds {ats.size} timestamps but "
                f"n_queries={n} (use TraceArrivals to cycle a short trace)")
        if ats.size > 1 and np.any(np.diff(ats[:n]) < 0):
            raise ValueError("arrival_times_ms must be non-decreasing")
        arrivals = ats[:n].copy()
    elif scen is not None:
        arrivals = scen.arrival_times(cfg, rng)
    if arrivals is None:
        arrivals = np.cumsum(rng.exponential(1000.0 / cfg.qps, n))
    arrival_t = arrivals.copy()
    end_of_arrivals = arrivals[-1]

    # tenant assignment draws follow the arrival draws on the parent
    # stream (single-tenant runs consume nothing here, so their seeded
    # arrival + hazard patterns are unchanged)
    tenant_of = None
    if classes:
        shares = np.asarray([tc.share for tc in classes], dtype=float)
        tenant_of = rng.choice(len(classes), size=n, p=shares / shares.sum())

    plan = None
    if scen is not None:
        # scenario-owned hazards: realize crash/slowdown/heterogeneity
        # windows over the arrival horizon; the legacy shuffle process is off
        plan = scen.realize({name: p.n for name, p in pools.items()},
                            end_of_arrivals, rng)
        for p in pools.values():
            p.set_plan(plan)

    # ------------------------------------------------------- path selection
    # the fast loop handles the no-feedback, no-tenant, unbatched,
    # mirror-free, hazard-free core — which includes every pure
    # arrival-process scenario — for schemes with a closed-form
    # recoverability rule; everything else takes the general loop below
    pred = None
    if not strat.coded:
        pred = "none"
    else:
        s_ = cur["schm"]
        if getattr(s_, "recoverable", None) is None:
            pred = "mds"
        elif getattr(type(s_), "recoverable", None) is \
                ReplicationScheme.recoverable and cur["r"] == cur["gk"]:
            pred = "row"
        elif type(s_).__name__ == "ApproxIFERScheme":
            pred = "count"
    have_parity = (not strat.coded or
                   all(f"parity{j}" in pools for j in range(cur["r"])))
    fast_ok = (n > 0 and ctl is None and not classes
               and strat.mirror == 1 and not strat.slo_default
               and cur["batch_max"] == 1 and pred is not None
               and have_parity and plan is not None
               and plan.n_windows == 0 and not plan.rates)
    if _FORCE_PATH == "general":
        fast_ok = False
    elif _FORCE_PATH == "fast" and not fast_ok:
        raise ValueError(
            "_FORCE_PATH='fast' but the config is not eligible for the "
            "fast DES path")
    if fast_ok:
        return _fast_sim(cfg, strat, cur, pred, pools, arrivals, scen)

    # ------------------------------------------------------- general loop
    events = []

    # closed-loop machinery: one "ctl" event per observation window whose
    # START precedes the end of arrivals (the threads engine closes the
    # same set: at submit time, plus trailing windows at shutdown).  Ctl
    # events own seqs 0..n_windows-1 so a ctl event at time t sorts ahead
    # of an arrival at the same t — the frontend ticks its window clock at
    # the top of submit(), before recording the query
    adjust_log = []          # (window_index, scheme, r, batch_max_size)
    wrecs = []               # (t_done, latency, by), kept sorted by t_done
    wprev = {"detected": 0, "cancel": 0}    # counter snapshots per window
    pending_adj = None       # (Adjustment, window_index) deferred to the
                             # next group boundary
    n_windows = 0
    ctl_state = None
    wlen = 0.0
    if ctl is not None:
        wlen = float(ctl.window_ms)
        n_windows = int(math.ceil(end_of_arrivals / wlen))
        for i in range(n_windows):
            heapq.heappush(events, ((i + 1) * wlen, i, "ctl", i))
        ctl_state = ctl.init(Adjustment(
            scheme=cur["schm"].name if strat.coded else None,
            r=cur["r"] if strat.coded else None,
            batch_max_size=cur["batch_max"]))

    # arrivals are NOT heap-resident: the loop merges the sorted arrival
    # array with the heap, comparing (t, seq) with virtual arrival seqs
    # n_windows..n_windows+n-1 — runtime-pushed events start past them, so
    # at equal t the order is ctl < arrival < finish/slo/shuffle, exactly
    # the order the old push-everything loop produced
    seq = n_windows + n

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    def apply_adjustment(adj, widx, live=True):
        """Retune the CURRENT knobs; in-flight groups keep what they
        captured.  Scheme/r apply only to coded strategies; batching to
        any.  The adjustment log records the post-adjustment knobs, and the
        threads engine records the identical tuples — the differential
        battery compares them verbatim.  ``live=False`` marks a trailing
        window (past the last arrival): record the decision and the final
        knobs but leave the serving pools alone — the threads engine only
        closes trailing windows at shutdown, after its workers have
        joined, so a trailing adjustment there can no longer batch or
        serve anything either."""
        if strat.coded and (adj.scheme is not None or adj.r is not None):
            name = adj.scheme if adj.scheme is not None \
                else cur["schm"].name
            want_r = adj.r if adj.r is not None else cur["r"]
            if name == base_schm.name and want_r == base_r:
                # de-escalation: restore the deployment's own scheme
                # instance (never a fresh registry default under the same
                # name), re-enabling identity-routing to the trained pools
                new = base_schm
            else:
                new = get_scheme(name, k=k, r=want_r, backend=backend)
                if not scheme_capabilities(new).model_agnostic:
                    raise ValueError(
                        f"controller adjustment to scheme {name!r} "
                        f"(r={new.r}) is not the deployment base and not "
                        f"model_agnostic — runtime escalation can only "
                        f"target schemes whose parity pool runs the "
                        f"deployed parameters")
                if new.r > agn_r:
                    raise ValueError(
                        f"controller adjustment needs r={new.r} "
                        f"escalation pools but only {agn_r} were "
                        f"provisioned — raise Controller.escalation_r")
            cur["schm"], cur["r"], cur["gk"] = new, new.r, new.k
            cur["enc_ms"] = cfg.encode_ms * encode_cost(new)
            cur["det"] = scheme_capabilities(new).detects_errors
        if adj.batch_max_size is not None:
            cur["batch_max"] = max(1, adj.batch_max_size)
            if live:
                pools["main"].batch_max = cur["batch_max"]
        adjust_log.append((widx,
                           cur["schm"].name if strat.coded else None,
                           cur["r"] if strat.coded else None,
                           cur["batch_max"]))

    if scen is None:
        # legacy background shuffles: a recurring process that slows random
        # instances, driven by the cfg.shuffle_* fields
        all_pools = list(pools.values())

        def schedule_shuffle(t0):
            if t0 > end_of_arrivals:      # stop background load after arrivals
                return
            dur = rng.uniform(*cfg.shuffle_ms)
            pool = all_pools[rng.integers(len(all_pools))]
            srv = rng.integers(pool.n)
            pool.slow_until[srv] = max(pool.slow_until[srv], t0 + dur)
            # next shuffle of this "tenant" after an idle gap
            push(t0 + dur + rng.uniform(*cfg.shuffle_gap_ms), "shuffle", None)

        for j in range(cfg.n_shuffles):
            schedule_shuffle(rng.uniform(0, 50.0))

    def dispatch(pool_name, now):
        pool = pools[pool_name]
        for s, items, fin in pool.try_dispatch(now):
            push(fin, "finish", (pool_name, s, items))

    def complete(qi, t, by=0):
        if not done[qi]:
            done[qi] = True
            latency[qi] = t - arrival_t[qi]
            how[qi] = by
            if ctl is not None:
                # ordered insert: completions are near-sorted (only a
                # future-dated decode can land behind later records, by at
                # most its decode latency), so the right-end bubble is a
                # few swaps at worst and window close below is one scan —
                # not the two full rebuilds per ctl event it used to be
                rec = (t, latency[qi], by)
                wrecs.append(rec)
                i = len(wrecs) - 1
                while i and wrecs[i - 1][0] > t:
                    wrecs[i] = wrecs[i - 1]
                    i -= 1
                wrecs[i] = rec

    def revote(g, t):
        """Joint Byzantine vote over group ``g``'s held responses — the DES
        mirror of ``ParMFrontend._screen``'s ``flag_errors`` call, re-run
        whenever a response touches the group (the frontend re-votes on
        every recorded arrival too, so an erroneous response accepted
        early, below the margin, is still caught once later responses
        provide the surplus).  All corrupt responses currently held are
        candidates together, evicted iff

            n_held  >=  k + 2 * n_candidates

        (``n_held`` counts every response the decoder holds, candidates
        included) — exactly the smallest-consistent-subset margin
        ``flag_errors`` enforces, including its abstention when two
        corruptions face only two surplus responses.  An evicted member
        already answered from a clean reconstruction counts corrected;
        one that answered its own query with the garbage is detected too
        late to help; one still unanswered stays missing for
        ``maybe_reconstruct`` (stashed so the end-of-run drain can serve
        the suspect output if no clean decode ever lands)."""
        cm = corrupt_members.get(g, ())
        cp = corrupt_parities.get(g, ())
        n_cand = len(cm) + len(cp)
        if not n_cand:
            return
        info = groups.get(g)
        if info is None:
            return      # group not assembled yet: no surplus can exist
        mem = info["members"]
        n_held = int(member_resp[mem].sum()) + \
            int(np.isfinite(info["parity_t"]).sum())
        if n_held < len(mem) + 2 * n_cand:
            return
        corrupted["detected"] += n_cand
        for qi in cm:
            member_resp[qi] = False
            if done[qi]:
                if how[qi] == 1:
                    corrupted["corrected"] += 1
            else:
                corrupt_stash[qi] = t
        for j in cp:
            info["parity_t"][j] = np.inf
        corrupt_members.pop(g, None)
        corrupt_parities.pop(g, None)

    def reconstruct_plan(g):
        """Reconstruction decision for one group: the shared
        ``recoverable_rows`` rule over (members whose response the decoder
        does not hold, parities arrived) — the exact decision
        ``ParMFrontend._decode_plan`` takes (its miss rule is "no
        trustworthy response recorded", NOT "query unanswered": an SLO- or
        eviction-answered member without a held response has no data to
        decode with), so the two layers agree by construction.  Returns
        ``(info, rows)`` or None."""
        info = groups.get(g)
        if info is None:
            return None     # never-assembled (partial trailing) group: the
                            # runtime never encodes one, so no decode here
        mem = info["members"]
        miss = ~member_resp[mem]
        if not miss.any() or done[mem].all():
            return None
        parity_avail = np.isfinite(info["parity_t"])
        if not parity_avail.any():
            return None
        rows = recoverable_rows(info["schm"], miss, parity_avail)
        if not rows.any():
            return None
        return info, rows

    def apply_reconstruction(info, rows, t):
        """Complete every recoverable member of one planned group.  Decode
        time is charged per group through the scheme's ``decode_cost`` hint
        whether the group decodes alone or inside a batched drain — the
        multigroup kernel's win is a LAUNCH-count win, which the timing
        model does not resolve, so batched and per-group drains stay
        bit-equal."""
        ready = t + cfg.decode_ms * decode_cost(info["schm"],
                                                int(rows.sum()))
        mem = info["members"]
        for j in np.nonzero(rows)[0]:
            qi = int(mem[int(j)])
            complete(qi, max(ready, arrival_t[qi]), by=1)
            if info["det"] and qi in corrupt_stash:
                # a member whose own response was voted out as corrupted,
                # now served from a clean reconstruction instead
                corrupted["corrected"] += 1
                corrupt_stash.pop(qi)

    def maybe_reconstruct(g, t):
        """Single-group reconstruction (plan + apply in one step)."""
        plan = reconstruct_plan(g)
        if plan is not None:
            apply_reconstruction(plan[0], plan[1], t)

    def reconstruct_groups(gids, t):
        """Batch-decode drain: every group a finish event touched, decoded
        together.  Gathers ALL groups' stacked reconstruction plans first —
        the DES twin of the frontend's one-launch ``decode_one_many`` /
        ``decode_many`` drain — then completes each at its own
        ``decode_cost`` charge.  Groups are disjoint (a query belongs to one
        group), so gather-then-apply completes exactly what interleaved
        per-group calls would: ``_FORCE_DECODE="pergroup"`` pins that in the
        differential test."""
        if _FORCE_DECODE == "pergroup":
            for g in gids:
                maybe_reconstruct(g, t)
            return
        plans = [p for p in (reconstruct_plan(g) for g in gids)
                 if p is not None]
        for info, rows in plans:
            apply_reconstruction(info, rows, t)

    arr_list = arrivals.tolist()
    ai = 0
    INF = float("inf")
    next_arr = arr_list[0] if n else INF
    n_ev = 0
    while True:
        if events:
            h0 = events[0]
            take_arr = ai < n and (
                next_arr < h0[0]
                or (next_arr == h0[0] and n_windows + ai < h0[1]))
        elif ai < n:
            take_arr = True
        else:
            break
        n_ev += 1
        if take_arr:
            t = next_arr
            qi = ai
            ai += 1
            next_arr = arr_list[ai] if ai < n else INF
            tn = int(tenant_of[qi]) if classes else None
            for _ in range(strat.mirror):
                pools["main"].submit(("q", qi), tenant=tn)
            dispatch("main", t)
            if strat.coded:
                gid_of[qi] = next_gid
                pending.append(qi)
                if len(pending) == cur["gk"]:
                    # group complete -> capture the current knobs, encode +
                    # dispatch r parity queries, one per parity model
                    # (§3.5); encoding happens on the frontend, so model
                    # its cost (scheme-owned: free for identity "encodes")
                    # as added latency on each parity path
                    g = next_gid
                    next_gid += 1
                    groups[g] = {
                        "members": np.array(pending, dtype=int),
                        "schm": cur["schm"], "r": cur["r"],
                        "det": cur["det"],
                        "parity_t": np.full(cur["r"], np.inf)}
                    pending.clear()
                    # base-scheme groups go to the trained parity pools;
                    # escalated groups to the deployed-params escalation
                    # pools at offset agn_base (ParMFrontend routes by the
                    # same identity test)
                    ofs = 0 if cur["schm"] is base_schm else agn_base
                    for j in range(cur["r"]):
                        pools[f"parity{ofs + j}"].submit(("p", (g, j)))
                        dispatch(f"parity{ofs + j}", t + cur["enc_ms"])
                    if pending_adj is not None:
                        # a deferred adjustment lands exactly at this group
                        # boundary — the frontend's contract
                        adj, widx = pending_adj
                        pending_adj = None
                        apply_adjustment(adj, widx)
            if strat.slo_default:
                # Clipper baseline deadline; per-tenant classes may
                # tighten or loosen it relative to cfg.slo_ms
                deadline = slo_of[tn] if classes else cfg.slo_ms
                if deadline is not None:
                    push(t + deadline, "slo", qi)
            continue
        ev = heapq.heappop(events)
        t = ev[0]
        kind = ev[2]
        if kind == "finish":
            pool_name, s, items = ev[3]
            pool = pools[pool_name]
            pool.free.append(s)
            # Byzantine injection: responses computed inside a corrupt
            # window are erroneous (one flag per inference call — the
            # threaded runtime corrupts per call too)
            corrupt = pool.corrupts(s, t)
            # complete EVERY item of the batch before any reconstruction
            # decision — mirroring the runtime's batch-atomic completion: a
            # decode must never treat a batch-mate as missing when its exact
            # output arrived in the same inference call.  Corrupt member
            # responses (detecting scheme) defer completion until after the
            # vote: an immediately-evicted one must not answer its query
            # with garbage
            touched = []
            deferred = []
            for ikind, idx in items:
                if ikind == "q":
                    # detection follows the scheme the item's GROUP
                    # captured (a member finishing before its group
                    # assembles screens under the knobs it will assemble
                    # with — the current ones)
                    if strat.coded:
                        g = int(gid_of[idx])
                        ginfo = groups.get(g)
                        det = ginfo["det"] if ginfo is not None else \
                            cur["det"]
                    else:
                        det = False
                    if corrupt and det:
                        member_resp[idx] = True
                        corrupt_members.setdefault(g, set()).add(idx)
                        deferred.append(idx)
                        touched.append(g)
                        continue
                    complete(idx, t)
                    if strat.coded:
                        member_resp[idx] = True
                        touched.append(g)
                else:  # parity output (g, j)
                    g, j = idx
                    ginfo = groups[g]
                    ginfo["parity_t"][j] = min(ginfo["parity_t"][j], t)
                    if corrupt and ginfo["det"]:
                        corrupt_parities.setdefault(
                            int(g), set()).add(int(j))
                    touched.append(int(g))
            for g in dict.fromkeys(touched):
                revote(g, t)
            for qi in deferred:
                if not done[qi] and qi not in corrupt_stash:
                    # the vote abstained (no surplus yet): the garbage is
                    # accepted and served as if clean — silently wrong,
                    # exactly what a non-detecting scheme always does
                    complete(qi, t)
            reconstruct_groups(dict.fromkeys(touched), t)
            dispatch(pool_name, t)
        elif kind == "slo":
            # Clipper baseline: answer with the default prediction at the
            # SLO deadline if the real prediction hasn't arrived
            complete(ev[3], t, by=2)
        elif kind == "shuffle":
            schedule_shuffle(t)
        else:  # "ctl"
            # close observation window [t - wlen, t): completions are
            # bucketed by their completion TIMESTAMP (a decode recorded
            # just before the boundary may complete just after it — that
            # record belongs to the next window), counters by per-window
            # delta.  wrecs is kept sorted by completion time, so the
            # window's records are a prefix — one scan, not two rebuilds.
            # Adjustments apply immediately when no group is assembling,
            # else at the next group boundary
            widx = ev[3]
            cut = 0
            nrec = len(wrecs)
            while cut < nrec and wrecs[cut][0] < t:
                cut += 1
            take = wrecs[:cut]
            del wrecs[:cut]
            win = build_window(
                widx, t - wlen, t,
                [(lat, by == 1) for (_, lat, by) in take],
                corrupted_detected=corrupted["detected"]
                - wprev["detected"],
                cancellations=cancelled["q"] + cancelled["p"]
                - wprev["cancel"])
            wprev["detected"] = corrupted["detected"]
            wprev["cancel"] = cancelled["q"] + cancelled["p"]
            adj, ctl_state = ctl.observe(ctl_state, win)
            if adj is not None:
                # windows past the last arrival are trailing: the threads
                # engine closes them at shutdown (workers joined, pending
                # group flushed), so the decision is recorded but applies
                # log-only — no pool may change mid-drain
                live = t <= end_of_arrivals
                if live and pending:
                    pending_adj = (adj, widx)
                else:
                    apply_adjustment(adj, widx, live=live)

    # detected-but-uncorrectable responses: the decoder knows they are
    # erroneous but never held enough clean responses to re-decode, so the
    # system serves the suspect output it received, at its actual finish
    # time — the same immediate-fulfillment choice the threaded frontend
    # makes when a flagged member is not recoverable
    for qi, tf in corrupt_stash.items():
        complete(qi, tf)

    main = pools["main"]
    return _finalize_report(
        cfg, strat, cur, scen, ctl, n_windows, adjust_log, latency, how,
        cancelled["q"], cancelled["p"], main.n_calls, main.n_items,
        sum(p.n_items for name, p in pools.items()
            if name.startswith("parity")),
        corrupted["detected"], corrupted["corrected"], n_ev,
        tenant_of=tenant_of, classes=classes)
