"""Discrete-event simulator of the ParM serving cluster (paper §5).

Reproduces the paper's tail-latency methodology without EC2: Poisson query
arrivals, single-queue load balancing (optimal for mean response time, §5.1),
background *network-shuffle* load that transiently inflates the service time
of randomly chosen instance pairs (§5.1 "Background traffic"), and 100k-query
runs reporting median / p99 / p99.9.

Strategies are ``ResilienceStrategy`` objects from
``repro.serving.strategy`` — the SAME objects the threaded runtime consumes,
so the two serving layers cannot drift.  ``simulate(cfg, strategy)`` accepts
either an instance or a registered name (``parm``, ``equal_resources``,
``approx_backup``, ``replication``, ``default_slo``, ``none``); the strategy
owns pool layout (the paper's m + m/k apples-to-apples budget, §5.1), group
assembly and on-unavailability behavior, and a strategy registered from any
other file runs here untouched.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np

from repro.serving.strategy import get_strategy


@dataclass
class SimConfig:
    m: int = 12                     # deployed-model instances
    k: int = 2                      # coding-group size (redundancy 1/k)
    qps: float = 270.0
    n_queries: int = 100_000
    service_ms: float = 25.0        # mean inference time (ResNet-18 on K80)
    service_cv: float = 0.05        # coefficient of variation (lognormal)
    # background load: concurrent network shuffles, each congesting the
    # link of one randomly chosen instance for its duration; queries served
    # by a congested instance incur an additional transfer delay
    n_shuffles: int = 4
    shuffle_ms: tuple = (300.0, 700.0)   # duration ~ U[a, b]
    shuffle_gap_ms: tuple = (800.0, 2400.0)  # idle gap between shuffles
    shuffle_delay_ms: tuple = (10.0, 40.0)   # added per-query delay when slow
    shuffle_slowdown: float = 1.0        # optional multiplicative part
    encode_ms: float = 0.153        # paper §5.2.5 (k=3 median), in ms
    decode_ms: float = 0.014
    approx_speedup: float = 1.15    # §5.2.6, GPU cluster value
    slo_ms: float = 200.0           # default-prediction deadline (default_slo)
    batch_size: int = 1             # §5.2.3; batched service is sublinear
    batch_cost: float = 0.2         # service(b) = service * (1 + cost*(b-1));
                                    # GPUs batch well (paper scaled qps by the
                                    # observed throughput gain)
    seed: int = 0


@dataclass(order=True)
class _Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    payload: object = field(compare=False, default=None)


class _Pool:
    """Single-queue pool of n servers with per-server slowdown windows."""

    def __init__(self, n, rng, cfg, mean_ms):
        self.n = n
        self.free = list(range(n))
        self.queue = []
        self.rng = rng
        self.cfg = cfg
        self.mean = mean_ms
        self.slow_until = np.zeros(n)
        self.sigma = math.sqrt(math.log(1 + cfg.service_cv ** 2))
        self.mu = math.log(mean_ms) - self.sigma ** 2 / 2

    def service_time(self, server, now):
        base = self.rng.lognormal(self.mu, self.sigma)
        b = self.cfg.batch_size
        if b > 1:
            base *= 1.0 + self.cfg.batch_cost * (b - 1)
        if now < self.slow_until[server]:
            base = base * self.cfg.shuffle_slowdown + \
                self.rng.uniform(*self.cfg.shuffle_delay_ms)
        return base

    def submit(self, item):
        self.queue.append(item)

    def try_dispatch(self, now):
        """Returns list of (server, item, finish_time)."""
        out = []
        while self.free and self.queue:
            s = self.free.pop()
            item = self.queue.pop(0)
            out.append((s, item, now + self.service_time(s, now)))
        return out


def simulate(cfg: SimConfig, strategy="parm"):
    """Run the DES under a ``ResilienceStrategy`` (instance or registered
    name).  Returns dict with latency percentiles and bookkeeping."""
    strat = get_strategy(strategy)
    rng = np.random.default_rng(cfg.seed)
    k = cfg.k
    layout = strat.layout(cfg.m, k)
    pools = {"main": _Pool(layout.main, rng, cfg, cfg.service_ms)}
    if layout.parity:
        pools["parity"] = _Pool(layout.parity, rng, cfg, cfg.service_ms)
    if layout.backup:
        pools["backup"] = _Pool(layout.backup, rng, cfg,
                                cfg.service_ms / cfg.approx_speedup)

    # pre-draw arrivals
    arrivals = np.cumsum(rng.exponential(1000.0 / cfg.qps, cfg.n_queries))
    latency = np.full(cfg.n_queries, np.inf)
    arrival_t = arrivals.copy()
    done = np.zeros(cfg.n_queries, bool)

    # coding-group bookkeeping (coded strategies only)
    group_of = np.arange(cfg.n_queries) // k
    n_groups = (cfg.n_queries + k - 1) // k
    group_parity_t = np.full(n_groups, np.inf)      # parity output ready
    group_member_t = np.full((n_groups, k), np.inf)

    events = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, _Event(t, seq, kind, payload))
        seq += 1

    for i, t in enumerate(arrivals):
        push(t, "arrive", i)

    # background shuffles: a recurring process that slows random instances
    all_pools = list(pools.values())

    end_of_arrivals = arrivals[-1]

    def schedule_shuffle(t0):
        if t0 > end_of_arrivals:          # stop background load after arrivals
            return
        dur = rng.uniform(*cfg.shuffle_ms)
        pool = all_pools[rng.integers(len(all_pools))]
        srv = rng.integers(pool.n)
        pool.slow_until[srv] = max(pool.slow_until[srv], t0 + dur)
        # next shuffle of this "tenant" after an idle gap
        push(t0 + dur + rng.uniform(*cfg.shuffle_gap_ms), "shuffle", None)

    for j in range(cfg.n_shuffles):
        schedule_shuffle(rng.uniform(0, 50.0))

    def dispatch(pool_name, now):
        pool = pools[pool_name]
        for s, item, fin in pool.try_dispatch(now):
            push(fin, "finish", (pool_name, s, item))

    def complete(qi, t, reconstructed=False):
        if not done[qi]:
            done[qi] = True
            latency[qi] = t - arrival_t[qi]
            if reconstructed:
                nonlocal_counter[0] += 1

    def maybe_reconstruct(g, t):
        """When parity + (k-1) members are in, the straggler's prediction can
        be decoded; all group members are then completable."""
        mt = np.sort(group_member_t[g])
        if not np.isfinite(group_parity_t[g]) or not np.isfinite(mt[-2]):
            return
        ready = max(group_parity_t[g], mt[-2]) + cfg.decode_ms
        base = g * k
        for j in range(k):
            qi = base + j
            if qi < cfg.n_queries and not done[qi]:
                complete(qi, max(ready, arrival_t[qi]), reconstructed=True)

    nonlocal_counter = [0]

    while events:
        ev = heapq.heappop(events)
        t = ev.t
        if ev.kind == "arrive":
            qi = ev.payload
            for _ in range(strat.mirror):
                pools["main"].submit(("q", qi))
            dispatch("main", t)
            if strat.coded:
                g = group_of[qi]
                if (qi % k == k - 1) or qi == cfg.n_queries - 1:
                    # group complete -> encode + dispatch parity query
                    pools["parity"].submit(("p", g))
                    # encoding happens on the frontend; model the cost as
                    # added latency on the parity path
                    dispatch("parity", t + cfg.encode_ms)
            if strat.backup:
                pools["backup"].submit(("q", qi))
                dispatch("backup", t)
            if strat.slo_default:
                push(t + cfg.slo_ms, "slo", qi)
        elif ev.kind == "finish":
            pool_name, s, item = ev.payload
            pools[pool_name].free.append(s)
            kind, idx = item
            if kind == "q":
                complete(idx, t)
                if strat.coded:
                    g = group_of[idx]
                    group_member_t[g, idx - g * k] = min(
                        group_member_t[g, idx - g * k], t)
                    maybe_reconstruct(g, t)
            else:  # parity output
                group_parity_t[idx] = min(group_parity_t[idx], t)
                maybe_reconstruct(idx, t)
            dispatch(pool_name, t)
        elif ev.kind == "slo":
            # Clipper baseline: answer with the default prediction at the
            # SLO deadline if the real prediction hasn't arrived
            complete(ev.payload, t)
        elif ev.kind == "shuffle":
            schedule_shuffle(t)

    lat = latency[np.isfinite(latency)]
    assert len(lat) == cfg.n_queries, \
        f"unanswered queries: {cfg.n_queries - len(lat)}"
    return {
        "strategy": strat.name,
        "median_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "p999_ms": float(np.percentile(lat, 99.9)),
        "mean_ms": float(lat.mean()),
        "max_ms": float(lat.max()),
        "reconstructions": int(nonlocal_counter[0]),
    }
