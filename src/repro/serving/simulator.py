"""Discrete-event simulator of the ParM serving cluster (paper §5).

Reproduces the paper's tail-latency methodology without EC2: Poisson query
arrivals, single-queue load balancing (optimal for mean response time, §5.1),
background *network-shuffle* load that transiently inflates the service time
of randomly chosen instance pairs (§5.1 "Background traffic"), and 100k-query
runs reporting median / p99 / p99.9.

Strategies are ``ResilienceStrategy`` objects from
``repro.serving.strategy`` — the SAME objects the threaded runtime consumes,
so the two serving layers cannot drift.  ``simulate(cfg, strategy)`` accepts
either an instance or a registered name (``parm``, ``equal_resources``,
``approx_backup``, ``replication``, ``default_slo``, ``none``); the strategy
owns pool layout (the paper's m + m/k apples-to-apples budget, §5.1), group
assembly and on-unavailability behavior, and a strategy registered from any
other file runs here untouched.

Codes are ``CodingScheme`` objects resolved through ``get_scheme`` — again
the same objects ``ParMFrontend`` serves.  For a coded strategy the DES runs
one parity pool per parity model (r pools, paper §3.5), assembles coding
groups of ``scheme.k`` queries (a ``fixes_k`` scheme — approx_backup — owns
its group size; ``cfg.k`` stays the redundancy budget that sizes the pools),
and reconstruction follows the scheme's own recoverability rule via the
shared ``recoverable_rows`` (MDS all-or-nothing for linear codes: up to r
concurrent unavailabilities per group; per-row replica arrival for
replication and approximate backups), with encode/decode latency scaled by
the scheme's ``encode_cost`` / ``decode_cost`` hints.  A scheme marked
``approximate`` (the approx_backup scheme) runs its parity pool at
``cfg.approx_speedup`` times the deployed service rate — the §5.2.6
cheap-backup economics, now scheme-owned instead of a dedicated backup-pool
special case.

Fault injection beyond the built-in shuffle load comes from ``Scenario``
objects (``repro.serving.scenarios``): ``simulate(cfg, strategy,
scenario="crash")`` realizes the scenario's hazards — instance crash/restart,
correlated pool slowdowns, bursty MMPP arrivals, heterogeneous service rates
— into per-server slowdown windows.  With ``scenario=None`` the legacy
cfg-driven shuffle process runs unchanged.  The ``byzantine`` hazard family
(``CorruptOutputs``) is a different fault class: responses computed inside a
corrupt window are *erroneous* rather than late.  For a ``detects_errors``
scheme (approxifer) the DES re-runs a joint vote whenever a response
touches a group: all corrupt responses the group holds are evicted
together once ``n_held >= k + 2 * n_candidates`` (the classical 2e-surplus
error-correction margin, the same one the frontend's numeric
``flag_errors`` enforces) — caught in time, the affected query is served
from a clean reconstruction; caught late, the garbage was already served
and only the detection is recorded.  Counts surface as
``ServingReport.corrupted_detected`` / ``corrected``.  Schemes without
detection accept the garbage silently, with identical latency.

This module is the **sim engine** behind the declarative serving surface in
``repro.serving.api``: ``deploy(spec, engine="sim").replay(trace)`` builds a
``SimConfig`` from (spec, trace) and calls ``simulate``.  Two serving-policy
behaviors mirror the threaded runtime exactly:

* **adaptive batching** (``cfg.batch_max_size > 1``): the main pool dequeues
  up to that many waiting queries per free server and charges one service
  interval on the calibrated per-batch curve
  ``service * (1 + batch_cost * (b - 1))`` with the *actual* batch size b —
  so tail-latency studies can sweep ``BatchingPolicy`` settings.  (The
  legacy ``cfg.batch_size`` static multiplier is unchanged for old studies.)
* **redundant-work cancellation**: queued originals whose query already
  completed (a parity decode beat them, a mirror replica won, the SLO
  default fired) and queued parity queries whose whole group already
  finished are tombstoned — skipped at dequeue without occupying a server —
  and counted in ``ServingReport.cancelled_queries`` /
  ``cancelled_parities``, matching the runtime's dequeue-time semantics.
"""
from __future__ import annotations

import heapq
import math
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.scheme import (decode_cost, encode_cost, get_scheme,
                               recoverable_rows)
from repro.serving.controller import Adjustment, get_controller
from repro.serving.report import ServingReport, build_window
from repro.serving.scenarios import get_scenario
from repro.serving.strategy import get_strategy


@dataclass
class SimConfig:
    m: int = 12                     # deployed-model instances
    k: int = 2                      # coding-group size (redundancy 1/k)
    r: int = 1                      # parity models per group (paper §3.5);
                                    # schemes may fix their own (replication)
    qps: float = 270.0
    n_queries: int = 100_000
    service_ms: float = 25.0        # mean inference time (ResNet-18 on K80)
    service_cv: float = 0.05        # coefficient of variation (lognormal)
    # background load: concurrent network shuffles, each congesting the
    # link of one randomly chosen instance for its duration; queries served
    # by a congested instance incur an additional transfer delay
    n_shuffles: int = 4
    shuffle_ms: tuple = (300.0, 700.0)   # duration ~ U[a, b]
    shuffle_gap_ms: tuple = (800.0, 2400.0)  # idle gap between shuffles
    shuffle_delay_ms: tuple = (10.0, 40.0)   # added per-query delay when slow
    shuffle_slowdown: float = 1.0        # optional multiplicative part
    encode_ms: float = 0.153        # paper §5.2.5 (k=3 median), in ms
    decode_ms: float = 0.014        # one r=1 subtraction decode; multi-row
                                    # decodes pay scheme.decode_cost() times it
    approx_speedup: float = 1.15    # §5.2.6, GPU cluster value
    slo_ms: float = 200.0           # default-prediction deadline
                                    # (default_slo); None disables the
                                    # deadline, matching a threads-engine
                                    # deployment with no slo_ms set
    batch_size: int = 1             # §5.2.3 legacy static model: every
                                    # service interval is charged for a fixed
                                    # batch of this size
    batch_cost: float = 0.2         # service(b) = service * (1 + cost*(b-1));
                                    # GPUs batch well (paper scaled qps by the
                                    # observed throughput gain)
    batch_max_size: int = 1         # adaptive batching (BatchingPolicy
                                    # .max_size): main pool dequeues up to
                                    # this many queries per free server and
                                    # charges the per-batch curve at the
                                    # ACTUAL batch size
    seed: int = 0


@dataclass(order=True)
class _Event:
    t: float
    seq: int
    kind: str = field(compare=False)
    payload: object = field(compare=False, default=None)


class _Pool:
    """Single-queue pool of n servers with per-server slowdown windows.

    ``batch_max`` — adaptive batching: a free server takes up to this many
    queued items per dispatch (1 = no batching).  ``skip`` — redundant-work
    tombstone check applied at dequeue; skipped items never occupy a server.
    """

    def __init__(self, name, n, rng, cfg, mean_ms, batch_max=1, skip=None):
        self.name = name
        self.n = n
        self.free = list(range(n))
        self.queue = deque()
        self.rng = rng
        self.cfg = cfg
        self.mean = mean_ms
        self.batch_max = batch_max
        self.skip = skip
        self.n_calls = 0                # inference calls (batches) served
        self.n_items = 0                # queries those calls carried
        self.slow_until = np.zeros(n)
        self.plan = None                # FaultPlan from a Scenario, if any
        self.sigma = math.sqrt(math.log(1 + cfg.service_cv ** 2))
        self.mu = math.log(mean_ms) - self.sigma ** 2 / 2

    def service_time(self, server, now, b=1):
        base = self.rng.lognormal(self.mu, self.sigma)
        # batching curve: adaptive batching charges the ACTUAL batch size;
        # the legacy static model charges cfg.batch_size for every interval
        eff_b = b if self.batch_max > 1 else self.cfg.batch_size
        if eff_b > 1:
            base *= 1.0 + self.cfg.batch_cost * (eff_b - 1)
        if now < self.slow_until[server]:
            base = base * self.cfg.shuffle_slowdown + \
                self.rng.uniform(*self.cfg.shuffle_delay_ms)
        if self.plan is not None:
            base = self.plan.adjust_service_ms(self.name, server, now, base,
                                               self.rng)
        return base

    def submit(self, item):
        self.queue.append(item)

    def try_dispatch(self, now):
        """Returns list of (server, items, finish_time); ``items`` is the
        batch one server serves in one inference call."""
        out = []
        while self.free and self.queue:
            batch = []
            while self.queue and len(batch) < self.batch_max:
                item = self.queue.popleft()
                if self.skip is not None and self.skip(item):
                    continue            # tombstoned while queued
                batch.append(item)
            if not batch:
                break                   # queue drained by tombstones
            s = self.free.pop()
            self.n_calls += 1
            self.n_items += len(batch)
            out.append((s, batch,
                        now + self.service_time(s, now, len(batch))))
        return out


def simulate(cfg: SimConfig, strategy="parm", scheme=None, scenario=None,
             backend=None, controller=None):
    """Run the DES under a ``ResilienceStrategy`` (instance or registered
    name).  ``scheme`` (instance or name) overrides the strategy's default
    code for coded strategies; ``scenario`` (instance or name) overrides the
    built-in shuffle background load with a hazard set from
    ``repro.serving.scenarios``.  ``backend`` is validated through the same
    ``get_scheme`` resolution the threads engine applies — the DES runs no
    kernel math, but an identical spec must pass or fail identically on both
    engines.  ``controller`` (instance or registered name from
    ``repro.serving.controller``) closes the loop: every
    ``controller.window_ms`` of simulated time a ``ctl`` event builds a
    ``ReportWindow`` from the completions inside the window and applies any
    returned ``Adjustment`` at the next coding-group boundary — on this
    clock, as events, so the differential battery can assert identical
    decision sequences against the threads engine.  Returns a
    ``ServingReport`` (typed, dict-compatible) with latency percentiles and
    bookkeeping."""
    strat = get_strategy(strategy)
    rng = np.random.default_rng(cfg.seed)
    k = cfg.k                               # redundancy budget (pool sizing)
    parity_service_ms = cfg.service_ms
    # resolve the scheme UNCONDITIONALLY, exactly like ParMFrontend._build:
    # an invalid scheme/backend must fail identically on both engines even
    # under a non-coded strategy (which then simply never uses the code)
    want = scheme if scheme is not None else (strat.scheme or "sum")
    # cfg.r sizes registry-name schemes; an instance carries its own r
    # (mirrors ParMFrontend, which defaults r to the instance's value)
    resolved = get_scheme(want, k=k,
                          r=cfg.r if isinstance(want, str) else None,
                          backend=backend)
    # the CURRENT deployment knobs — mutable, because a controller may
    # retune them mid-run; new coding groups capture them at assembly
    cur = {"schm": None, "r": cfg.r, "gk": k, "enc_ms": cfg.encode_ms,
           "batch_max": max(1, cfg.batch_max_size)}
    if strat.coded:
        cur["schm"] = resolved
        cur["r"] = resolved.r               # a scheme may fix its own r
        cur["gk"] = resolved.k              # ... and its own group size
        cur["enc_ms"] = cfg.encode_ms * encode_cost(resolved)
        if getattr(resolved, "approximate", False):
            # approx_backup scheme: the parity pool runs cheap backup models
            parity_service_ms = cfg.service_ms / cfg.approx_speedup
    # the deployment's own resolved scheme OBJECT and r: controller
    # de-escalation restores this instance (not a fresh registry default
    # under the same name), and group dispatch routes by identity against
    # it — the same contract as ParMFrontend._base_scheme
    base_schm, base_r = cur["schm"], cur["r"]

    ctl = None
    if controller is not None:
        ctl = get_controller(controller)

    n = cfg.n_queries
    latency = np.full(n, np.inf)
    done = np.zeros(n, bool)
    how = np.zeros(n, np.int8)              # 0 model | 1 parity | 2 default
    cancelled = {"q": 0, "p": 0}
    # Byzantine bookkeeping (detects_errors schemes under corrupt-output
    # hazards): responses voted out, and affected predictions served clean
    corrupted = {"detected": 0, "corrected": 0}
    member_resp = np.zeros(n, bool)         # member responses the decoder
                                            # currently holds (clean, or
                                            # corrupt but not yet voted out)
    corrupt_members = {}                    # gid -> set of qi: corrupt member
                                            # responses held, not yet evicted
    corrupt_parities = {}                   # gid -> set of j: likewise
    corrupt_stash = {}                      # qi -> finish_t: voted-out member
                                            # responses whose query is still
                                            # unanswered

    # dynamic coding-group bookkeeping (coded strategies only): groups
    # assemble from consecutive arrivals and CAPTURE the scheme / r / error
    # detection active at assembly, so a controller adjustment applies at
    # the next group boundary without touching in-flight groups — the same
    # contract the threaded frontend honors.  Member availability is read
    # off ``done`` — a reconstructed member counts as available for the
    # next decode decision, exactly as in the runtime's _maybe_decode
    groups = {}      # gid -> {"members", "schm", "r", "det", "parity_t"}
    gid_of = {}      # qi -> gid, assigned at arrival
    pending = []     # members of the group currently assembling
    next_gid = 0

    def tombstoned(item):
        """Dequeue-time redundant-work cancellation — the DES mirror of the
        runtime's ``ParMFrontend._should_skip``: an original whose query
        already completed, or a parity query whose whole group did, is
        skipped without occupying a server."""
        kind, idx = item
        if kind == "q":
            if done[idx]:
                cancelled["q"] += 1
                return True
            return False
        if done[groups[idx[0]]["members"]].all():
            cancelled["p"] += 1
            return True
        return False

    # A controller may escalate at runtime: parity pools come in TWO
    # families, mirroring ParMFrontend._build.  Pools 0..base_r-1 are the
    # deployment's own parity pools; Controller.escalation_r extra pools
    # model workers running the *deployed* parameters (plain service time,
    # never the approx-backup speedup), and every adjustment that is not an
    # exact return to the base dispatches there.
    agn_base, agn_r = cur["r"], 0
    if ctl is not None and strat.coded:
        esc = getattr(ctl, "escalation_r", ctl.max_r)
        agn_r = max(0, int(esc(cur["r"])))
    r_pools = cur["r"] + agn_r
    layout = strat.layout(cfg.m, k, cur["r"])
    pools = {"main": _Pool("main", layout.main, rng, cfg, cfg.service_ms,
                           batch_max=cur["batch_max"],
                           skip=tombstoned)}
    if layout.parity:
        for j in range(r_pools):
            svc = parity_service_ms if j < cur["r"] else cfg.service_ms
            pools[f"parity{j}"] = _Pool(f"parity{j}", layout.parity, rng,
                                        cfg, svc,
                                        skip=tombstoned)

    # pre-draw arrivals (a scenario may replace Poisson with MMPP bursts)
    scen = None
    if scenario is None:
        scenario = strat.scenario
    arrivals = None
    if scenario is not None:
        scen = get_scenario(scenario)
        arrivals = scen.arrival_times(cfg, rng)
    if arrivals is None:
        arrivals = np.cumsum(rng.exponential(1000.0 / cfg.qps, n))
    arrival_t = arrivals.copy()

    events = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, _Event(t, seq, kind, payload))
        seq += 1

    end_of_arrivals = arrivals[-1]

    # closed-loop machinery: one "ctl" event per observation window whose
    # START precedes the end of arrivals (the threads engine closes the
    # same set: at submit time, plus trailing windows at shutdown).  Pushed
    # BEFORE the arrivals so a ctl event at time t sorts ahead of an
    # arrival at the same t — the frontend ticks its window clock at the
    # top of submit(), before recording the query
    adjust_log = []          # (window_index, scheme, r, batch_max_size)
    wrecs = []               # (t_done, latency, by) not yet windowed
    wprev = {"detected": 0, "cancel": 0}    # counter snapshots per window
    pending_adj = None       # (Adjustment, window_index) deferred to the
                             # next group boundary
    n_windows = 0
    ctl_state = None
    if ctl is not None:
        wlen = float(ctl.window_ms)
        n_windows = int(math.ceil(end_of_arrivals / wlen))
        for i in range(n_windows):
            push((i + 1) * wlen, "ctl", i)
        ctl_state = ctl.init(Adjustment(
            scheme=cur["schm"].name if strat.coded else None,
            r=cur["r"] if strat.coded else None,
            batch_max_size=cur["batch_max"]))

    def apply_adjustment(adj, widx, live=True):
        """Retune the CURRENT knobs; in-flight groups keep what they
        captured.  Scheme/r apply only to coded strategies; batching to
        any.  The adjustment log records the post-adjustment knobs, and the
        threads engine records the identical tuples — the differential
        battery compares them verbatim.  ``live=False`` marks a trailing
        window (past the last arrival): record the decision and the final
        knobs but leave the serving pools alone — the threads engine only
        closes trailing windows at shutdown, after its workers have
        joined, so a trailing adjustment there can no longer batch or
        serve anything either."""
        if strat.coded and (adj.scheme is not None or adj.r is not None):
            name = adj.scheme if adj.scheme is not None \
                else cur["schm"].name
            want_r = adj.r if adj.r is not None else cur["r"]
            if name == base_schm.name and want_r == base_r:
                # de-escalation: restore the deployment's own scheme
                # instance (never a fresh registry default under the same
                # name), re-enabling identity-routing to the trained pools
                new = base_schm
            else:
                new = get_scheme(name, k=k, r=want_r, backend=backend)
                if not getattr(new, "model_agnostic", False):
                    raise ValueError(
                        f"controller adjustment to scheme {name!r} "
                        f"(r={new.r}) is not the deployment base and not "
                        f"model_agnostic — runtime escalation can only "
                        f"target schemes whose parity pool runs the "
                        f"deployed parameters")
                if new.r > agn_r:
                    raise ValueError(
                        f"controller adjustment needs r={new.r} "
                        f"escalation pools but only {agn_r} were "
                        f"provisioned — raise Controller.escalation_r")
            cur["schm"], cur["r"], cur["gk"] = new, new.r, new.k
            cur["enc_ms"] = cfg.encode_ms * encode_cost(new)
        if adj.batch_max_size is not None:
            cur["batch_max"] = max(1, adj.batch_max_size)
            if live:
                pools["main"].batch_max = cur["batch_max"]
        adjust_log.append((widx,
                           cur["schm"].name if strat.coded else None,
                           cur["r"] if strat.coded else None,
                           cur["batch_max"]))

    for i, t in enumerate(arrivals):
        push(t, "arrive", i)

    if scen is not None:
        # scenario-owned hazards: realize crash/slowdown/heterogeneity
        # windows over the arrival horizon; the legacy shuffle process is off
        plan = scen.realize({name: p.n for name, p in pools.items()},
                            end_of_arrivals, rng)
        for p in pools.values():
            p.plan = plan
    else:
        # legacy background shuffles: a recurring process that slows random
        # instances, driven by the cfg.shuffle_* fields
        all_pools = list(pools.values())

        def schedule_shuffle(t0):
            if t0 > end_of_arrivals:      # stop background load after arrivals
                return
            dur = rng.uniform(*cfg.shuffle_ms)
            pool = all_pools[rng.integers(len(all_pools))]
            srv = rng.integers(pool.n)
            pool.slow_until[srv] = max(pool.slow_until[srv], t0 + dur)
            # next shuffle of this "tenant" after an idle gap
            push(t0 + dur + rng.uniform(*cfg.shuffle_gap_ms), "shuffle", None)

        for j in range(cfg.n_shuffles):
            schedule_shuffle(rng.uniform(0, 50.0))

    def dispatch(pool_name, now):
        pool = pools[pool_name]
        for s, items, fin in pool.try_dispatch(now):
            push(fin, "finish", (pool_name, s, items))

    def complete(qi, t, by=0):
        if not done[qi]:
            done[qi] = True
            latency[qi] = t - arrival_t[qi]
            how[qi] = by
            if ctl is not None:
                wrecs.append((t, latency[qi], by))

    def revote(g, t):
        """Joint Byzantine vote over group ``g``'s held responses — the DES
        mirror of ``ParMFrontend._screen``'s ``flag_errors`` call, re-run
        whenever a response touches the group (the frontend re-votes on
        every recorded arrival too, so an erroneous response accepted
        early, below the margin, is still caught once later responses
        provide the surplus).  All corrupt responses currently held are
        candidates together, evicted iff

            n_held  >=  k + 2 * n_candidates

        (``n_held`` counts every response the decoder holds, candidates
        included) — exactly the smallest-consistent-subset margin
        ``flag_errors`` enforces, including its abstention when two
        corruptions face only two surplus responses.  An evicted member
        already answered from a clean reconstruction counts corrected;
        one that answered its own query with the garbage is detected too
        late to help; one still unanswered stays missing for
        ``maybe_reconstruct`` (stashed so the end-of-run drain can serve
        the suspect output if no clean decode ever lands)."""
        cm = corrupt_members.get(g, ())
        cp = corrupt_parities.get(g, ())
        n_cand = len(cm) + len(cp)
        if not n_cand:
            return
        info = groups.get(g)
        if info is None:
            return      # group not assembled yet: no surplus can exist
        mem = info["members"]
        n_held = int(member_resp[mem].sum()) + \
            int(np.isfinite(info["parity_t"]).sum())
        if n_held < len(mem) + 2 * n_cand:
            return
        corrupted["detected"] += n_cand
        for qi in cm:
            member_resp[qi] = False
            if done[qi]:
                if how[qi] == 1:
                    corrupted["corrected"] += 1
            else:
                corrupt_stash[qi] = t
        for j in cp:
            info["parity_t"][j] = np.inf
        corrupt_members.pop(g, None)
        corrupt_parities.pop(g, None)

    def maybe_reconstruct(g, t):
        """Reconstruct every member the scheme can recover *right now*: the
        shared ``recoverable_rows`` rule over (members whose response the
        decoder does not hold, parities arrived) — the exact decision
        ``ParMFrontend._maybe_decode`` takes (its miss rule is "no
        trustworthy response recorded", NOT "query unanswered": an SLO- or
        eviction-answered member without a held response has no data to
        decode with), so the two layers agree by construction."""
        info = groups.get(g)
        if info is None:
            return          # never-assembled (partial trailing) group: the
                            # runtime never encodes one, so no decode here
        mem = info["members"]
        miss = ~member_resp[mem]
        if not miss.any() or done[mem].all():
            return
        parity_avail = np.isfinite(info["parity_t"])
        if not parity_avail.any():
            return
        rows = recoverable_rows(info["schm"], miss, parity_avail)
        if not rows.any():
            return
        ready = t + cfg.decode_ms * decode_cost(info["schm"],
                                                int(rows.sum()))
        for j in np.nonzero(rows)[0]:
            qi = int(mem[int(j)])
            complete(qi, max(ready, arrival_t[qi]), by=1)
            if info["det"] and qi in corrupt_stash:
                # a member whose own response was voted out as corrupted,
                # now served from a clean reconstruction instead
                corrupted["corrected"] += 1
                corrupt_stash.pop(qi)

    while events:
        ev = heapq.heappop(events)
        t = ev.t
        if ev.kind == "arrive":
            qi = ev.payload
            for _ in range(strat.mirror):
                pools["main"].submit(("q", qi))
            dispatch("main", t)
            if strat.coded:
                gid_of[qi] = next_gid
                pending.append(qi)
                if len(pending) == cur["gk"]:
                    # group complete -> capture the current knobs, encode +
                    # dispatch r parity queries, one per parity model
                    # (§3.5); encoding happens on the frontend, so model
                    # its cost (scheme-owned: free for identity "encodes")
                    # as added latency on each parity path
                    g = next_gid
                    next_gid += 1
                    groups[g] = {
                        "members": np.array(pending, dtype=int),
                        "schm": cur["schm"], "r": cur["r"],
                        "det": getattr(cur["schm"], "detects_errors",
                                       False),
                        "parity_t": np.full(cur["r"], np.inf)}
                    pending.clear()
                    # base-scheme groups go to the trained parity pools;
                    # escalated groups to the deployed-params escalation
                    # pools at offset agn_base (ParMFrontend routes by the
                    # same identity test)
                    ofs = 0 if cur["schm"] is base_schm else agn_base
                    for j in range(cur["r"]):
                        pools[f"parity{ofs + j}"].submit(("p", (g, j)))
                        dispatch(f"parity{ofs + j}", t + cur["enc_ms"])
                    if pending_adj is not None:
                        # a deferred adjustment lands exactly at this group
                        # boundary — the frontend's contract
                        adj, widx = pending_adj
                        pending_adj = None
                        apply_adjustment(adj, widx)
            if strat.slo_default and cfg.slo_ms is not None:
                push(t + cfg.slo_ms, "slo", qi)
        elif ev.kind == "finish":
            pool_name, s, items = ev.payload
            pool = pools[pool_name]
            pool.free.append(s)
            # Byzantine injection: responses computed inside a corrupt
            # window are erroneous (one flag per inference call — the
            # threaded runtime corrupts per call too)
            corrupt = pool.plan is not None and \
                pool.plan.corrupts(pool_name, s, t)
            # complete EVERY item of the batch before any reconstruction
            # decision — mirroring the runtime's batch-atomic completion: a
            # decode must never treat a batch-mate as missing when its exact
            # output arrived in the same inference call.  Corrupt member
            # responses (detecting scheme) defer completion until after the
            # vote: an immediately-evicted one must not answer its query
            # with garbage
            touched = []
            deferred = []
            for kind, idx in items:
                if kind == "q":
                    # detection follows the scheme the item's GROUP
                    # captured (a member finishing before its group
                    # assembles screens under the knobs it will assemble
                    # with — the current ones)
                    if strat.coded:
                        g = int(gid_of[idx])
                        ginfo = groups.get(g)
                        det = ginfo["det"] if ginfo is not None else \
                            getattr(cur["schm"], "detects_errors", False)
                    else:
                        det = False
                    if corrupt and det:
                        member_resp[idx] = True
                        corrupt_members.setdefault(g, set()).add(idx)
                        deferred.append(idx)
                        touched.append(g)
                        continue
                    complete(idx, t)
                    if strat.coded:
                        member_resp[idx] = True
                        touched.append(g)
                else:  # parity output (g, j)
                    g, j = idx
                    ginfo = groups[g]
                    ginfo["parity_t"][j] = min(ginfo["parity_t"][j], t)
                    if corrupt and ginfo["det"]:
                        corrupt_parities.setdefault(
                            int(g), set()).add(int(j))
                    touched.append(int(g))
            for g in dict.fromkeys(touched):
                revote(g, t)
            for qi in deferred:
                if not done[qi] and qi not in corrupt_stash:
                    # the vote abstained (no surplus yet): the garbage is
                    # accepted and served as if clean — silently wrong,
                    # exactly what a non-detecting scheme always does
                    complete(qi, t)
            for g in dict.fromkeys(touched):
                maybe_reconstruct(g, t)
            dispatch(pool_name, t)
        elif ev.kind == "slo":
            # Clipper baseline: answer with the default prediction at the
            # SLO deadline if the real prediction hasn't arrived
            complete(ev.payload, t, by=2)
        elif ev.kind == "shuffle":
            schedule_shuffle(t)
        elif ev.kind == "ctl":
            # close observation window [t - wlen, t): completions are
            # bucketed by their completion TIMESTAMP (a decode recorded
            # just before the boundary may complete just after it — that
            # record belongs to the next window), counters by per-window
            # delta.  Adjustments apply immediately when no group is
            # assembling, else at the next group boundary
            widx = ev.payload
            take = [rec for rec in wrecs if rec[0] < t]
            wrecs[:] = [rec for rec in wrecs if rec[0] >= t]
            win = build_window(
                widx, t - wlen, t,
                [(lat, by == 1) for (_, lat, by) in take],
                corrupted_detected=corrupted["detected"]
                - wprev["detected"],
                cancellations=cancelled["q"] + cancelled["p"]
                - wprev["cancel"])
            wprev["detected"] = corrupted["detected"]
            wprev["cancel"] = cancelled["q"] + cancelled["p"]
            adj, ctl_state = ctl.observe(ctl_state, win)
            if adj is not None:
                # windows past the last arrival are trailing: the threads
                # engine closes them at shutdown (workers joined, pending
                # group flushed), so the decision is recorded but applies
                # log-only — no pool may change mid-drain
                live = t <= end_of_arrivals
                if live and pending:
                    pending_adj = (adj, widx)
                else:
                    apply_adjustment(adj, widx, live=live)

    # detected-but-uncorrectable responses: the decoder knows they are
    # erroneous but never held enough clean responses to re-decode, so the
    # system serves the suspect output it received, at its actual finish
    # time — the same immediate-fulfillment choice the threaded frontend
    # makes when a flagged member is not recoverable
    for qi, tf in corrupt_stash.items():
        complete(qi, tf)

    lat = latency[np.isfinite(latency)]
    assert len(lat) == n, f"unanswered queries: {n - len(lat)}"
    by = {}
    for code, name in ((0, "model"), (1, "parity"), (2, "default")):
        c = int((how == code).sum())
        if c:
            by[name] = c
    main = pools["main"]
    return ServingReport(
        engine="sim",
        strategy=strat.name,
        # the report names the scheme the run ENDED on (post-adjustments)
        scheme=cur["schm"].name if strat.coded else None,
        scenario=scen.name if scen is not None else None,
        n=n,
        median_ms=float(np.percentile(lat, 50)),
        p99_ms=float(np.percentile(lat, 99)),
        p999_ms=float(np.percentile(lat, 99.9)),
        mean_ms=float(lat.mean()),
        max_ms=float(lat.max()),
        completed_by=by,
        reconstructions=int((how == 1).sum()),
        cancelled_queries=cancelled["q"],
        cancelled_parities=cancelled["p"],
        batches=main.n_calls,
        mean_batch_size=(main.n_items / main.n_calls) if main.n_calls
        else 1.0,
        corrupted_detected=corrupted["detected"],
        corrected=corrupted["corrected"],
        controller=ctl.name if ctl is not None else None,
        windows=n_windows,
        adjustments=tuple(adjust_log),
        parity_served=sum(p.n_items for name, p in pools.items()
                          if name.startswith("parity")))
