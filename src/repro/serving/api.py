"""Declarative serving API: ``DeploymentSpec`` in, ``Session`` out.

ParM is a framework *atop* a prediction-serving system (the paper deploys on
Clipper), so the user-facing serving surface matters as much as the codes.
This module is that surface — one frozen, declarative spec that BOTH serving
layers consume:

    spec = DeploymentSpec(fwd=fwd, params=params, parity_params=pp,
                          strategy="parm", scheme="sum", k=2, m=4,
                          batching=BatchingPolicy(max_size=4, max_delay_ms=2))

    with deploy(spec) as session:                    # engine="threads"
        fut = session.submit(x)                      # -> PredictionFuture
        y = fut.result(timeout=1.0)
        report = session.stats()                     # -> ServingReport

    report = deploy(spec, engine="sim").replay(Trace(n_queries=100_000,
                                                     qps=270.0))

The *same* spec drives the threaded runtime (``engine="threads"`` — real JAX
inference on worker threads) and the discrete-event simulator
(``engine="sim"`` — the paper's 100k-query tail-latency methodology).  The
deployment half of the configuration (model, strategy, scheme, pool budget
m/k/r, fault scenario, SLO, batching policy) lives in the spec; the sim-only
workload half (arrival process, query count, calibrated service times) lives
in a ``Trace``, so sweeping workloads never mutates the deployment and
sweeping deployments never re-describes the workload.

``ParMFrontend(...)`` and ``simulate(cfg, ...)`` keep working — the frontend
constructor folds its legacy kwarg surface into a ``DeploymentSpec`` (the
deprecated spellings warn), and ``simulate`` is exactly what
``SimSession.replay`` runs.  See DESIGN.md §8 for the authoring guide.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field, fields, replace
from typing import Any, Callable, Optional, Union

from repro.serving.report import ServingReport
from repro.serving.simulator import SimConfig, simulate

ENGINES = ("threads", "sim")


@dataclass(frozen=True)
class BatchingPolicy:
    """Clipper-style adaptive batching for the main pool.

    A worker serves up to ``max_size`` queued queries per inference call.
    Batches form *adaptively* from queue depth: an idle server takes
    whatever is waiting (at most ``max_size``) and never holds a lone query
    hostage.  ``max_delay_ms`` is a threads-engine refinement — after
    dequeuing one query a worker waits up to that long for the batch to
    fill; the DES models the size cap only (dequeue-time batching), so keep
    ``max_delay_ms = 0`` when comparing the two engines query-for-query.

    ``max_size = 1`` (the default) disables batching entirely.
    """

    max_size: int = 1
    max_delay_ms: float = 0.0

    def __post_init__(self):
        if self.max_size < 1:
            raise ValueError(f"max_size must be >= 1, got {self.max_size}")
        if self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0, got {self.max_delay_ms}")


@dataclass(frozen=True, eq=False)
class DeploymentSpec:
    """Frozen description of one coded-serving deployment.

    Consumed identically by ``deploy(spec, engine="threads")`` and
    ``deploy(spec, engine="sim")``.  ``fwd`` / ``params`` (and
    ``parity_params`` for coded strategies) are required by the threads
    engine and ignored by the DES, which simulates service times instead of
    running inference.

    ``strategy`` / ``scheme`` / ``scenario`` accept registered names or
    instances — the same registries ``ParMFrontend`` and ``simulate``
    resolve.  ``k`` is the redundancy budget (pool sizing); a ``fixes_k``
    scheme may own a different group size.  ``r`` is parity models per group
    (``None``: the scheme's own, default 1).
    """

    # model (threads engine; the DES simulates service instead)
    fwd: Optional[Callable] = None
    params: Any = None
    parity_params: Any = None
    parity_fwd: Optional[Callable] = None

    # resilience
    strategy: Union[str, Any] = "parm"
    scheme: Union[str, Any, None] = None
    backend: Optional[str] = None
    k: int = 2
    r: Optional[int] = None
    m: int = 4

    # serving policy
    batching: BatchingPolicy = field(default_factory=BatchingPolicy)
    slo_ms: Optional[float] = None
    default_prediction: Any = None
    # closed-loop retuning: a registered controller name or instance
    # (repro.serving.controller).  Both engines observe ReportWindow
    # snapshots every controller.window_ms and apply its Adjustments at
    # coding-group boundaries; None (the default) disables the loop
    controller: Union[str, Any, None] = None

    # fault injection.  ``scenario`` drives BOTH engines; the three knobs
    # below configure the threads engine's wall-clock fault-injection
    # adapter only — the DES realizes the same hazards from ``Trace.seed``
    # in simulated time (one seed for the whole replay, so seeded DES
    # baselines stay bit-stable)
    scenario: Any = None
    scenario_seed: int = 0
    scenario_time_scale: float = 1.0
    scenario_horizon_ms: float = 600_000.0

    # expert hooks (threads engine)
    delay_fn: Optional[Callable] = None
    encode_fn: Optional[Callable] = None
    decode_fn: Optional[Callable] = None

    def __post_init__(self):
        if self.k < 1 or self.m < 1:
            raise ValueError(f"k and m must be >= 1, got k={self.k} "
                             f"m={self.m}")
        if not isinstance(self.batching, BatchingPolicy):
            raise TypeError(
                f"batching must be a BatchingPolicy, got {self.batching!r}")

    def replace(self, **changes) -> "DeploymentSpec":
        """A changed copy (the spec itself is frozen)."""
        return replace(self, **changes)


@dataclass(frozen=True)
class Trace:
    """The sim-only workload half of a deployment: arrival process, query
    count and the calibrated service-time model the DES charges.  Replayed
    against a ``DeploymentSpec`` via ``deploy(spec, engine="sim")
    .replay(trace)``.  Field meanings match ``SimConfig``, and the defaults
    ARE ``SimConfig``'s — the calibration constants live in one place.
    ``seed`` drives every random draw of the replay, scenario hazards
    included (the spec's ``scenario_seed`` is a threads-engine knob)."""

    n_queries: int = SimConfig.n_queries
    qps: float = SimConfig.qps
    service_ms: float = SimConfig.service_ms
    service_cv: float = SimConfig.service_cv
    seed: int = SimConfig.seed
    n_shuffles: int = SimConfig.n_shuffles
    shuffle_ms: tuple = SimConfig.shuffle_ms
    shuffle_gap_ms: tuple = SimConfig.shuffle_gap_ms
    shuffle_delay_ms: tuple = SimConfig.shuffle_delay_ms
    shuffle_slowdown: float = SimConfig.shuffle_slowdown
    encode_ms: float = SimConfig.encode_ms
    decode_ms: float = SimConfig.decode_ms
    approx_speedup: float = SimConfig.approx_speedup
    batch_cost: float = SimConfig.batch_cost
    # multi-tenant mode: a tuple of TenantClass (repro.serving.scenarios)
    # tagging traffic with shares / WFQ weights / per-class SLOs; empty =
    # single-tenant.  DESIGN.md §11
    tenants: tuple = SimConfig.tenants
    # explicit arrival timestamps (ms).  Takes precedence over both the
    # Poisson default and any scenario arrival process — the trace-replay
    # fast lane when timestamps are already in hand (TraceArrivals is the
    # scenario-level spelling, with cycling)
    arrival_times_ms: Optional[tuple] = SimConfig.arrival_times_ms


class PredictionFuture:
    """Async handle for one submitted query: the result, how it completed
    (``model`` | ``parity`` | ``default`` | ``flushed``), its latency, and
    whether the SLO deadline was blown."""

    def __init__(self, query, slo_ms: Optional[float] = None):
        self._query = query
        self._slo_ms = slo_ms

    @property
    def qid(self):
        return self._query.qid

    def done(self) -> bool:
        return self._query.event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until the prediction is available (or raise TimeoutError)."""
        if not self._query.event.wait(timeout):
            raise TimeoutError(
                f"query {self._query.qid} unanswered after {timeout}s")
        return self._query.result

    @property
    def completed_by(self) -> str:
        return self._query.completed_by

    @property
    def latency_ms(self) -> float:
        return self._query.latency_ms

    @property
    def deadline_exceeded(self) -> bool:
        """True once the query finished past its SLO (or was answered with
        the default prediction *at* the deadline).  False while pending,
        for deployments without an SLO, and for shutdown-flushed queries —
        their finish time is a teardown artifact, not a latency (the same
        exclusion ``ServingReport`` applies)."""
        if not self.done() or self.completed_by == "flushed":
            return False
        if self.completed_by == "default":
            return True
        return self._slo_ms is not None and self.latency_ms > self._slo_ms

    def __repr__(self):
        # parenthesized: bare ``a or b if c else d`` parses as
        # ``a or (b if c else d)``, which printed a done-but-unattributed
        # future as its falsy completed_by instead of "pending"
        state = (self.completed_by or "pending") if self.done() else "pending"
        return f"PredictionFuture(qid={self.qid}, {state})"


class Session:
    """Base of both engines: context-managed shutdown + report access."""

    engine = ""

    def __init__(self, spec: DeploymentSpec):
        self.spec = spec

    def submit(self, x, qid=None) -> PredictionFuture:
        raise NotImplementedError

    def stats(self) -> ServingReport:
        raise NotImplementedError

    def shutdown(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False


class ThreadsSession(Session):
    """The threaded runtime behind the declarative surface: real JAX
    inference on ``ModelInstance`` worker threads, driven by the spec."""

    engine = "threads"

    def __init__(self, spec: DeploymentSpec):
        super().__init__(spec)
        if spec.fwd is None or spec.params is None:
            raise ValueError(
                "engine='threads' runs real inference: DeploymentSpec needs "
                "fwd= and params= (the sim engine does not)")
        from repro.serving.runtime import ParMFrontend
        self._frontend = ParMFrontend(spec=spec)
        self._next_qid = 0
        self._submitted = set()
        self._lock = threading.Lock()

    def submit(self, x, qid=None) -> PredictionFuture:
        """Submit one query batch; returns immediately with a future.

        ``qid`` defaults to an auto-assigned id; an explicit one must be
        fresh — reuse would overwrite the earlier query's bookkeeping and
        orphan its future, so it raises instead.  The auto counter always
        skips past explicit ids.  The id is *reserved* under the session
        lock (not merely checked), so concurrent submitters cannot race two
        queries onto one qid."""
        with self._lock:
            if qid is None:
                qid = self._next_qid
            elif qid in self._submitted:
                raise ValueError(f"qid {qid} was already submitted")
            self._submitted.add(qid)
            self._next_qid = max(self._next_qid, qid + 1)
        q = self._frontend.submit(qid, x)
        return PredictionFuture(q, slo_ms=self.spec.slo_ms)

    def wait_all(self, timeout: float = 60.0) -> bool:
        return self._frontend.wait_all(timeout=timeout)

    def stats(self) -> ServingReport:
        return self._frontend.stats()

    def shutdown(self):
        self._frontend.shutdown()

    @property
    def frontend(self):
        """Escape hatch to the underlying ``ParMFrontend``."""
        return self._frontend


class SimSession(Session):
    """The discrete-event simulator behind the declarative surface.

    The DES is trace-driven — workloads arrive as a whole (``replay``), not
    query-by-query — so ``submit`` raises and points at ``replay``.
    """

    engine = "sim"

    def __init__(self, spec: DeploymentSpec):
        super().__init__(spec)
        self._last: Optional[ServingReport] = None

    def replay(self, trace: Optional[Trace] = None,
               **overrides) -> ServingReport:
        """Run the spec's deployment against a workload trace.

        ``overrides`` are ``Trace`` field overrides for one-off replays:
        ``session.replay(qps=330)``.  All randomness — arrivals, service
        draws AND scenario hazard realization — derives from ``trace.seed``
        (the spec's ``scenario_seed`` configures only the threads engine's
        wall-clock adapter).
        """
        trace = replace(trace or Trace(), **overrides) if overrides \
            else (trace or Trace())
        spec = self.spec
        # every Trace field maps 1:1 onto its SimConfig namesake (the
        # schema-lock test pins names AND defaults), so a workload field
        # added to both can never be silently dropped here.  The splat is a
        # *shallow* field read — asdict() would recurse into TenantClass
        # entries and hand SimConfig plain dicts instead
        cfg = SimConfig(
            **{f.name: getattr(trace, f.name) for f in fields(trace)},
            m=spec.m, k=spec.k,
            r=1 if spec.r is None else spec.r,
            # None disables the deadline — exactly like the threads engine,
            # which arms no SLO timers without an explicit spec.slo_ms
            slo_ms=spec.slo_ms,
            batch_max_size=spec.batching.max_size)
        self._last = simulate(cfg, spec.strategy, scheme=spec.scheme,
                              scenario=spec.scenario, backend=spec.backend,
                              controller=spec.controller)
        return self._last

    def submit(self, x, qid=None) -> PredictionFuture:
        raise RuntimeError(
            "the sim engine is trace-driven: use "
            "deploy(spec, engine='sim').replay(Trace(...)); per-query "
            "submit() is the threads engine's surface")

    def stats(self) -> ServingReport:
        if self._last is None:
            raise RuntimeError("no replay has run yet — call "
                               "session.replay(Trace(...)) first")
        return self._last


def deploy(spec: DeploymentSpec, engine: str = "threads") -> Session:
    """Bring a ``DeploymentSpec`` up on one of the two serving engines.

    ``threads`` — the real runtime (``ParMFrontend`` worker threads);
    ``sim``     — the DES (``simulate``), reached through ``replay(trace)``.
    """
    if not isinstance(spec, DeploymentSpec):
        raise TypeError(f"deploy() takes a DeploymentSpec, got {spec!r}")
    if engine == "threads":
        return ThreadsSession(spec)
    if engine == "sim":
        return SimSession(spec)
    raise ValueError(f"unknown engine {engine!r}; one of {ENGINES}")


def deploy_lm(spec, engine: str = "threads"):
    """Generation sibling of ``deploy``: takes a ``GenerationSpec`` and
    returns a coded LM serving session (token-level continuous batching,
    per-step parity reconstruction — ``repro.serving.generation``).  Lazy
    import so one-shot deployments never pay for the generation stack."""
    from repro.serving.generation import deploy_lm as _deploy_lm
    return _deploy_lm(spec, engine)
