"""Injectable fault scenarios shared by BOTH serving layers.

A ``Scenario`` composes ``Hazard`` objects — instance crash/restart,
correlated whole-pool slowdowns, the paper's §5.1 background network
shuffles, bursty (Markov-modulated Poisson) arrivals, heterogeneous
per-server service rates — into one declarative object that

* the discrete-event simulator consumes natively
  (``simulate(cfg, strategy, scenario=...)`` realizes the hazards into a
  ``FaultPlan`` of per-pool/per-server slowdown windows), and
* the threaded runtime consumes through a fault-injecting ``delay_fn``
  adapter (``ParMFrontend(..., scenario=...)``), which maps worker instance
  ids onto the same (pool, server) coordinates and sleeps through the same
  windows in wall-clock time.

Because one object drives both layers, a hazard added here is immediately
runnable end-to-end through every registered (strategy x scheme) pair —
the same anti-drift contract the strategy/scheme registries provide
(DESIGN.md §6).

Scenarios are registered like schemes and strategies::

    register_scenario(Scenario("flaky", (InstanceCrash(), NetworkShuffles())))
    simulate(cfg, "parm", scenario="flaky")
    ParMFrontend(..., scenario="flaky")
    DeploymentSpec(..., scenario="flaky")      # either engine, via deploy()

Built-ins: ``calm``, ``shuffle``, ``crash``, ``correlated_slowdown``,
``bursty``, ``hetero``, ``byzantine`` (erroneous/corrupted responses —
the ``CorruptOutputs`` hazard family), ``storm`` (everything at once),
``diurnal`` (sinusoidal nonhomogeneous Poisson arrivals), ``flash_crowd``
(exponentially-decaying rate spikes).  Arrival processes can also replay
explicit timestamp traces (``TraceArrivals``), and ``TenantClass`` tags
traffic with per-tenant shares / WFQ weights / SLOs for the simulator's
multi-tenant mode (DESIGN.md §11).

The ``byzantine`` family is a different fault *class* from the rest: a
corrupt window does not (only) delay a response, it makes the response
**wrong**.  The DES flags such responses natively (``FaultPlan.corrupts``)
and lets a ``detects_errors`` coding scheme (approxifer) vote them out;
the threaded runtime injects real numerical corruption through the
``corrupt_fn`` adapter — the same window set the DES realizes — and the
frontend's decode path does the voting on actual outputs.  Corrupted
responses from the injector are garbage at ``CORRUPTION_SCALE``, matching
ApproxIFER's adversarial model (gross errors, not subtle bias).

All hazard times are in simulator milliseconds; the runtime adapter converts
them to wall-clock seconds via ``time_scale`` (1.0 = one sim-ms per real ms).
Multiplicative slowdowns apply only in the DES — the runtime runs real
inference, whose duration the adapter cannot scale, so it injects the
additive part (transfer delays, crash downtime) only.
"""
from __future__ import annotations

import random as _random
import time
from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

# Worker instance-id convention shared with ``repro.serving.runtime``:
# main pool workers are 0..m-1, parity-queue j workers live at
# 1000 + 100*j + i, backup workers at 2000 + i.
MAIN_BASE = 0
PARITY_BASE = 1000
PARITY_STRIDE = 100
BACKUP_BASE = 2000

# What a Byzantine response is corrupted TO by the threaded runtime's fault
# injector: garbage at a scale far above any real model output, far above
# the approxifer decoder's voting tolerance (``err_tol``), so detection
# exercises the gross-error adversarial model rather than hinging on
# interpolation slack.
CORRUPTION_SCALE = 1.0e3


_MAX_PARITY_POOLS = (BACKUP_BASE - PARITY_BASE) // PARITY_STRIDE


def instance_id(pool: str, server: int) -> int:
    """(pool name, server index) -> the runtime's worker instance id.

    The encoding has finite ranges (main < 1000, parity pools of up to 100
    servers, at most 10 parity pools); out-of-range coordinates raise rather
    than silently collide with another pool's ids."""
    if pool == "main":
        if not 0 <= server < PARITY_BASE - MAIN_BASE:
            raise ValueError(f"main server index out of range: {server}")
        return MAIN_BASE + server
    if pool == "backup":
        if server < 0:
            raise ValueError(f"backup server index out of range: {server}")
        return BACKUP_BASE + server
    if pool.startswith("parity"):
        j = int(pool[len("parity"):] or 0)
        if not 0 <= j < _MAX_PARITY_POOLS:
            raise ValueError(
                f"at most {_MAX_PARITY_POOLS} parity pools encodable, "
                f"got pool {pool!r}")
        if not 0 <= server < PARITY_STRIDE:
            raise ValueError(
                f"at most {PARITY_STRIDE} servers per parity pool "
                f"encodable, got server {server}")
        return PARITY_BASE + PARITY_STRIDE * j + server
    raise ValueError(f"unknown pool {pool!r}")


def pool_of_iid(iid: int) -> Tuple[str, int]:
    """Inverse of ``instance_id``."""
    if iid >= BACKUP_BASE:
        return "backup", iid - BACKUP_BASE
    if iid >= PARITY_BASE:
        off = iid - PARITY_BASE
        return f"parity{off // PARITY_STRIDE}", off % PARITY_STRIDE
    return "main", iid


@dataclass(frozen=True)
class Window:
    """One realized hazard interval on (pool, server).

    ``server == -1`` hits every server of the pool (correlated slowdown).
    ``until_restart`` models a crash: a query dispatched at ``now`` inside
    the window waits out the remaining downtime ``t1 - now`` before service
    starts. Otherwise service time becomes ``base * mult + U[add_lo, add_hi]``.
    ``corrupt`` marks a Byzantine window: responses computed inside it are
    erroneous (the delay knobs still apply — a failing node is typically
    slow AND wrong, which is also what gives a voting decoder the surplus
    of clean responses it needs).
    """
    pool: str
    server: int
    t0: float
    t1: float
    mult: float = 1.0
    add_lo: float = 0.0
    add_hi: float = 0.0
    until_restart: bool = False
    corrupt: bool = False


class FaultPlan:
    """Realized hazards: slowdown windows + static per-server rate
    multipliers, queryable by (pool, server, time).

    Windows are bucketed per (pool, server) — pool-wide windows under
    server -1 — each bucket holding parallel sorted ``t0``/``t1`` arrays:
    a lookup advances a per-bucket cursor past leading windows that ended
    before ``now`` (both consumers query with (near-)monotonic time — the
    DES pops events in time order, the runtime adapter passes wall-clock)
    and bisects the start-time array for the upper bound, so a lookup
    touches only the handful of windows straddling ``now`` instead of
    rescanning — or slice-copying — the bucket's tail."""

    def __init__(self, windows: List[Window],
                 rates: Dict[Tuple[str, int], float]):
        self._wins: Dict[Tuple[str, int], List[Window]] = {}
        for w in windows:
            self._wins.setdefault((w.pool, w.server), []).append(w)
        self._t0s: Dict[Tuple[str, int], List[float]] = {}
        self._t1s: Dict[Tuple[str, int], List[float]] = {}
        for key, ws in self._wins.items():
            ws.sort(key=lambda w: w.t0)
            self._t0s[key] = [w.t0 for w in ws]
            self._t1s[key] = [w.t1 for w in ws]
        self._cursor = {key: 0 for key in self._wins}
        self.rates = rates
        self.n_windows = len(windows)
        self.n_corrupt = sum(1 for w in windows if w.corrupt)
        self._pools = (frozenset(p for p, _ in self._wins)
                       | frozenset(p for p, _ in rates))

    def relevant(self, pool: str) -> bool:
        """Hot-path gate: does this plan ever touch ``pool`` (any window or
        rate multiplier, at any time)?  A False answer lets the DES skip
        the per-dispatch ``adjust_service_ms`` call entirely — on calm or
        narrowly-targeted scenarios that is every dispatch."""
        return pool in self._pools

    def _active(self, pool, server, now):
        for key in ((pool, server), (pool, -1)):
            ws = self._wins.get(key)
            if not ws:
                continue
            t1s = self._t1s[key]
            i = self._cursor[key]
            # drop leading windows that ended before ``now`` for good
            while i < len(ws) and t1s[i] <= now:
                i += 1
            self._cursor[key] = i
            for j in range(i, bisect_right(self._t0s[key], now, i)):
                if now < t1s[j]:
                    yield ws[j]

    def rate(self, pool, server) -> float:
        return self.rates.get((pool, server), 1.0) * \
            self.rates.get((pool, -1), 1.0)

    def adjust_service_ms(self, pool, server, now, base_ms, rng) -> float:
        """DES hook: service time of a query dispatched at ``now``."""
        base_ms *= self.rate(pool, server)
        for w in self._active(pool, server, now):
            if w.until_restart:
                base_ms += w.t1 - now
            else:
                base_ms = base_ms * w.mult + rng.uniform(w.add_lo, w.add_hi)
        return base_ms

    def injected_delay_ms(self, pool, server, now, rng) -> float:
        """Runtime hook: additive delay only (real inference can't be
        scaled), crash downtime included."""
        extra = 0.0
        for w in self._active(pool, server, now):
            if w.until_restart:
                extra += w.t1 - now
            else:
                extra += rng.uniform(w.add_lo, w.add_hi)
        return extra

    def corrupts(self, pool, server, now) -> bool:
        """Byzantine hook, both engines: is a corrupt window active on
        (pool, server) at ``now`` — i.e. is a response computed now
        erroneous?  (Delay injection for these windows flows through the
        two hooks above like any other window.)"""
        return any(w.corrupt for w in self._active(pool, server, now))


def _recurring(rng, horizon_ms, first, dur_rng, gap_rng):
    """Yield (t0, t1) windows of a recurring on/off process until horizon."""
    t = first
    while t <= horizon_ms:
        dur = rng.uniform(*dur_rng)
        yield t, t + dur
        t += dur + rng.uniform(*gap_rng)


def _target_pools(pool: str, pool_sizes: Dict[str, int]) -> List[str]:
    if pool == "*":
        return sorted(pool_sizes)
    if pool == "parity*":
        return sorted(p for p in pool_sizes if p.startswith("parity"))
    if pool not in pool_sizes:
        return []
    return [pool]


@dataclass(frozen=True)
class NetworkShuffles:
    """§5.1 background traffic: each of ``n_tenants`` repeatedly congests
    the link of one randomly chosen instance; queries it serves meanwhile
    pay an extra transfer delay."""
    n_tenants: int = 4
    duration_ms: tuple = (300.0, 700.0)
    gap_ms: tuple = (800.0, 2400.0)
    delay_ms: tuple = (10.0, 40.0)
    slowdown: float = 1.0

    def realize(self, pool_sizes, horizon_ms, rng):
        windows = []
        pools = sorted(pool_sizes)
        for _ in range(self.n_tenants):
            for t0, t1 in _recurring(rng, horizon_ms, rng.uniform(0, 50.0),
                                     self.duration_ms, self.gap_ms):
                pool = pools[rng.integers(len(pools))]
                srv = int(rng.integers(pool_sizes[pool]))
                windows.append(Window(pool, srv, t0, t1, mult=self.slowdown,
                                      add_lo=self.delay_ms[0],
                                      add_hi=self.delay_ms[1]))
        return windows, {}


@dataclass(frozen=True)
class InstanceCrash:
    """Crash/restart process per server: exponential time-between-failures,
    uniform downtime. A query dispatched to a crashed server waits out the
    remaining downtime (the runtime adapter sleeps it)."""
    pool: str = "*"
    mtbf_ms: float = 20_000.0
    downtime_ms: tuple = (500.0, 2000.0)

    def realize(self, pool_sizes, horizon_ms, rng):
        windows = []
        for pool in _target_pools(self.pool, pool_sizes):
            for s in range(pool_sizes[pool]):
                t = rng.exponential(self.mtbf_ms)
                while t <= horizon_ms:
                    down = rng.uniform(*self.downtime_ms)
                    windows.append(Window(pool, s, t, t + down,
                                          until_restart=True))
                    t += down + rng.exponential(self.mtbf_ms)
        return windows, {}


@dataclass(frozen=True)
class CorrelatedSlowdown:
    """Recurring slowdowns that hit an entire pool at once (shared switch,
    co-located noisy neighbor) — the failure mode replication-style schemes
    are most sensitive to."""
    pool: str = "*"                   # "*" = a random pool per event
    duration_ms: tuple = (400.0, 900.0)
    gap_ms: tuple = (1500.0, 4000.0)
    delay_ms: tuple = (15.0, 50.0)
    slowdown: float = 1.0

    def realize(self, pool_sizes, horizon_ms, rng):
        windows = []
        pools = _target_pools(self.pool, pool_sizes)
        if not pools:
            return [], {}
        for t0, t1 in _recurring(rng, horizon_ms, rng.uniform(0, 100.0),
                                 self.duration_ms, self.gap_ms):
            pool = pools[rng.integers(len(pools))]
            windows.append(Window(pool, -1, t0, t1, mult=self.slowdown,
                                  add_lo=self.delay_ms[0],
                                  add_hi=self.delay_ms[1]))
        return windows, {}


@dataclass(frozen=True)
class HeterogeneousRates:
    """Static per-server service-rate spread (mixed hardware generations):
    each server's mean service time is scaled by lognormal(0, sigma)."""
    pool: str = "*"
    sigma: float = 0.15

    def realize(self, pool_sizes, horizon_ms, rng):
        rates = {}
        for pool in _target_pools(self.pool, pool_sizes):
            for s in range(pool_sizes[pool]):
                rates[(pool, s)] = float(np.exp(rng.normal(0.0, self.sigma)))
        return [], rates


@dataclass(frozen=True)
class DeterministicSlowdown:
    """Explicitly targeted slowdown windows — the building block of the
    differential tests, where both serving layers must see the *same*
    unavailability pattern."""
    targets: tuple                    # of (pool, server)
    add_ms: float = 1000.0
    t0: float = 0.0
    t1: float = float("inf")
    mult: float = 1.0

    def realize(self, pool_sizes, horizon_ms, rng):
        return [Window(pool, server, self.t0, self.t1, mult=self.mult,
                       add_lo=self.add_ms, add_hi=self.add_ms)
                for pool, server in self.targets], {}


@dataclass(frozen=True)
class CorruptOutputs:
    """Byzantine hazard: recurring per-server episodes during which every
    response the server computes is erroneous (silent data corruption, a
    wedged accelerator, an adversarial replica).  Episodes also add a
    transfer-scale delay — a failing node is slow as well as wrong — which
    is what lets a ``detects_errors`` scheme accumulate the surplus of
    clean responses it needs to vote the garbage out.

    Exponential time-between-episodes (``mtbe_ms``), uniform duration."""

    pool: str = "main"
    mtbe_ms: float = 6000.0
    duration_ms: tuple = (150.0, 450.0)
    delay_ms: tuple = (20.0, 60.0)

    def realize(self, pool_sizes, horizon_ms, rng):
        windows = []
        for pool in _target_pools(self.pool, pool_sizes):
            for s in range(pool_sizes[pool]):
                t = rng.exponential(self.mtbe_ms)
                while t <= horizon_ms:
                    dur = rng.uniform(*self.duration_ms)
                    windows.append(Window(pool, s, t, t + dur,
                                          add_lo=self.delay_ms[0],
                                          add_hi=self.delay_ms[1],
                                          corrupt=True))
                    t += dur + rng.exponential(self.mtbe_ms)
        return windows, {}


@dataclass(frozen=True)
class DeterministicCorruption:
    """Explicitly targeted Byzantine windows — the corrupt-output analogue
    of ``DeterministicSlowdown``, for tests where both serving layers must
    see the *same* erroneous responses."""

    targets: tuple                    # of (pool, server)
    t0: float = 0.0
    t1: float = float("inf")
    add_ms: float = 0.0

    def realize(self, pool_sizes, horizon_ms, rng):
        return [Window(pool, server, self.t0, self.t1,
                       add_lo=self.add_ms, add_hi=self.add_ms, corrupt=True)
                for pool, server in self.targets], {}


@dataclass(frozen=True)
class DeterministicArrivals:
    """Explicit arrival times — the arrival-process analogue of
    ``DeterministicSlowdown`` for differential tests: the DES reads these
    exact times off the scenario, and the threads-engine side of the test
    paces its ``submit`` calls to the same schedule, so both engines see
    one arrival pattern (and close identical controller windows)."""

    times_ms: tuple

    def realize(self, pool_sizes, horizon_ms, rng):
        return [], {}

    def arrival_times(self, cfg, rng):
        if cfg.n_queries > len(self.times_ms):
            raise ValueError(
                f"DeterministicArrivals holds {len(self.times_ms)} arrival "
                f"times but the trace asks for {cfg.n_queries} queries")
        return np.asarray(self.times_ms[:cfg.n_queries], dtype=float)


@dataclass(frozen=True)
class BurstyArrivals:
    """Two-state Markov-modulated Poisson process (MMPP): calm periods at
    the configured qps, bursts at ``burst_mult`` times it."""
    burst_mult: float = 3.0
    calm_ms: tuple = (2000.0, 6000.0)
    burst_ms: tuple = (300.0, 1200.0)

    def realize(self, pool_sizes, horizon_ms, rng):
        return [], {}

    def arrival_times(self, cfg, rng):
        n = cfg.n_queries
        times = np.empty(n)
        i, t, burst = 0, 0.0, False
        while i < n:
            seg_end = t + rng.uniform(*(self.burst_ms if burst
                                        else self.calm_ms))
            rate = cfg.qps * (self.burst_mult if burst else 1.0)
            while i < n:
                nxt = t + rng.exponential(1000.0 / rate)
                if nxt > seg_end:
                    t = seg_end
                    break
                t = nxt
                times[i] = t
                i += 1
            burst = not burst
        return times


def _thinned_arrivals(n: int, peak_qps: float, accept_fn, rng) -> np.ndarray:
    """Nonhomogeneous Poisson process via chunked, vectorized thinning:
    candidate arrivals are drawn at the peak rate in blocks, then kept with
    probability ``rate(t) / peak`` (``accept_fn`` maps a time array to that
    ratio).  Returns the first ``n`` accepted times, sorted."""
    out = np.empty(n)
    have, t = 0, 0.0
    chunk = int(max(1024, min(4 * n, 1 << 16)))
    mean_gap = 1000.0 / peak_qps
    while have < n:
        cand = t + np.cumsum(rng.exponential(mean_gap, chunk))
        keep = cand[rng.random(chunk) < accept_fn(cand)]
        take = min(keep.size, n - have)
        out[have:have + take] = keep[:take]
        have += take
        t = cand[-1]
    return out


@dataclass(frozen=True)
class TraceArrivals:
    """Replay an explicit arrival-timestamp trace (production logs, a
    public cluster trace, a recorded incident).  If the trace holds fewer
    timestamps than the run asks for it is tiled cyclically: each replayed
    epoch is shifted by the trace span plus one mean inter-arrival gap, so
    the seam between epochs carries the trace's own average spacing rather
    than a zero-gap collision (set ``cycle=False`` to make a short trace a
    hard error instead)."""

    times_ms: tuple
    cycle: bool = True

    def realize(self, pool_sizes, horizon_ms, rng):
        return [], {}

    def arrival_times(self, cfg, rng):
        ts = np.asarray(self.times_ms, dtype=float)
        if ts.ndim != 1 or ts.size == 0:
            raise ValueError("TraceArrivals needs a non-empty 1-D trace")
        if ts.size > 1 and np.any(np.diff(ts) < 0):
            raise ValueError("TraceArrivals trace must be non-decreasing")
        n = cfg.n_queries
        if n <= ts.size:
            return ts[:n].copy()
        if not self.cycle:
            raise ValueError(
                f"TraceArrivals holds {ts.size} arrival times but the "
                f"trace asks for {n} queries (cycle=False)")
        gap = (ts[-1] - ts[0]) / max(ts.size - 1, 1)
        period = (ts[-1] - ts[0]) + max(gap, 1e-9)
        reps = -(-n // ts.size)
        base = ts - ts[0]
        out = np.concatenate([base + i * period for i in range(reps)])
        return out[:n] + ts[0]


@dataclass(frozen=True)
class DiurnalArrivals:
    """Sinusoidal day/night load: a nonhomogeneous Poisson process with
    ``rate(t) = qps * (1 + amplitude * sin(2*pi*t / period_ms))``, sampled
    by vectorized thinning.  ``cfg.qps`` stays the *mean* rate, so swapping
    ``calm`` for ``diurnal`` holds total offered load fixed while moving
    mass into the peaks — the regime where tail latency earns its keep."""

    period_ms: float = 60_000.0
    amplitude: float = 0.6

    def realize(self, pool_sizes, horizon_ms, rng):
        return [], {}

    def arrival_times(self, cfg, rng):
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError(
                f"DiurnalArrivals amplitude must be in [0, 1), "
                f"got {self.amplitude}")
        peak = cfg.qps * (1.0 + self.amplitude)
        two_pi = 2.0 * np.pi

        def accept(t):
            return (cfg.qps * (1.0 + self.amplitude
                               * np.sin(two_pi * t / self.period_ms))
                    / peak)

        return _thinned_arrivals(cfg.n_queries, peak, accept, rng)


@dataclass(frozen=True)
class FlashCrowd:
    """Flash-crowd arrivals: baseline Poisson at ``qps`` with a spike every
    ``every_ms`` that multiplies the instantaneous rate by ``spike_mult``
    and decays exponentially (time constant ``decay_ms``) — the
    retweet-storm / cache-expiry shape that overwhelms a pool far faster
    than any MMPP burst."""

    spike_mult: float = 8.0
    every_ms: float = 12_000.0
    decay_ms: float = 1_500.0

    def realize(self, pool_sizes, horizon_ms, rng):
        return [], {}

    def arrival_times(self, cfg, rng):
        if self.spike_mult < 1.0:
            raise ValueError(
                f"FlashCrowd spike_mult must be >= 1, got {self.spike_mult}")
        peak = cfg.qps * self.spike_mult
        excess = self.spike_mult - 1.0

        def accept(t):
            boost = excess * np.exp(-(t % self.every_ms) / self.decay_ms)
            return cfg.qps * (1.0 + boost) / peak

        return _thinned_arrivals(cfg.n_queries, peak, accept, rng)


@dataclass(frozen=True)
class TenantClass:
    """One tenant / SLO class for multi-tenant serving (DESIGN.md §11).

    ``share``  — relative fraction of arriving traffic; the simulator
    normalizes shares over all classes, so ``(3, 1)`` means 75%/25%.
    ``weight`` — weighted-fair-queueing weight at dequeue time: under
    contention a tenant with weight 2 drains twice as fast as weight 1.
    ``slo_ms`` — per-class latency SLO for the per-tenant violation
    breakdown; ``None`` inherits the trace-level ``slo_ms``.
    """

    name: str
    share: float = 1.0
    weight: float = 1.0
    slo_ms: Optional[float] = None

    def __post_init__(self):
        if self.share <= 0.0:
            raise ValueError(f"tenant {self.name!r}: share must be > 0")
        if self.weight <= 0.0:
            raise ValueError(f"tenant {self.name!r}: weight must be > 0")
        if self.slo_ms is not None and self.slo_ms <= 0.0:
            raise ValueError(f"tenant {self.name!r}: slo_ms must be > 0 "
                             f"(or None to inherit the trace-level SLO)")


@dataclass(frozen=True)
class Scenario:
    """A named, composable set of hazards consumed by both serving layers."""

    name: str
    hazards: tuple = field(default_factory=tuple)

    def arrival_times(self, cfg, rng):
        """Arrival process override, or None for the default Poisson."""
        for h in self.hazards:
            fn = getattr(h, "arrival_times", None)
            if fn is not None:
                return fn(cfg, rng)
        return None

    def realize(self, pool_sizes: Dict[str, int], horizon_ms: float,
                rng) -> FaultPlan:
        windows, rates = [], {}
        for h in self.hazards:
            w, rt = h.realize(pool_sizes, horizon_ms, rng)
            windows.extend(w)
            rates.update(rt)
        return FaultPlan(windows, rates)

    def adapters(self, pool_sizes: Dict[str, int], *, seed: int = 0,
                 horizon_ms: float = 600_000.0, time_scale: float = 1.0,
                 extra=None):
        """Both threaded-runtime fault adapters off ONE realized plan and
        one wall-clock origin: ``(delay_fn, corrupt_fn)``.

        ``delay_fn(iid) -> seconds`` maps each worker's instance id to its
        (pool, server) window set by wall-clock time; ``extra`` composes
        with a user-provided delay_fn (delays add).  ``random.Random`` is
        used for per-query jitter — its single-call draws are safe under
        CPython's GIL for concurrent workers.

        ``corrupt_fn(iid) -> bool`` is the Byzantine twin: True while a
        corrupt window is active on the worker's (pool, server), reading
        the SAME windows by the SAME clock (a separately-realized plan
        would skew the two adapters by their setup gap).  It is ``None``
        when the plan holds no corrupt windows, so frontends skip wiring
        the output-corruption path — and its screening — entirely."""
        plan = self.realize(pool_sizes, horizon_ms,
                            np.random.default_rng(seed))
        jitter = _random.Random(seed + 1)
        origin = time.perf_counter()

        class _Jitter:                   # FaultPlan expects rng.uniform(a, b)
            uniform = staticmethod(jitter.uniform)

        def now_ms():
            return (time.perf_counter() - origin) * 1e3 / time_scale

        def delay(iid):
            pool, server = pool_of_iid(iid)
            d = plan.injected_delay_ms(pool, server, now_ms(), _Jitter)
            d_s = d * time_scale / 1e3
            if extra is not None:
                d_s += extra(iid)
            return d_s

        if plan.n_corrupt == 0:
            return delay, None

        def corrupt(iid):
            pool, server = pool_of_iid(iid)
            return plan.corrupts(pool, server, now_ms())

        return delay, corrupt

    def delay_fn(self, pool_sizes: Dict[str, int], *, seed: int = 0,
                 horizon_ms: float = 600_000.0, time_scale: float = 1.0,
                 extra=None):
        """The delay adapter alone (see ``adapters``).  There is
        deliberately no standalone corrupt-adapter helper: the two
        injectors must share one realized plan and one clock origin, so
        callers that want both go through ``adapters``."""
        return self.adapters(pool_sizes, seed=seed, horizon_ms=horizon_ms,
                             time_scale=time_scale, extra=extra)[0]


# --------------------------------------------------------------- registry ---
_SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(scenario: Scenario) -> Scenario:
    """Register a scenario instance under its ``name``."""
    _SCENARIOS[scenario.name] = scenario
    return scenario


def list_scenarios() -> list:
    """Introspection: registered scenario names, sorted.  Every listed name
    resolves via ``get_scenario(name)``."""
    return sorted(_SCENARIOS)


def available_scenarios():
    return list_scenarios()


def get_scenario(scenario: Union[str, Scenario]) -> Scenario:
    """Resolve a name (or pass an instance through)."""
    if isinstance(scenario, Scenario):
        return scenario
    if isinstance(scenario, str):
        if scenario not in _SCENARIOS:
            raise KeyError(
                f"unknown scenario {scenario!r}; registered: "
                f"{available_scenarios()}")
        return _SCENARIOS[scenario]
    raise TypeError(f"not a Scenario or registered name: {scenario!r}")


register_scenario(Scenario("calm"))
register_scenario(Scenario("shuffle", (NetworkShuffles(),)))
register_scenario(Scenario("crash", (InstanceCrash(),)))
register_scenario(Scenario("correlated_slowdown", (CorrelatedSlowdown(),)))
register_scenario(Scenario("bursty", (BurstyArrivals(),
                                      NetworkShuffles(n_tenants=2))))
register_scenario(Scenario("hetero", (HeterogeneousRates(),
                                      NetworkShuffles(n_tenants=2))))
register_scenario(Scenario("byzantine", (CorruptOutputs(),)))
register_scenario(Scenario("diurnal", (DiurnalArrivals(),)))
register_scenario(Scenario("flash_crowd", (FlashCrowd(),)))
register_scenario(Scenario("storm", (NetworkShuffles(),
                                     InstanceCrash(mtbf_ms=40_000.0),
                                     CorrelatedSlowdown(),
                                     BurstyArrivals(burst_mult=2.0))))
