"""Closed-loop adaptive-redundancy controllers for the serving stack.

ParM's evaluation (paper §7) fixes ``(scheme, k, r)`` at deploy time, but
real clusters alternate calm periods with bursts and correlated slowdowns.
ApproxIFER's runtime-adaptive decoding shows redundancy can change *without
retraining*: the ``approxifer`` scheme is ``model_agnostic`` (its parity
pool runs the deployed parameters) with a ``dynamic_arity`` decoder, so
escalating from r=1 to r=2 at runtime needs no new parity model — only the
control loop this module provides.

A ``Controller`` watches the sliding window of serving signals both engines
emit (``repro.serving.report.ReportWindow``: per-window p50/p999 and
straggler / corruption / cancellation rates) and answers each window with an
``Adjustment`` — or ``None`` to hold.  The engines apply adjustments at the
next coding-group boundary (threads) / as events on the simulation clock
(DES), so the differential battery can assert identical decision sequences
across engines.

The protocol is deliberately *functional*: a controller instance is frozen
and stateless, and its evolving memory lives in an opaque state value::

    state = controller.init(base)                  # base: the deployed knobs
    adjustment, state = controller.observe(state, window)   # every window

One instance can therefore drive both engines of a differential test (or
many concurrent replays) without cross-talk.  The full protocol:

``name``                — registry identity, surfaced in ``ServingReport``;
``window_ms``           — observation-window length in *scenario* time
                          (simulated ms in the DES; the threads engine
                          divides wall-clock by ``scenario_time_scale``);
``init(base)``          — initial state.  ``base`` is an ``Adjustment``
                          holding the deployment's own scheme/r/batching,
                          i.e. what "de-escalate" should return to;
``observe(state, w)``   — one closed ``ReportWindow`` in, ``(Adjustment |
                          None, new_state)`` out;
``max_r(base_r)``       — the largest ``r`` any adjustment may request;
``escalation_r(base_r)``— how many *deployed-params* parity pools the
                          engines must provision up front, beyond the
                          deployment's own ``parity_params`` pools.  Any
                          adjustment that is not an exact return to the
                          deployment base is dispatched to these pools,
                          whose workers run the deployed model — correct
                          exactly for a ``model_agnostic`` escalation
                          target like ``approxifer`` (the reason the
                          default escalation goes there rather than to a
                          trained parity model that does not exist at
                          runtime); the engines REJECT non-agnostic
                          escalation targets at adjustment time.  Return 0
                          for a controller that never leaves the base
                          (``static``), so its pool layout — and thus any
                          seeded hazard realization — is identical to a
                          controller-less deployment.  Optional: engines
                          fall back to ``max_r(base_r)`` (conservative)
                          when a controller does not define it.

Built-ins (``register_controller`` / ``get_controller``):

``static``       — the no-op baseline: observes, never adjusts;
``threshold``    — escalate-and-hold bang-bang: escalate to (``approxifer``,
                   r=2, batched) the moment a window is *hot*
                   (straggler/corruption rate or p999/p50 tail ratio above
                   threshold), drop back to the deployment base only after
                   ``down_windows`` consecutive genuinely *calm* windows;
``hysteresis``   — the same thresholds debounced in both directions:
                   ``up_windows`` consecutive hot windows to escalate and a
                   deeper calm streak to de-escalate, so a flapping signal
                   cannot make the deployment flap with it.

Controllers enumerate candidate actions through the registries'
introspection helpers (``list_schemes`` / ``list_strategies`` /
``list_scenarios``) — the threshold family validates its escalation target
against ``list_schemes()`` at construction, so a typo fails at deploy time,
not mid-run.  See DESIGN.md §10 for the authoring guide.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Tuple, Union

from repro.core.scheme import list_schemes
from repro.serving.report import ReportWindow


@dataclass(frozen=True)
class Adjustment:
    """One retuning action: every field is optional, ``None`` means "keep
    the current value".  For a non-coded strategy the engines apply only
    ``batch_max_size`` (there is no scheme or parity pool to retune)."""

    scheme: Optional[str] = None
    r: Optional[int] = None
    batch_max_size: Optional[int] = None

    def __post_init__(self):
        if self.r is not None and self.r < 1:
            raise ValueError(f"r must be >= 1, got {self.r}")
        if self.batch_max_size is not None and self.batch_max_size < 1:
            raise ValueError(
                f"batch_max_size must be >= 1, got {self.batch_max_size}")


@dataclass(frozen=True)
class _BangBangState:
    """Functional state of the threshold/hysteresis family: which mode the
    loop is in, the current hot/calm streaks, the deployment base the
    de-escalation returns to, and the calm-reference p50 (the running
    minimum of window medians — queueing can only raise a window's p50
    above the unloaded service time, so the minimum tracks the calm
    level)."""
    base: Adjustment
    mode: str = "base"              # "base" | "escalated"
    hot_streak: int = 0
    calm_streak: int = 0
    ref_p50: float = float("inf")


@dataclass(frozen=True)
class StaticController:
    """The no-op baseline: observes every window, never adjusts.  Exists so
    'controller overhead without actions' is a measurable point and so
    sweeps can treat 'no controller' as just another registered name."""

    window_ms: float = 1000.0
    name: str = "static"

    def init(self, base: Adjustment):
        return None

    def observe(self, state, window: ReportWindow):
        return None, state

    def max_r(self, base_r: int) -> int:
        return base_r

    def escalation_r(self, base_r: int) -> int:
        return 0        # never leaves the base: no extra pools, no RNG drift


@dataclass(frozen=True)
class ThresholdController:
    """Bang-bang controller: escalate on a *hot* window, return to the
    deployment base on a *calm* one.

    A window with completions is **hot** when any of: ``straggler_rate >=
    hot_straggler_rate`` (parity reconstructions are carrying load —
    originals are not arriving in time), ``corruption_rate >=
    hot_corruption_rate`` (Byzantine responses are being voted out), the
    scale-free tail ratio ``p999/p50 >= hot_tail_ratio`` (queueing is
    stretching the tail, the §5 congestion signature), or the window's p50
    sits ``hot_p50_mult`` times above the calm-reference p50 (see below).
    It is **calm** when every signal sits at or below its ``calm_*``
    threshold.  Windows in between — and empty windows, which carry no
    evidence — hold.

    The calm-reference p50 is the running minimum of window medians,
    carried in the functional state.  It exists because the tail ratio is
    scale-free and goes BLIND inside a saturated burst: once the queue
    backs up, every completion is slow, p50 rises with p999, and the ratio
    flattens back under the hot threshold — a fully saturated window can
    read as "calm" by ratio alone.  The absolute level signal (p50 at
    ``hot_p50_mult``x the unloaded median) catches exactly those windows,
    and the matching ``calm_p50_mult`` bound keeps a still-congested
    window from counting toward a de-escalation streak.

    The straggler thresholds are deliberately high (0.45): a reconstruction
    is counted whenever the *parity path* wins the completion race, and with
    an idle parity pool at small k that race is benignly won ~30% of the
    time even on a calm workload.  Thresholds below that benign race rate
    make every window read as hot; thresholds above it leave the straggler
    signal meaning what it should — a genuine main-pool outage (e.g. a
    crashed or frozen instance, where the rate approaches the fraction of
    groups touching the dead instance).  Congestion is instead caught by the
    tail ratio, which is scale-free and insensitive to the race rate.

    The asymmetric debounce (``up_windows=1``, ``down_windows=4``) encodes
    *escalate-and-hold*: react to the first hot window immediately, but only
    stand down after a sustained calm streak.  During alternating
    burst/calm regimes (``bursty``, ``storm``) a symmetric policy flaps —
    and every de-escalation pays one full un-coded burst onset, which is
    exactly the p999 the controller exists to cut.  ``calm_tail_ratio`` sits
    at 1.4 (tight: escalated-mode windows during turbulence score 1.5–2.2)
    so "calm" means genuinely quiet, not merely "the redundancy is working".

    Escalation dispatches ``(escalate_scheme, escalate_r,
    escalate_batch_max)``; the default target is ``approxifer`` because it
    is ``model_agnostic`` — its extra parity pool can run the deployed
    parameters, so r can rise at runtime without any retrained parity model
    — and ``detects_errors``, so the corruption signal is actionable too.
    De-escalation replays the ``base`` adjustment captured at ``init``.
    """

    window_ms: float = 1000.0
    hot_straggler_rate: float = 0.45
    hot_corruption_rate: float = 0.02
    hot_tail_ratio: float = 3.0
    hot_p50_mult: float = 3.0
    calm_straggler_rate: float = 0.45
    calm_corruption_rate: float = 0.0
    calm_tail_ratio: float = 1.4
    calm_p50_mult: float = 1.5
    escalate_scheme: Optional[str] = "approxifer"
    escalate_r: int = 2
    escalate_batch_max: int = 4
    up_windows: int = 1
    down_windows: int = 4
    name: str = "threshold"

    def __post_init__(self):
        if self.escalate_scheme is not None and \
                self.escalate_scheme not in list_schemes():
            raise ValueError(
                f"escalate_scheme {self.escalate_scheme!r} is not a "
                f"registered coding scheme; known: {list_schemes()}")
        if self.escalate_r < 1:
            raise ValueError(f"escalate_r must be >= 1, got "
                             f"{self.escalate_r}")
        if self.up_windows < 1 or self.down_windows < 1:
            raise ValueError("up_windows and down_windows must be >= 1")

    def max_r(self, base_r: int) -> int:
        return max(base_r, self.escalate_r)

    def escalation_r(self, base_r: int) -> int:
        # a "no-op escalation" (same scheme family, same r) would still be
        # dispatched to deployed-params pools; only skip provisioning when
        # the policy can never leave the base at all
        if self.escalate_scheme is None and self.escalate_r == base_r:
            return 0
        return self.escalate_r

    def init(self, base: Adjustment) -> _BangBangState:
        return _BangBangState(base=base)

    def _classify(self, w: ReportWindow,
                  ref_p50: float = float("inf")) -> Optional[str]:
        if w.n == 0:
            return None                 # no completions: no evidence
        tail = (w.p999_ms / w.p50_ms) if w.p50_ms > 0 else 1.0
        level = (w.p50_ms / ref_p50) if ref_p50 > 0 else 1.0
        if (w.straggler_rate >= self.hot_straggler_rate
                or w.corruption_rate >= self.hot_corruption_rate
                or tail >= self.hot_tail_ratio
                or level >= self.hot_p50_mult):
            return "hot"
        if (w.straggler_rate <= self.calm_straggler_rate
                and w.corruption_rate <= self.calm_corruption_rate
                and tail <= self.calm_tail_ratio
                and level <= self.calm_p50_mult):
            return "calm"
        return None

    def observe(self, state: _BangBangState, window: ReportWindow
                ) -> Tuple[Optional[Adjustment], _BangBangState]:
        ref = state.ref_p50
        if window.n > 0 and window.p50_ms == window.p50_ms:   # not NaN
            ref = min(ref, float(window.p50_ms))
        cls = self._classify(window, ref)
        hot = state.hot_streak + 1 if cls == "hot" else 0
        calm = state.calm_streak + 1 if cls == "calm" else 0
        if state.mode == "base" and hot >= self.up_windows:
            adj = Adjustment(
                scheme=self.escalate_scheme,
                r=self.escalate_r,
                batch_max_size=self.escalate_batch_max
                if self.escalate_batch_max > 1 else None)
            return adj, replace(state, mode="escalated",
                                hot_streak=0, calm_streak=0, ref_p50=ref)
        if state.mode == "escalated" and calm >= self.down_windows:
            return state.base, replace(state, mode="base",
                                       hot_streak=0, calm_streak=0,
                                       ref_p50=ref)
        return None, replace(state, hot_streak=hot, calm_streak=calm,
                             ref_p50=ref)


@dataclass(frozen=True)
class HysteresisController(ThresholdController):
    """The threshold policy debounced on the way *up* as well: two
    consecutive hot windows to escalate (a single noisy window cannot raise
    r) and a deeper calm streak to drop back.  Trades one window of
    reaction latency for immunity to spurious escalations."""

    up_windows: int = 2
    down_windows: int = 6
    name: str = "hysteresis"


# --------------------------------------------------------------- registry ---
_CONTROLLERS: Dict[str, Callable[..., object]] = {}


def register_controller(name: str, factory: Callable[..., object] = None,
                        *, override: bool = False):
    """Register a controller factory ``factory(**kw)`` under ``name``.
    Usable as a decorator, mirroring ``register_scheme``.  Registering a
    *different* factory under an existing name raises unless
    ``override=True`` (same-factory re-registration is a no-op, so module
    re-imports stay safe)."""
    def _register(f):
        if not override and _CONTROLLERS.get(name, f) is not f:
            raise ValueError(
                f"controller {name!r} is already registered; pass "
                f"override=True to replace it")
        _CONTROLLERS[name] = f
        return f
    if factory is None:
        return _register
    return _register(factory)


def list_controllers() -> list:
    """Introspection: registered controller names, sorted.  Every listed
    name resolves via ``get_controller(name)``."""
    return sorted(_CONTROLLERS)


def available_controllers():
    return list_controllers()


def get_controller(controller: Union[str, object], **kw):
    """Resolve ``controller`` to a controller object.

    * a controller *instance* passes through after a duck-type check of the
      protocol surface (``name`` / ``window_ms`` / ``init`` / ``observe`` /
      ``max_r``) — failing at deploy time beats an AttributeError out of an
      engine's window loop;
    * a string is looked up in the registry and instantiated with ``**kw``.
    """
    if not isinstance(controller, str):
        missing = [a for a in ("name", "window_ms", "init", "observe",
                               "max_r") if not hasattr(controller, a)]
        if missing:
            raise TypeError(
                f"not a Controller (missing {missing}) or registered "
                f"name: {controller!r}")
        return controller
    if controller not in _CONTROLLERS:
        raise KeyError(
            f"unknown controller {controller!r}; registered: "
            f"{list_controllers()}")
    return _CONTROLLERS[controller](**kw)


register_controller("static", StaticController)
register_controller("threshold", ThresholdController)
register_controller("hysteresis", HysteresisController)
