"""Coded autoregressive LM serving: token-level continuous batching with
per-step parity reconstruction.  DESIGN.md §13 is the authoring guide.

ParM codes one-shot queries; this module extends the same framework to
*generation*.  A ``GenerationSpec`` deploys k member instances plus r parity
instances of a decode-capable model (``prefill`` / ``decode_step`` /
``init_cache``).  Each member serves ``n_slots`` independent token streams
out of one fixed-shape KV-cache pool (continuous batching: streams join and
leave at token boundaries; the pool never reshapes, so resident streams are
never recompiled or perturbed).  The coding group is a *slot column*: slot s
of every member plus slot s of every parity instance.

Reconstruction semantics per decode step (the ``make_joint_parity_train_step``
LM substrate from PR 3, ApproxIFER's model-agnostic stance for the default
parity params):

* encode over input EMBEDDINGS — each step the parity stream consumes
  ``sum_i C[j,i] * embed(token_i)`` and advances its own KV cache;
* decode over LOGITS — a member that misses the per-step straggle deadline
  has its logits row recovered by the scheme's existing linear decoders from
  the parity logits and the on-time members' logits.

The recovered stream never stalls: the emitted token is the argmax of the
*reconstructed* logits, and because a decode step's cache update depends
only on its INPUT token (never on which logits won the race), the
straggler's still-running step repairs its own cache in the background —
its executor queue serializes the late step before the next one, so by the
time the next decode wants the cache it is exact.  That is the cache-repair
rule: repair-by-completion + canonical token feedback.

Scheduler states per stream: WAITING (queued) -> ADMITTED (prefill into a
free (member, slot), first token emitted from prefill logits, parity slot
column rebuilt from the encoded prompt) -> DECODING (one coded step per
token) -> FINISHED (future fulfilled, slot freed, parity column rebuilt for
the remaining occupants).

Engines:

* ``deploy_lm(spec, engine="threads")`` — real JAX inference on executor
  threads, wall-clock straggle deadlines, scenario delay adapters;
* ``deploy_lm(spec, engine="sim")``     — every decode step becomes one DES
  query at a service time calibrated from ``launch/roofline.py``
  (``decode_token_cost``), so 10M-token tail studies of the big configs
  (qwen3_moe_235b, jamba_1_5_large_398b, mamba2_780m) run on the
  simulator's fast path unchanged.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.scheme import get_scheme
from repro.serving.api import BatchingPolicy, DeploymentSpec, Trace, deploy
from repro.serving.report import ServingReport
from repro.serving.scenarios import get_scenario, instance_id

_SHUTDOWN = object()


# --------------------------------------------------------------------------
# Spec
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class GenerationSpec:
    """Frozen description of one coded LM deployment.

    ``cfg`` / ``params`` drive the default transformer substrate
    (``repro.models.transformer``); ``parity_params`` defaults to the
    deployed params (ApproxIFER-style model-agnostic parity — retraining a
    parity model per token position is a non-starter, and for linear
    substrates the deployed model already satisfies the code exactly).
    ``prefill_fn`` / ``decode_fn`` / ``embed_fn`` / ``init_cache_fn``
    override the substrate (tests inject exactly-linear stubs).

    The threads engine sizes its cache pools from
    ``batching.max_size`` (= slots per member) and ``max_seq_len``;
    ``straggle_ms`` is the per-step deadline after which a missing member
    row is reconstructed from parity.  ``m`` / ``utilization`` / ``kv_len``
    / ``tp`` calibrate the sim engine's token-level service model.
    """

    cfg: Any = None
    params: Any = None
    parity_params: Any = None            # None -> params (model-agnostic)
    scheme: Union[str, Any] = "sum"
    strategy: Union[str, Any] = "parm"   # sim engine strategy
    k: int = 2
    r: int = 1
    batching: BatchingPolicy = field(
        default_factory=lambda: BatchingPolicy(max_size=4))
    max_seq_len: int = 64
    max_new_tokens: int = 8
    straggle_ms: float = 200.0

    # fault injection (threads engine wall-clock adapters; the sim engine
    # realizes the same scenario hazards in simulated time)
    scenario: Any = None
    scenario_seed: int = 0
    scenario_time_scale: float = 1.0
    scenario_horizon_ms: float = 600_000.0
    delay_fn: Optional[Callable] = None  # iid -> seconds, composes

    # substrate overrides (tests / non-transformer models)
    prefill_fn: Optional[Callable] = None
    decode_fn: Optional[Callable] = None
    embed_fn: Optional[Callable] = None
    init_cache_fn: Optional[Callable] = None

    # distributed placement: a jax Mesh puts params on the inference layout
    # (distributed/sharding.py, fsdp_params=False — weights replicated over
    # the data axis, tensor-parallel over the model axis)
    mesh: Any = None

    # sim-engine calibration: m member streams at `utilization` of the
    # roofline decode-step service time for cfg at kv_len / tensor-parallel
    # degree tp
    m: int = 12
    utilization: float = 0.7
    kv_len: int = 4096
    tp: int = 1

    def __post_init__(self):
        if self.k < 1 or self.r < 1:
            raise ValueError(f"k and r must be >= 1, got k={self.k} "
                             f"r={self.r}")
        if not isinstance(self.batching, BatchingPolicy):
            raise TypeError(
                f"batching must be a BatchingPolicy, got {self.batching!r}")

    def replace(self, **changes) -> "GenerationSpec":
        return replace(self, **changes)


# --------------------------------------------------------------------------
# Futures and stream state
# --------------------------------------------------------------------------
class GenerationFuture:
    """Async handle for one generation request: the emitted token ids, how
    many steps were served from a parity reconstruction, and the per-token
    emission timestamps."""

    def __init__(self, rid):
        self.rid = rid
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._tokens: List[int] = []
        self._recon_steps = 0
        self._times: List[float] = []
        self.completed_by = None         # "model" | "flushed"

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> List[int]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} unfinished after {timeout}s")
        return list(self._tokens)

    @property
    def tokens_so_far(self) -> List[int]:
        with self._lock:
            return list(self._tokens)

    @property
    def reconstructed_steps(self) -> int:
        return self._recon_steps

    @property
    def inter_token_ms(self) -> List[float]:
        with self._lock:
            t = self._times
            return [1e3 * (b - a) for a, b in zip(t, t[1:])]

    def _emit(self, token, now, reconstructed):
        with self._lock:
            self._tokens.append(int(token))
            self._times.append(now)
            if reconstructed:
                self._recon_steps += 1

    def _finish(self, how="model"):
        self.completed_by = how
        self._event.set()

    def __repr__(self):
        state = (self.completed_by or "done") if self.done() else "pending"
        return f"GenerationFuture(rid={self.rid}, {state})"


class _Stream:
    """One admitted request living in (member, slot)."""

    __slots__ = ("rid", "prompt", "max_new", "pos", "next_token", "future",
                 "t_admit")

    def __init__(self, rid, prompt, max_new, future):
        self.rid = rid
        self.prompt = prompt             # list[int], inputs already consumed
        self.max_new = max_new
        self.pos = len(prompt)           # cache fill == next write position
        self.next_token = None           # canonical feedback token
        self.future = future
        self.t_admit = time.monotonic()

    @property
    def history(self):
        """All input tokens consumed so far (prompt + fed-back emissions)."""
        return self.prompt + self.future.tokens_so_far[:-1] \
            if self.future.tokens_so_far else self.prompt


class _Executor(threading.Thread):
    """One model instance: a worker thread draining a FIFO job queue.

    FIFO order IS the cache-repair rule: a straggling decode step finishes
    (and updates this instance's cache) before the next step dequeues."""

    def __init__(self, name):
        super().__init__(name=name, daemon=True)
        self.jobs = queue.Queue()

    def submit(self, fn):
        evt, out = threading.Event(), {}
        self.jobs.put((fn, evt, out))
        return evt, out

    def run(self):
        while True:
            job = self.jobs.get()
            if job is _SHUTDOWN:
                break
            fn, evt, out = job
            try:
                out["result"] = fn()
            except Exception as e:        # surfaced at collection time
                out["error"] = e
            evt.set()

    def stop(self):
        self.jobs.put(_SHUTDOWN)


# --------------------------------------------------------------------------
# Default substrate: repro.models.transformer
# --------------------------------------------------------------------------
def _transformer_fns(spec):
    from repro.models import transformer as T
    cfg = spec.cfg

    def prefill_fn(params, tokens=None, embeds=None, cache_len=0):
        return T.prefill(cfg, params, tokens=tokens, embeds=embeds,
                         cache_len=cache_len)

    decode_jit = jax.jit(
        lambda params, cache, pos, token: T.decode_step(
            cfg, params, cache, pos, token=token))
    decode_emb_jit = jax.jit(
        lambda params, cache, pos, embed: T.decode_step(
            cfg, params, cache, pos, embed=embed))

    def decode_fn(params, cache, pos, token=None, embed=None):
        if embed is not None:
            return decode_emb_jit(params, cache, pos, embed)
        return decode_jit(params, cache, pos, token)

    def embed_fn(params, tokens):
        return T.embed_tokens(cfg, params, jnp.asarray(tokens))

    def init_cache_fn(params, batch, cache_len):
        return T.init_cache(cfg, batch, cache_len)

    return prefill_fn, decode_fn, embed_fn, init_cache_fn


def _resolve_fns(spec):
    if spec.prefill_fn is not None:
        return (spec.prefill_fn, spec.decode_fn, spec.embed_fn,
                spec.init_cache_fn)
    if spec.cfg is None or spec.params is None:
        raise ValueError(
            "GenerationSpec needs cfg= and params= (or a full "
            "prefill_fn/decode_fn/embed_fn/init_cache_fn substrate)")
    return _transformer_fns(spec)


def place_inference_params(params, mesh):
    """Put a param tree on the inference layout of ``mesh``:
    ``ShardingRules(mesh, fsdp_params=False)`` — tensor-parallel over the
    model axis, replicated over the data axis (every member instance holds
    a full replica; see DESIGN.md §13)."""
    from repro.distributed.sharding import ShardingRules
    rules = ShardingRules(mesh, fsdp_params=False)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        params)
    shardings = rules.params(shapes)
    return jax.tree.map(jax.device_put, params, shardings)


# --------------------------------------------------------------------------
# Threads engine
# --------------------------------------------------------------------------
class GenerationSession:
    """Token-level continuous batching with per-step coded redundancy.

    ``submit(prompt)`` -> ``GenerationFuture``; ``stats()`` ->
    ``ServingReport`` whose completions are decode steps (so ``median_ms``
    etc. ARE inter-token latencies) plus the per-token fields
    (``tokens_per_s``, ``inter_token_p50/p999_ms``, ``reconstructed_steps``).
    """

    engine = "threads"

    def __init__(self, spec: GenerationSpec):
        self.spec = spec
        self.scheme = get_scheme(spec.scheme, k=spec.k, r=spec.r)
        self.coeffs = np.asarray(self.scheme.coeffs, np.float32)  # [r, k]
        fns = _resolve_fns(spec)
        self._prefill, self._decode, self._embed, self._init_cache = fns
        self.k, self.r = spec.k, spec.r
        self.n_slots = spec.batching.max_size
        self.max_seq = spec.max_seq_len

        params = spec.params
        pparams = spec.parity_params if spec.parity_params is not None \
            else params
        if spec.mesh is not None:
            params = place_inference_params(params, spec.mesh)
            pparams = place_inference_params(pparams, spec.mesh)
        self.params, self.parity_params = params, pparams

        # one fixed-shape cache pool per instance; slots never reshape
        self._caches = [self._init_cache(params, self.n_slots, self.max_seq)
                        for _ in range(self.k)]
        self._pcaches = [self._init_cache(pparams, self.n_slots,
                                          self.max_seq)
                         for _ in range(self.r)]
        self._ppos = np.zeros((self.r, self.n_slots), np.int64)

        # (member, slot) occupancy
        self._slots: List[List[Optional[_Stream]]] = [
            [None] * self.n_slots for _ in range(self.k)]
        self._dirty = set()              # slot columns needing parity rebuild

        # fault adapters: scenario delays compose with the user delay_fn
        delay_fn = spec.delay_fn
        self.scenario = None
        if spec.scenario is not None:
            self.scenario = get_scenario(spec.scenario)
            pool_sizes = {"main": self.k}
            for j in range(self.r):
                pool_sizes[f"parity{j}"] = 1
            delay_fn, _ = self.scenario.adapters(
                pool_sizes, seed=spec.scenario_seed,
                horizon_ms=spec.scenario_horizon_ms,
                time_scale=spec.scenario_time_scale, extra=delay_fn)
        self._delay_fn = delay_fn
        self._member_iids = [instance_id("main", i) for i in range(self.k)]
        self._parity_iids = [instance_id(f"parity{j}", 0)
                             for j in range(self.r)]

        self._members = [_Executor(f"lm-member-{i}") for i in range(self.k)]
        self._parities = [_Executor(f"lm-parity-{j}") for j in range(self.r)]
        for ex in self._members + self._parities:
            ex.start()

        # warm the decode paths (jit compile) before any deadline is armed —
        # a first-step compile would otherwise read as a multi-second
        # straggle on every instance at once, which no code survives
        tok0 = jnp.zeros((self.n_slots, 1), jnp.int32)
        pos0 = jnp.zeros((self.n_slots,), jnp.int32)
        self._decode(self.params, self._caches[0], pos0, token=tok0)
        self._decode(self.parity_params, self._pcaches[0], pos0,
                     embed=self._embed(self.params, tok0))

        self._waiting: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._stopping = False
        self._idle = threading.Event()   # set while nothing queued/active
        self._idle.set()
        self._gaps_ms: List[float] = []
        self._completed_by: Dict[str, int] = {}
        self._recon_steps = 0
        self._t0 = None
        self._t1 = None
        self._next_rid = 0
        self._scheduler = threading.Thread(target=self._loop,
                                           name="lm-scheduler", daemon=True)
        self._scheduler.start()

    # -- public surface ----------------------------------------------------
    def submit(self, prompt, max_new_tokens=None) -> GenerationFuture:
        """Queue one generation request (prompt: sequence of token ids)."""
        with self._lock:
            if self._stopping:
                raise RuntimeError("session is shut down")
            rid = self._next_rid
            self._next_rid += 1
        fut = GenerationFuture(rid)
        self._idle.clear()
        self._waiting.put((rid, [int(t) for t in prompt],
                           max_new_tokens or self.spec.max_new_tokens, fut))
        return fut

    def wait_all(self, timeout: float = 120.0) -> bool:
        """Block until every submitted request has finished."""
        return self._idle.wait(timeout)

    def stats(self) -> ServingReport:
        with self._lock:
            gaps = np.asarray(self._gaps_ms, float)
            n = len(gaps)
            span = (self._t1 - self._t0) if (self._t0 is not None
                                             and self._t1 is not None
                                             and self._t1 > self._t0) else 0.0
            pct = (lambda q: float(np.percentile(gaps, q))) if n else \
                (lambda q: float("nan"))
            return ServingReport(
                engine="threads", strategy="parm",
                scheme=getattr(self.scheme, "name", str(self.spec.scheme)),
                scenario=getattr(self.scenario, "name", None),
                n=n, median_ms=pct(50), p99_ms=pct(99), p999_ms=pct(99.9),
                mean_ms=float(gaps.mean()) if n else float("nan"),
                max_ms=float(gaps.max()) if n else float("nan"),
                completed_by=dict(self._completed_by),
                reconstructions=self._recon_steps,
                tokens_per_s=(n / span) if span else 0.0,
                inter_token_p50_ms=pct(50), inter_token_p999_ms=pct(99.9),
                reconstructed_steps=self._recon_steps)

    def shutdown(self):
        with self._lock:
            if self._stopping:
                return
            self._stopping = True
        self._scheduler.join(timeout=60.0)
        for ex in self._members + self._parities:
            ex.stop()
        for ex in self._members + self._parities:
            ex.join(timeout=10.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False

    # -- scheduler ---------------------------------------------------------
    def _active(self):
        return [(i, s) for i in range(self.k) for s in range(self.n_slots)
                if self._slots[i][s] is not None]

    def _loop(self):
        while True:
            self._admit()
            active = self._active()
            if not active:
                with self._lock:
                    stop = self._stopping
                if self._waiting.empty():
                    self._idle.set()
                    if stop:
                        break
                    time.sleep(1e-3)
                    continue
            else:
                self._step(active)
        # flush: nothing active remains by construction

    def _sleep_for(self, iid):
        if self._delay_fn is None:
            return 0.0
        try:
            return float(self._delay_fn(iid) or 0.0)
        except TypeError:
            return 0.0

    def _admit(self):
        """Fill free (member, slot) pairs from the waiting queue; rebuild
        parity columns whose occupancy changed."""
        admitted = False
        while True:
            free = [(i, s) for i in range(self.k)
                    for s in range(self.n_slots)
                    if self._slots[i][s] is None]
            if not free:
                break
            try:
                rid, prompt, max_new, fut = self._waiting.get_nowait()
            except queue.Empty:
                break
            i, s = free[0]
            stream = _Stream(rid, prompt, max_new, fut)
            self._slots[i][s] = stream
            if self._t0 is None:
                with self._lock:
                    self._t0 = time.monotonic()

            toks = jnp.asarray([prompt], jnp.int32)            # [1, P]
            ex = self._members[i]

            def job(toks=toks, i=i, s=s, stream=stream):
                iid = self._member_iids[i]
                d = self._sleep_for(iid)
                if d:
                    time.sleep(d)
                logits, one = self._prefill(self.params, tokens=toks,
                                            cache_len=self.max_seq)
                self._caches[i] = jax.tree.map(
                    lambda pool, new: pool.at[:, s:s + 1].set(new),
                    self._caches[i], one)
                return np.asarray(logits[0, -1])

            evt, out = ex.submit(job)
            evt.wait()
            if "error" in out:
                raise out["error"]
            # first token comes from the prefill logits (admission path,
            # uncoded); decode steps from here on are coded
            tok = int(np.argmax(out["result"]))
            now = time.monotonic()
            stream.future._times.append(stream.t_admit)
            stream.future._emit(tok, now, reconstructed=False)
            stream.next_token = tok
            self._record(now - stream.t_admit, reconstructed=False)
            self._dirty.add(s)
            admitted = True
            if stream.max_new <= 1:
                self._finish(i, s)
        if admitted or self._dirty:
            for s in sorted(self._dirty):
                self._rebuild_parity(s)
            self._dirty.clear()

    def _rebuild_parity(self, s):
        """Re-prefill parity slot column s from the encoded histories of its
        current occupants (right-aligned; empty members contribute zeros).

        Occupants admitted at different times sit at different positions;
        right-alignment matches the newest suffix, which is exact for
        position-independent substrates and the trained-parity
        approximation otherwise (DESIGN.md §13)."""
        hists = []
        for i in range(self.k):
            st = self._slots[i][s]
            hists.append(st.history if st is not None else [])
        L = max((len(h) for h in hists), default=0)
        if L == 0:
            for j in range(self.r):
                self._ppos[j, s] = 0
            return
        # encoded prompt embeddings [1, L, D]
        embs = []
        for h in hists:
            if h:
                e = np.asarray(self._embed(self.params,
                                           jnp.asarray([h], jnp.int32)))
            else:
                e = None
            embs.append(e)
        D = next(e.shape[-1] for e in embs if e is not None)
        dt = next(e.dtype for e in embs if e is not None)
        for j in range(self.r):
            enc = np.zeros((1, L, D), np.float32)
            for i, e in enumerate(embs):
                if e is not None:
                    enc[:, L - e.shape[1]:] += self.coeffs[j, i] * \
                        e.astype(np.float32)
            enc = jnp.asarray(enc.astype(dt))

            def job(enc=enc, j=j, s=s):
                _, one = self._prefill(self.parity_params, embeds=enc,
                                       cache_len=self.max_seq)
                self._pcaches[j] = jax.tree.map(
                    lambda pool, new: pool.at[:, s:s + 1].set(new),
                    self._pcaches[j], one)
                return None

            evt, out = self._parities[j].submit(job)
            evt.wait()
            if "error" in out:
                raise out["error"]
            self._ppos[j, s] = L

    def _step(self, active):
        """One coded decode step for every active stream."""
        k, n_slots = self.k, self.n_slots
        tok = np.zeros((k, n_slots, 1), np.int32)
        pos = np.zeros((k, n_slots), np.int32)
        occ = np.zeros((k, n_slots), bool)
        for i, s in active:
            st = self._slots[i][s]
            tok[i, s, 0] = st.next_token
            pos[i, s] = st.pos
            occ[i, s] = True

        # member jobs: full fixed-shape batch, per-slot positions
        member_out = []
        for i in range(k):
            ti, pi = jnp.asarray(tok[i]), jnp.asarray(pos[i])

            def job(i=i, ti=ti, pi=pi):
                d = self._sleep_for(self._member_iids[i])
                if d:
                    time.sleep(d)
                logits, new = self._decode(self.params, self._caches[i],
                                           pi, token=ti)
                self._caches[i] = new
                return np.asarray(logits)          # [n_slots, 1, V]

            member_out.append(self._members[i].submit(job))

        # parity jobs: encoded input embedding, own cache column positions.
        # Unoccupied (member, slot) cells carry token 0 only for shape — mask
        # their embeddings to zero so they contribute nothing to the code.
        embs = np.asarray(
            self._embed(self.params, jnp.asarray(tok.reshape(k * n_slots, 1)))
        ).reshape(k, n_slots, 1, -1)
        embs = embs * occ[:, :, None, None]
        parity_out = []
        active_slots = {s for _, s in active}
        for j in range(self.r):
            enc = np.einsum("i,ind->nd", self.coeffs[j],
                            embs[:, :, 0]).astype(embs.dtype)[:, None]
            enc_j = jnp.asarray(enc)
            ppos_j = jnp.asarray(self._ppos[j].astype(np.int32))

            def pjob(j=j, enc_j=enc_j, ppos_j=ppos_j):
                d = self._sleep_for(self._parity_iids[j])
                if d:
                    time.sleep(d)
                logits, new = self._decode(self.parity_params,
                                           self._pcaches[j], ppos_j,
                                           embed=enc_j)
                self._pcaches[j] = new
                return np.asarray(logits)
            parity_out.append(self._parities[j].submit(pjob))
            self._ppos[j][list(active_slots)] += 1

        # collect with the per-step straggle deadline
        deadline = time.monotonic() + self.spec.straggle_ms / 1e3
        logits = [None] * k
        missing = []
        for i, (evt, out) in enumerate(member_out):
            if evt.wait(max(0.0, deadline - time.monotonic())):
                if "error" in out:
                    raise out["error"]
                logits[i] = out["result"]
            else:
                missing.append(i)

        reconstructed = set()
        if missing:
            pavail = np.zeros((self.r,), bool)
            plogits = [None] * self.r
            for j, (evt, out) in enumerate(parity_out):
                if evt.wait(max(0.0, deadline - time.monotonic())):
                    if "error" in out:
                        raise out["error"]
                    plogits[j] = out["result"]
                    pavail[j] = True
            if len(missing) <= int(pavail.sum()):
                V = next(x for x in logits if x is not None).shape[-1] \
                    if any(x is not None for x in logits) else \
                    plogits[int(np.argmax(pavail))].shape[-1]
                outs = np.stack([
                    x if x is not None else
                    np.zeros((n_slots, 1, V), np.float32)
                    for x in logits])                       # [k, n, 1, V]
                # an available member's unoccupied slots decoded garbage
                # (token 0) that the parity never encoded — mask them so
                # the residual subtraction stays exact
                outs = outs * occ[:, :, None, None]
                pouts = np.stack([
                    p if p is not None else
                    np.zeros((n_slots, 1, V), np.float32)
                    for p in plogits])                      # [r, n, 1, V]
                mask = np.zeros((k,), bool)
                mask[missing] = True
                rec = np.asarray(self.scheme.decode(
                    jnp.asarray(pouts, jnp.float32),
                    jnp.asarray(outs, jnp.float32),
                    jnp.asarray(mask), jnp.asarray(pavail)))
                for i in missing:
                    logits[i] = rec[i]
                    reconstructed.add(i)
            else:
                # irrecoverable this step: block for the stragglers
                for i in missing:
                    evt, out = member_out[i]
                    evt.wait()
                    if "error" in out:
                        raise out["error"]
                    logits[i] = out["result"]

        # emit canonical tokens; feed them back regardless of which side
        # (member or parity decode) produced the logits
        now = time.monotonic()
        for i, s in active:
            st = self._slots[i][s]
            recon = i in reconstructed
            tok_out = int(np.argmax(logits[i][s, 0]))
            gap = now - st.future._times[-1]
            st.future._emit(tok_out, now, reconstructed=recon)
            self._record(gap, reconstructed=recon)
            st.next_token = tok_out
            st.pos += 1
            if len(st.future.tokens_so_far) >= st.max_new or \
                    st.pos >= self.max_seq - 1:
                self._finish(i, s)

    def _record(self, gap_s, *, reconstructed):
        with self._lock:
            self._gaps_ms.append(1e3 * gap_s)
            key = "parity" if reconstructed else "model"
            self._completed_by[key] = self._completed_by.get(key, 0) + 1
            if reconstructed:
                self._recon_steps += 1
            self._t1 = time.monotonic()

    def _finish(self, i, s):
        st = self._slots[i][s]
        self._slots[i][s] = None
        self._dirty.add(s)
        st.future._finish("model")


# --------------------------------------------------------------------------
# Sim engine: roofline-calibrated token-level DES
# --------------------------------------------------------------------------
def token_service_ms(spec: GenerationSpec) -> float:
    """Roofline decode-step service time (ms) for the spec's config."""
    from repro.launch.roofline import decode_token_cost
    if spec.cfg is None:
        raise ValueError("sim engine calibration needs spec.cfg")
    return 1e3 * decode_token_cost(spec.cfg, batch=spec.batching.max_size,
                                   kv_len=spec.kv_len, tp=spec.tp)


def _tokenize_report(report: ServingReport, tokens_per_s: float):
    """Surface a DES report's completions under their per-token names: each
    DES query was one decode step, so median/p999 ARE inter-token
    latencies."""
    from dataclasses import replace as drep
    return drep(report, tokens_per_s=tokens_per_s,
                inter_token_p50_ms=report.median_ms,
                inter_token_p999_ms=report.p999_ms,
                reconstructed_steps=report.reconstructions)


class LMSimSession:
    """Token-level DES: every decode step of ``m`` member streams is one
    simulated query at the roofline-calibrated service time, so the
    existing simulator (fast path included) prices 10M-token tail studies
    of the big configs without running a single matmul."""

    engine = "sim"

    def __init__(self, spec: GenerationSpec):
        self.spec = spec
        self._last: Optional[ServingReport] = None

    def replay(self, n_tokens: int = 100_000, *, seed: int = 0,
               service_cv: float = 0.1, **trace_overrides) -> ServingReport:
        spec = self.spec
        step_ms = token_service_ms(spec)
        qps = spec.utilization * spec.m * 1e3 / step_ms
        dspec = DeploymentSpec(
            strategy=spec.strategy, scheme=spec.scheme, k=spec.k, r=spec.r,
            m=spec.m, scenario=spec.scenario,
            batching=BatchingPolicy(max_size=1))
        trace = Trace(n_queries=int(n_tokens), qps=qps, service_ms=step_ms,
                      service_cv=service_cv, seed=seed, **trace_overrides)
        report = deploy(dspec, engine="sim").replay(trace)
        self._last = _tokenize_report(report, tokens_per_s=qps)
        return self._last

    def stats(self) -> ServingReport:
        if self._last is None:
            raise RuntimeError("no replay has run yet — call "
                               "session.replay(n_tokens=...) first")
        return self._last

    def shutdown(self):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def deploy_lm(spec: GenerationSpec, engine: str = "threads"):
    """Bring a ``GenerationSpec`` up on one of the two serving engines."""
    if not isinstance(spec, GenerationSpec):
        raise TypeError(f"deploy_lm() takes a GenerationSpec, got {spec!r}")
    if engine == "threads":
        return GenerationSession(spec)
    if engine == "sim":
        return LMSimSession(spec)
    raise ValueError(f"unknown engine {engine!r}; one of ('threads', 'sim')")
