"""Resilience strategies: one declarative object consumed by BOTH serving
layers (the threaded runtime and the discrete-event simulator).

A ``ResilienceStrategy`` owns the three decisions the paper's §5.1 baselines
differ in, so the two serving implementations cannot drift:

* worker-pool layout      — ``layout(m, k, r)`` -> ``PoolLayout`` (how the
                            redundancy budget m/k is spent: parity instances
                            or extra deployed instances);
* group assembly          — ``coded`` (form coding groups of ``scheme.k``
                            and dispatch parity queries) vs ``mirror``
                            (replicate each query) vs nothing;
* on-unavailability       — decode (coded), first-replica-wins (mirror),
                            Clipper default prediction at the SLO deadline
                            (``slo_default``), or just wait.

Registered strategies (all sized for the paper's apples-to-apples m + m/k
instance budget, §5.1):

  ``parm``            m deployed + m/k parity instances per parity model;
                      coding groups of k; decode on unavailability.
  ``equal_resources`` m + m/k deployed instances, no redundancy.
  ``replication``     every query dispatched twice to the main pool
                      (2x resources; first completion wins).
  ``approx_backup``   m deployed + m/k approximate backups (§5.2.6),
                      expressed as the coded ``approx_backup`` *scheme*
                      (k = 1 cheap model per group, passthrough decode) —
                      no dedicated backup pool exists in either serving
                      layer any more.
  ``default_slo``     m deployed; late predictions replaced by a default at
                      the SLO deadline (§4.1 baseline).
  ``none``            m deployed only (queueing-knee baseline).

New strategies plug in with ``register_strategy`` from any file and are then
runnable end-to-end through ``ParMFrontend`` and ``simulate`` untouched —
and, one level up, through the declarative serving surface: a
``DeploymentSpec(strategy="mine")`` deploys on either engine
(``repro.serving.api.deploy``) the moment the name is registered.

A strategy may also pin a default fault ``scenario`` (a registered name from
``repro.serving.scenarios``); both serving layers resolve it when the caller
does not pass one explicitly, so a strategy can declare the hazard regime it
is meant to be evaluated under.

Serving *policy* — adaptive batching, SLO deadlines, redundant-work
cancellation — deliberately does NOT live here: those are frontend
properties declared on the ``DeploymentSpec`` (``BatchingPolicy``,
``slo_ms``), orthogonal to the resilience strategy (DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Union


@dataclass(frozen=True)
class PoolLayout:
    """Instance counts per pool. ``parity`` is instances *per parity queue*
    in the threaded runtime and the parity-pool size in the simulator."""
    main: int
    parity: int = 0


@dataclass(frozen=True)
class ResilienceStrategy:
    """Declarative strategy; both serving layers interpret the same flags."""

    name: str
    coded: bool = False          # assemble groups of scheme.k, dispatch parity
    mirror: int = 1              # copies of each query sent to the main pool
    slo_default: bool = False    # fulfill with the default prediction at SLO
    extra_main: bool = False     # spend the redundancy budget on main pool
    scheme: Optional[str] = None  # default CodingScheme name (coded only)
    scenario: Optional[str] = None  # default fault Scenario name; None means
                                    # each serving layer's own default (the
                                    # DES's legacy shuffle load, no injection
                                    # in the threaded runtime)

    def n_redundant(self, m: int, k: int) -> int:
        """The paper's redundancy budget: m/k instances (at least 1)."""
        return max(1, m // k)

    def layout(self, m: int, k: int, r: int = 1) -> PoolLayout:
        nr = self.n_redundant(m, k)
        return PoolLayout(
            main=m + (nr * r if self.extra_main else 0),
            parity=nr if self.coded else 0)


# --------------------------------------------------------------- registry ---
_STRATEGIES: Dict[str, ResilienceStrategy] = {}


def register_strategy(strategy: ResilienceStrategy, *,
                      override: bool = False) -> ResilienceStrategy:
    """Register a strategy instance under its ``name``.  Registering a
    *different* strategy under an existing name raises unless
    ``override=True`` (an equal re-registration is a no-op, so module
    re-imports stay safe)."""
    if not override and _STRATEGIES.get(strategy.name, strategy) != strategy:
        raise ValueError(
            f"resilience strategy {strategy.name!r} is already registered; "
            f"pass override=True to replace it")
    _STRATEGIES[strategy.name] = strategy
    return strategy


def list_strategies() -> list:
    """Introspection: registered strategy names, sorted.  Every listed name
    resolves via ``get_strategy(name)``."""
    return sorted(_STRATEGIES)


def available_strategies():
    return list_strategies()


def get_strategy(strategy: Union[str, ResilienceStrategy],
                 **overrides) -> ResilienceStrategy:
    """Resolve a name (or pass an instance through), optionally overriding
    fields, e.g. ``get_strategy("parm", scheme="concat")``."""
    if isinstance(strategy, ResilienceStrategy):
        return replace(strategy, **overrides) if overrides else strategy
    if isinstance(strategy, str):
        if strategy not in _STRATEGIES:
            raise KeyError(
                f"unknown resilience strategy {strategy!r}; registered: "
                f"{available_strategies()}")
        base = _STRATEGIES[strategy]
        return replace(base, **overrides) if overrides else base
    raise TypeError(
        f"not a ResilienceStrategy or registered name: {strategy!r}")


register_strategy(ResilienceStrategy("parm", coded=True, scheme="sum"))
register_strategy(ResilienceStrategy("equal_resources", extra_main=True))
register_strategy(ResilienceStrategy("replication", mirror=2))
register_strategy(ResilienceStrategy("approx_backup", coded=True,
                                     scheme="approx_backup"))
register_strategy(ResilienceStrategy("default_slo", slo_default=True))
register_strategy(ResilienceStrategy("none"))
