"""Threaded prediction-serving runtime with ParM coded resilience.

A faithful (single-host) analogue of the paper's Clipper-based deployment:
a frontend with a single dispatch queue per pool (the load-balancing strategy
of §5.1), model-instance worker threads running real JAX inference, coding
groups of k consecutively dispatched query batches, frontend-side encode, and
on-unavailability decode. Slowdowns are injected per instance (sleep), since
the mitigation is agnostic to the cause (§2.2).

Used by the end-to-end example (examples/serve_parm.py) and integration tests;
the 100k-query tail studies use the DES in ``repro.serving.simulator``.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.codes import SumEncoder, LinearDecoder


@dataclass
class Query:
    qid: int
    data: np.ndarray
    arrival: float = 0.0
    event: threading.Event = field(default_factory=threading.Event)
    result: Optional[np.ndarray] = None
    completed_by: str = ""
    finish: float = 0.0

    def fulfill(self, result, how, now=None):
        if not self.event.is_set():
            self.result = result
            self.completed_by = how
            self.finish = now or time.perf_counter()
            self.event.set()

    @property
    def latency_ms(self):
        return (self.finish - self.arrival) * 1e3


class ModelInstance(threading.Thread):
    """Worker pulling (tag, payload) items off a shared pool queue."""

    def __init__(self, iid, pool_q, fwd, params, on_done,
                 delay_fn: Optional[Callable[[int], float]] = None):
        super().__init__(daemon=True)
        self.iid = iid
        self.pool_q = pool_q
        self.fwd = fwd
        self.params = params
        self.on_done = on_done
        self.delay_fn = delay_fn
        self.stop = False

    def run(self):
        while not self.stop:
            try:
                item = self.pool_q.get(timeout=0.05)
            except queue.Empty:
                continue
            tag, payload, x = item
            if self.delay_fn:
                d = self.delay_fn(self.iid)
                if d > 0:
                    time.sleep(d)
            out = np.asarray(self.fwd(self.params, x))
            self.on_done(tag, payload, out)


class ParMFrontend:
    """Frontend: group assembly, encode, dispatch, decode-on-unavailability.

    mode: "parm" | "equal_resources" | "default_slo" (Clipper default
    prediction at the SLO deadline, §4.1 baseline)."""

    def __init__(self, fwd, deployed_params, parity_params=None, *, k=2,
                 r=1, m=4, mode="parm", delay_fn=None, encode_fn=None,
                 decode_fn=None, default_prediction=None, slo_ms=None):
        """``r > 1`` (paper §3.5): ``parity_params`` is a list of r parity
        models, each trained to the j-th Vandermonde combination; r parity
        queries are dispatched per coding group and the decoder solves the
        linear system for up to r concurrent unavailabilities."""
        self.k, self.r, self.mode = k, r, mode
        self.encoder = SumEncoder(k, r)
        self.decoder = LinearDecoder(k, r)
        self._coeffs = np.asarray(self.encoder.coeffs)
        self.encode_fn = encode_fn or (lambda q: np.asarray(self.encoder(q)))
        self.decode_fn = decode_fn
        self.default_prediction = default_prediction
        self.slo_ms = slo_ms
        self.queries = {}
        self.groups = {}   # gid -> {"members", "outs", "parity": {j: out}}
        self.lock = threading.Lock()
        self._next_gid = 0
        self._pending_group = []

        self.main_q = queue.Queue()
        n_parity = max(1, m // k)
        self.workers = []
        n_main = m + (n_parity * r if mode == "equal_resources" else 0)
        for i in range(n_main):
            w = ModelInstance(i, self.main_q, fwd, deployed_params,
                              self._on_model_done, delay_fn)
            w.start()
            self.workers.append(w)
        if mode == "parm":
            if r == 1 and not isinstance(parity_params, (list, tuple)):
                parity_params = [parity_params]
            assert len(parity_params) == r
            self.parity_qs = []
            for j in range(r):
                pq = queue.Queue()
                self.parity_qs.append(pq)
                for i in range(n_parity):
                    w = ModelInstance(1000 + 100 * j + i, pq, fwd,
                                      parity_params[j],
                                      self._on_parity_done, delay_fn)
                    w.start()
                    self.workers.append(w)
            self.parity_q = self.parity_qs[0]      # back-compat alias

    # ------------------------------------------------------------------
    def submit(self, qid, x):
        """x: one query batch (leading batch dim, usually 1)."""
        q = Query(qid, x, arrival=time.perf_counter())
        with self.lock:
            self.queries[qid] = q
            if self.mode == "parm":
                self._pending_group.append(qid)
                self.gid_of = getattr(self, "gid_of", {})
                self.gid_of[qid] = self._next_gid
                if len(self._pending_group) == self.k:
                    gid = self._next_gid
                    members = list(self._pending_group)
                    self._pending_group.clear()
                    self._next_gid += 1
                    self.groups[gid] = {"members": members, "outs": {},
                                        "parity": {}}
                    # frontend-side encode (1/k network overhead, §3.1);
                    # r parity queries, one per parity model (§3.5)
                    parities = self.encode_fn(
                        np.stack([self.queries[m].data for m in members]))
                    for j, pq in enumerate(self.parity_qs):
                        pq.put(("parity", (gid, j), parities[j]))
        self.main_q.put(("query", qid, x))
        if self.mode == "default_slo" and self.slo_ms is not None:
            t = threading.Timer(self.slo_ms / 1e3, self._default_fire,
                                args=(qid,))
            t.daemon = True
            t.start()
        return q

    def _default_fire(self, qid):
        q = self.queries[qid]
        q.fulfill(self.default_prediction, "default")

    # ------------------------------------------------------------------
    def _on_model_done(self, tag, qid, out):
        q = self.queries[qid]
        q.fulfill(out, "model")
        if self.mode != "parm":
            return
        with self.lock:
            gid = self.gid_of.get(qid)
            info = self.groups.get(gid)
            if info is not None:
                info["outs"][qid] = out
                self._maybe_decode(gid, info)

    def _on_parity_done(self, tag, key, out):
        gid, j = key
        with self.lock:
            info = self.groups.get(gid)
            if info is None:
                return
            info["parity"][j] = out
            self._maybe_decode(gid, info)

    def _maybe_decode(self, gid, info):
        """Called with lock held: reconstruct up to ``n_parities_arrived``
        missing predictions (r=1 fast path: subtraction decoder)."""
        n_par = len(info["parity"])
        missing = [m for m in info["members"] if m not in info["outs"]
                   and not self.queries[m].event.is_set()]
        if not missing or len(missing) > n_par:
            return
        any_out = next(iter(info["parity"].values()))
        outs = np.stack([info["outs"].get(m, np.zeros_like(any_out))
                         for m in info["members"]])
        if self.r == 1 and len(missing) == 1:
            j = info["members"].index(missing[0])
            if self.decode_fn is not None:
                recon = self.decode_fn(info["parity"][0], outs, j)
            else:
                recon = np.asarray(self.decoder.decode_one(
                    info["parity"][0], outs, j))
            self.queries[missing[0]].fulfill(recon, "parity")
            return
        parity_outs = np.stack([
            info["parity"].get(j, np.zeros_like(any_out))
            for j in range(self.r)])
        parity_avail = np.array([j in info["parity"]
                                 for j in range(self.r)])
        miss_mask = np.array([m in missing for m in info["members"]])
        recon = np.asarray(self.decoder.decode(
            jnp.asarray(parity_outs), jnp.asarray(outs),
            jnp.asarray(miss_mask), jnp.asarray(parity_avail)))
        for m in missing:
            idx = info["members"].index(m)
            self.queries[m].fulfill(recon[idx], "parity")

    # ------------------------------------------------------------------
    def wait_all(self, timeout=60.0):
        deadline = time.time() + timeout
        for q in self.queries.values():
            q.event.wait(max(0.0, deadline - time.time()))
        return all(q.event.is_set() for q in self.queries.values())

    def shutdown(self):
        for w in self.workers:
            w.stop = True
        for w in self.workers:
            w.join(timeout=1.0)

    def stats(self):
        lats = np.array([q.latency_ms for q in self.queries.values()
                         if q.event.is_set()])
        by = {}
        for q in self.queries.values():
            by[q.completed_by] = by.get(q.completed_by, 0) + 1
        return {"median_ms": float(np.percentile(lats, 50)),
                "p99_ms": float(np.percentile(lats, 99)) if len(lats) > 1 else float(lats.max()),
                "max_ms": float(lats.max()),
                "completed_by": by, "n": len(lats)}
