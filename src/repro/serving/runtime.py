"""Threaded prediction-serving runtime with pluggable coded resilience.

A faithful (single-host) analogue of the paper's Clipper-based deployment:
a frontend with a single dispatch queue per pool (the load-balancing strategy
of §5.1), model-instance worker threads running real JAX inference, coding
groups of k consecutively dispatched query batches, frontend-side encode, and
on-unavailability decode. Slowdowns are injected per instance (sleep), since
the mitigation is agnostic to the cause (§2.2).

Which pools exist, how queries are grouped/mirrored, and what happens on
unavailability are owned by a ``ResilienceStrategy`` (``serving/strategy.py``)
and the code itself by a ``CodingScheme`` (``core/scheme.py``) — the same two
objects the DES in ``repro.serving.simulator`` consumes, so the threaded and
simulated serving paths cannot drift. See DESIGN.md for the plugin API.

Used by the end-to-end example (examples/serve_parm.py) and integration tests;
the 100k-query tail studies use the DES in ``repro.serving.simulator``.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.scheme import get_scheme, recoverable_rows
from repro.serving.scenarios import get_scenario, instance_id
from repro.serving.strategy import get_strategy


@dataclass
class Query:
    qid: int
    data: np.ndarray
    arrival: float = 0.0
    event: threading.Event = field(default_factory=threading.Event)
    result: Optional[np.ndarray] = None
    completed_by: str = ""
    finish: float = 0.0

    def fulfill(self, result, how, now=None):
        if not self.event.is_set():
            self.result = result
            self.completed_by = how
            self.finish = now or time.perf_counter()
            self.event.set()

    @property
    def latency_ms(self):
        return (self.finish - self.arrival) * 1e3


class ModelInstance(threading.Thread):
    """Worker pulling (tag, payload) items off a shared pool queue."""

    def __init__(self, iid, pool_q, fwd, params, on_done,
                 delay_fn: Optional[Callable[[int], float]] = None):
        super().__init__(daemon=True)
        self.iid = iid
        self.pool_q = pool_q
        self.fwd = fwd
        self.params = params
        self.on_done = on_done
        self.delay_fn = delay_fn
        self.stop = False

    def run(self):
        while not self.stop:
            try:
                item = self.pool_q.get(timeout=0.05)
            except queue.Empty:
                continue
            tag, payload, x = item
            if self.delay_fn:
                d = self.delay_fn(self.iid)
                if d > 0:
                    time.sleep(d)
            out = np.asarray(self.fwd(self.params, x))
            self.on_done(tag, payload, out)


class ParMFrontend:
    """Frontend: group assembly, encode, dispatch, decode-on-unavailability.

    ``strategy`` — a ``ResilienceStrategy`` or registered name
    (``parm`` | ``equal_resources`` | ``replication`` | ``approx_backup`` |
    ``default_slo`` | ``none``); owns pool layout and unavailability behavior.
    ``scheme`` — a ``CodingScheme`` or registered name (``sum`` | ``concat`` |
    ``replication`` | ``approx_backup`` | ``learned``); owns encode/decode
    AND the coding-group size: groups are assembled with ``scheme.k``
    queries, which a ``fixes_k`` scheme (approx_backup: k = 1, one cheap
    backup query per group) decouples from the redundancy-budget ``k`` that
    sizes the pools. ``backend`` selects the jnp or Pallas-kernel hot path
    when ``scheme`` is given by name.

    The old ``mode=`` kwarg is a deprecated alias for ``strategy=``; the old
    ``backup_params=`` (the removed dedicated backup pool) is a deprecated
    alias for ``parity_params=``.
    """

    def __init__(self, fwd, deployed_params, parity_params=None, *, k=2,
                 r=None, m=4, strategy="parm", scheme=None, backend=None,
                 mode=None, delay_fn=None, encode_fn=None, decode_fn=None,
                 default_prediction=None, slo_ms=None, backup_params=None,
                 parity_fwd=None, scenario=None, scenario_seed=0,
                 scenario_time_scale=1.0, scenario_horizon_ms=600_000.0):
        """``r > 1`` (paper §3.5): ``parity_params`` is a list of r parity
        models, each trained to the j-th Vandermonde combination; r parity
        queries are dispatched per coding group and the decoder solves the
        linear system for up to r concurrent unavailabilities. ``r`` and
        ``backend`` default to the scheme's own values when a scheme
        *instance* is passed; an explicit mismatch raises.

        ``parity_fwd`` — forward function for the parity-pool workers when
        the parity model is a *different architecture* from the deployed
        model (the approx_backup scheme's cheap backup model); defaults to
        ``fwd``.

        ``scenario`` — a fault ``Scenario`` (instance or registered name from
        ``repro.serving.scenarios``, e.g. ``"crash"``); its hazards are
        realized once and injected as per-instance delays through the same
        windows the DES applies, composing with any user ``delay_fn``.
        ``scenario_time_scale`` maps scenario milliseconds to wall-clock
        milliseconds (1.0 = real time); recurring hazards are realized out
        to ``scenario_horizon_ms`` sim-ms, so injection stops after
        ``scenario_horizon_ms * scenario_time_scale`` wall-clock ms —
        raise it for longer experiments."""
        if mode is not None:
            warnings.warn(
                "ParMFrontend(mode=...) is deprecated; use strategy=",
                DeprecationWarning, stacklevel=2)
            strategy = mode
        if backup_params is not None:
            warnings.warn(
                "ParMFrontend(backup_params=...) is deprecated; approximate "
                "backups are the coded 'approx_backup' scheme now — pass "
                "parity_params= (and parity_fwd= for a cheaper architecture)",
                DeprecationWarning, stacklevel=2)
            if parity_params is None:
                parity_params = backup_params
        self.strategy = get_strategy(strategy)
        if scheme is None:
            scheme = self.strategy.scheme or "sum"
        # validates k / r / backend against scheme instances
        self.scheme = get_scheme(scheme, k=k, r=r, backend=backend)
        self.k = k
        # group assembly follows the scheme's own group size: a fixes_k
        # scheme (approx_backup) decouples it from the budget k
        self.group_k = self.scheme.k if self.strategy.coded else k
        # a scheme may fix its own parity count (replication: r = k)
        self.r = self.scheme.r if self.strategy.coded else \
            (1 if r is None else r)
        self.encode_fn = encode_fn or (
            lambda q: np.asarray(self.scheme.encode(q)))
        self.decode_fn = decode_fn
        self.default_prediction = default_prediction
        self.slo_ms = slo_ms
        self.queries = {}
        self.groups = {}   # gid -> {"members", "outs", "parity": {j: out}}
        self.gid_of = {}
        self.lock = threading.Lock()
        self._next_gid = 0
        self._pending_group = []
        self._early_outs = {}   # outputs that beat their group's assembly

        layout = self.strategy.layout(m, k, self.r)
        if scenario is None:
            scenario = self.strategy.scenario
        self.scenario = None
        if scenario is not None:
            # fault-injection adapter: the scenario's hazard windows become
            # per-instance delays, composed with any user delay_fn
            self.scenario = get_scenario(scenario)
            pool_sizes = {"main": layout.main}
            if self.strategy.coded and layout.parity:
                for j in range(self.r):
                    pool_sizes[f"parity{j}"] = layout.parity
            delay_fn = self.scenario.delay_fn(
                pool_sizes, seed=scenario_seed,
                horizon_ms=scenario_horizon_ms,
                time_scale=scenario_time_scale, extra=delay_fn)
        self.main_q = queue.Queue()
        self.workers = []
        for i in range(layout.main):
            w = ModelInstance(instance_id("main", i), self.main_q, fwd,
                              deployed_params, self._on_model_done, delay_fn)
            w.start()
            self.workers.append(w)
        if self.strategy.coded:
            if parity_params is None:
                # replication-style schemes: the "parity model" is the
                # deployed model itself (decode is a passthrough)
                parity_params = [deployed_params] * self.r
            elif not isinstance(parity_params, (list, tuple)):
                parity_params = [parity_params]
            assert len(parity_params) == self.r, \
                (len(parity_params), self.r)
            self.parity_qs = []
            for j in range(self.r):
                pq = queue.Queue()
                self.parity_qs.append(pq)
                for i in range(layout.parity):
                    w = ModelInstance(instance_id(f"parity{j}", i), pq,
                                      parity_fwd or fwd, parity_params[j],
                                      self._on_parity_done, delay_fn)
                    w.start()
                    self.workers.append(w)
            self.parity_q = self.parity_qs[0]      # back-compat alias

    # ------------------------------------------------------------------
    def submit(self, qid, x):
        """x: one query batch (leading batch dim, usually 1)."""
        q = Query(qid, x, arrival=time.perf_counter())
        to_encode = None
        with self.lock:
            self.queries[qid] = q
            if self.strategy.coded:
                self._pending_group.append(qid)
                self.gid_of[qid] = self._next_gid
                if len(self._pending_group) == self.group_k:
                    gid = self._next_gid
                    members = list(self._pending_group)
                    self._pending_group.clear()
                    self._next_gid += 1
                    # outputs that finished before the group existed
                    outs = {m: self._early_outs.pop(m) for m in members
                            if m in self._early_outs}
                    self.groups[gid] = {"members": members, "outs": outs,
                                        "parity": {}}
                    to_encode = (gid, np.stack(
                        [self.queries[m].data for m in members]))
        for _ in range(self.strategy.mirror):
            self.main_q.put(("query", qid, x))
        if to_encode is not None:
            # frontend-side encode (1/k network overhead, §3.1); r parity
            # queries, one per parity model (§3.5). Runs outside the lock —
            # a JAX dispatch here would stall every completion callback —
            # which is safe because no parity output for this gid can arrive
            # before these puts
            gid, stacked = to_encode
            parities = self.encode_fn(stacked)
            for j, pq in enumerate(self.parity_qs):
                pq.put(("parity", (gid, j), parities[j]))
        if self.strategy.slo_default and self.slo_ms is not None:
            t = threading.Timer(self.slo_ms / 1e3, self._default_fire,
                                args=(qid,))
            t.daemon = True
            t.start()
        return q

    def _default_fire(self, qid):
        q = self.queries[qid]
        q.fulfill(self.default_prediction, "default")

    # ------------------------------------------------------------------
    def _on_model_done(self, tag, qid, out):
        q = self.queries[qid]
        if not self.strategy.coded:
            q.fulfill(out, "model")
            return
        # record the output and fulfill atomically: a decode racing in
        # between would see the member as available yet read its zero
        # placeholder, reconstructing garbage for the group's straggler
        with self.lock:
            gid = self.gid_of.get(qid)
            info = self.groups.get(gid)
            if info is not None:
                info["outs"][qid] = out
            else:
                # finished before the k-th member arrived and the group was
                # assembled; stash it so the decode never zero-fills this row
                self._early_outs[qid] = out
            q.fulfill(out, "model")
            if info is not None:
                self._maybe_decode(gid, info)

    def _on_parity_done(self, tag, key, out):
        gid, j = key
        with self.lock:
            info = self.groups.get(gid)
            if info is None:
                return
            info["parity"][j] = out
            self._maybe_decode(gid, info)

    def _recoverable(self, miss_mask, parity_avail):
        """Which missing rows can be reconstructed now? Delegates to the
        shared ``recoverable_rows`` rule — the same function the DES consults
        — so the two serving layers cannot drift on decode decisions."""
        return recoverable_rows(self.scheme, miss_mask, parity_avail)

    def _maybe_decode(self, gid, info):
        """Called with lock held: reconstruct up to ``n_parities_arrived``
        missing predictions (r=1 fast path: subtraction decoder)."""
        if not info["parity"]:
            return
        members = info["members"]
        miss_mask = np.array([m not in info["outs"]
                              and not self.queries[m].event.is_set()
                              for m in members])
        parity_avail = np.array([j in info["parity"]
                                 for j in range(self.r)])
        miss_mask = self._recoverable(miss_mask, parity_avail)
        missing = [m for m, miss in zip(members, miss_mask) if miss]
        if not missing:
            return
        any_out = next(iter(info["parity"].values()))
        outs = np.stack([info["outs"].get(m, np.zeros_like(any_out))
                         for m in members])
        if self.r == 1 and len(missing) == 1:
            j = members.index(missing[0])
            if self.decode_fn is not None:
                recon = self.decode_fn(info["parity"][0], outs, j)
            else:
                recon = np.asarray(self.scheme.decode_one(
                    info["parity"][0], outs, j))
            self.queries[missing[0]].fulfill(recon, "parity")
            return
        parity_outs = np.stack([
            info["parity"].get(j, np.zeros_like(any_out))
            for j in range(self.r)])
        recon = np.asarray(self.scheme.decode(
            jnp.asarray(parity_outs), jnp.asarray(outs),
            jnp.asarray(miss_mask), jnp.asarray(parity_avail)))
        for m in missing:
            self.queries[m].fulfill(recon[members.index(m)], "parity")

    # ------------------------------------------------------------------
    def wait_all(self, timeout=60.0):
        deadline = time.time() + timeout
        for q in self.queries.values():
            q.event.wait(max(0.0, deadline - time.time()))
        return all(q.event.is_set() for q in self.queries.values())

    def shutdown(self):
        for w in self.workers:
            w.stop = True
        for w in self.workers:
            w.join(timeout=1.0)
        # a workload that isn't a multiple of k leaves a partial coding group
        # behind; fulfill its members so wait_all() can't hang on them
        with self.lock:
            leftovers = list(self._pending_group)
            self._pending_group.clear()
        for qid in leftovers:
            q = self.queries.get(qid)
            if q is not None and not q.event.is_set():
                q.fulfill(self.default_prediction, "flushed")

    def stats(self):
        """Latency percentiles + completion-path counts, with the same keys
        the DES (``repro.serving.simulator.simulate``) reports. Queries
        flushed at shutdown appear in ``completed_by`` but are excluded from
        the latency numbers — their finish time is a shutdown artifact."""
        lats = np.array([q.latency_ms for q in self.queries.values()
                         if q.event.is_set() and q.completed_by != "flushed"])
        by = {}
        for q in self.queries.values():
            if q.completed_by:
                by[q.completed_by] = by.get(q.completed_by, 0) + 1

        def pct(p):
            return float(np.percentile(lats, p)) if len(lats) else float("nan")

        return {"strategy": self.strategy.name,
                "scheme": self.scheme.name if self.strategy.coded else None,
                "scenario": self.scenario.name if self.scenario else None,
                "median_ms": pct(50),
                "p99_ms": pct(99),
                "p999_ms": pct(99.9),
                "mean_ms": float(lats.mean()) if len(lats) else float("nan"),
                "max_ms": float(lats.max()) if len(lats) else float("nan"),
                "completed_by": by,
                "reconstructions": by.get("parity", 0),
                "n": int(len(lats))}
