"""Threaded prediction-serving runtime with pluggable coded resilience.

A faithful (single-host) analogue of the paper's Clipper-based deployment:
a frontend with a single dispatch queue per pool (the load-balancing strategy
of §5.1), model-instance worker threads running real JAX inference, coding
groups of k consecutively dispatched query batches, frontend-side encode, and
on-unavailability decode. Slowdowns are injected per instance (sleep), since
the mitigation is agnostic to the cause (§2.2).

Which pools exist, how queries are grouped/mirrored, and what happens on
unavailability are owned by a ``ResilienceStrategy`` (``serving/strategy.py``)
and the code itself by a ``CodingScheme`` (``core/scheme.py``) — the same two
objects the DES in ``repro.serving.simulator`` consumes, so the threaded and
simulated serving paths cannot drift. See DESIGN.md for the plugin API.

This module is the **threads engine** behind the declarative serving surface
in ``repro.serving.api``: ``deploy(DeploymentSpec(...), engine="threads")``
constructs a ``ParMFrontend`` from the spec, and the legacy kwarg constructor
is a shim that folds its arguments into a ``DeploymentSpec`` first.  Two
serving-policy behaviors live here rather than in the strategy, because they
are properties of the *frontend*, not of the code:

* **adaptive batching** (``DeploymentSpec.batching``): main-pool workers
  dequeue up to ``max_size`` waiting queries per inference call (optionally
  holding the batch open ``max_delay_ms`` for late joiners), stack them along
  the batch dimension, and split the stacked output back per query;
* **redundant-work cancellation**: a queued query whose prediction already
  arrived (parity decode beat it, a mirror replica won, or the SLO default
  fired) is tombstoned and skipped at dequeue, and an undispatched parity
  query whose group has every original answered is dropped the same way —
  both counted in ``ServingReport.cancelled_queries`` /
  ``cancelled_parities``;
* **Byzantine screening**: under a corrupt-output scenario the workers'
  ``corrupt_fn`` adapter garbles real outputs (``CORRUPTION_SCALE``), and a
  ``detects_errors`` scheme (approxifer) votes recorded responses out via
  ``flag_errors`` whenever the group holds surplus responses — evicted
  responses never answer their query nor enter a decode; counts surface as
  ``ServingReport.corrupted_detected`` / ``corrected``;
* **closed-loop adaptation** (``DeploymentSpec.controller``): a registered
  ``Controller`` (``serving/controller.py``) observes fixed-length windows of
  the live signals (ticked at the top of ``submit`` on the scenario clock,
  trailing windows closed at shutdown) and emits ``Adjustment``s that retune
  scheme / r / batch size.  Adjustments land at the next coding-group
  boundary; in-flight groups keep the scheme/r they captured at assembly, so
  nothing is dropped mid-decode.  Parity pools are provisioned up front in
  two families: pools ``0..r-1`` run the deployment's own ``parity_params``,
  and ``Controller.escalation_r`` extra pools run the *deployed* parameters
  for escalated groups — a controller adjustment that is not an exact return
  to the deployment base must name a ``model_agnostic`` scheme (approxifer),
  whose parity input is a combination of plain queries, so the deployed
  model is its parity model; groups route to one family or the other by the
  scheme they captured.  The adjustment log uses the same tuples the DES
  records, so the differential battery compares decision sequences verbatim.

Used by the end-to-end example (examples/serve_parm.py) and integration tests;
the 100k-query tail studies use the DES in ``repro.serving.simulator``.
"""
from __future__ import annotations

import queue
import threading
import time
import warnings
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.scheme import (get_scheme, recoverable_rows,
                               scheme_capabilities)
from repro.serving.api import BatchingPolicy, DeploymentSpec
from repro.serving.controller import Adjustment, get_controller
from repro.serving.report import ServingReport, build_window
from repro.serving.scenarios import (CORRUPTION_SCALE, get_scenario,
                                     instance_id)
from repro.serving.strategy import get_strategy

# worker-shutdown sentinel: one per worker is pushed onto its pool queue so a
# blocking ``get()`` wakes immediately — no idle polling, sub-ms shutdown
_SHUTDOWN = object()

# test hook for the batched multi-group decode drain (`_decode_touched`):
# None = batch whenever >1 recoverable group shares a scheme and shape,
# "batched" = route even a single group through the multigroup launch,
# "pergroup" = always decode per group (the pre-fusion path).  The fused /
# unfused differential test drives both settings through identical workloads
# and asserts identical ServingReport reconstruction counts.
_FORCE_DECODE: Optional[str] = None

# not-passed marker for the legacy kwarg surface: any kwarg the caller
# actually supplied is `is not _UNSET`, so spec-vs-kwargs conflict detection
# needs no shadow table of defaults
_UNSET = object()


@dataclass
class Query:
    qid: int
    data: np.ndarray
    arrival: float = 0.0
    event: threading.Event = field(default_factory=threading.Event)
    result: Optional[np.ndarray] = None
    completed_by: str = ""
    finish: float = 0.0

    def fulfill(self, result, how, now=None):
        if not self.event.is_set():
            self.result = result
            self.completed_by = how
            self.finish = now or time.perf_counter()
            self.event.set()

    @property
    def latency_ms(self):
        return (self.finish - self.arrival) * 1e3


class ModelInstance(threading.Thread):
    """Worker pulling (tag, payload, x) items off a shared pool queue.

    ``skip_fn(tag, payload)`` — redundant-work tombstone check, consulted at
    dequeue (an item that became pointless while queued is dropped, never
    served).  ``batching`` — adaptive batching policy; when ``max_size > 1``
    the worker collects up to that many queued items per inference call,
    stacks them along the batch dim and splits the output back per item.
    ``on_done_batch([(payload, out), ...])`` — batch-atomic completion: the
    whole batch's outputs are handed over in ONE call, so the consumer can
    record every batch-mate before any decode decision runs (delivering them
    one at a time would let a parity decode "reconstruct" a member whose
    exact output sits later in the same batch).  ``on_batch(n)`` —
    bookkeeping callback, once per inference call.
    """

    def __init__(self, iid, pool_q, fwd, params, on_done,
                 delay_fn: Optional[Callable[[int], float]] = None,
                 skip_fn: Optional[Callable] = None,
                 batching: Optional[BatchingPolicy] = None,
                 on_batch: Optional[Callable[[int], None]] = None,
                 on_done_batch: Optional[Callable] = None,
                 corrupt_fn: Optional[Callable[[int], bool]] = None):
        super().__init__(daemon=True)
        self.iid = iid
        self.pool_q = pool_q
        self.fwd = fwd
        self.params = params
        self.on_done = on_done
        self.delay_fn = delay_fn
        self.skip_fn = skip_fn
        self.batching = batching
        self.on_batch = on_batch
        self.on_done_batch = on_done_batch
        self.corrupt_fn = corrupt_fn
        self.stop = False

    def _maybe_corrupt(self, out):
        """Byzantine injection (``corrupt_fn`` adapter, the ``delay_fn``
        twin): while a corrupt window is active on this instance, the
        response is garbage at ``CORRUPTION_SCALE`` — real numerical
        corruption the decode path must detect, not a flag."""
        if self.corrupt_fn is not None and self.corrupt_fn(self.iid):
            return np.full_like(out, CORRUPTION_SCALE)
        return out

    def _collect(self, first):
        """Fill a batch: up to ``max_size`` items, holding the batch open at
        most ``max_delay_ms`` after the first dequeue (Clipper-style)."""
        items = [first]
        deadline = time.perf_counter() + self.batching.max_delay_ms / 1e3
        while len(items) < self.batching.max_size:
            wait = deadline - time.perf_counter()
            try:
                item = self.pool_q.get(timeout=wait) if wait > 0 \
                    else self.pool_q.get_nowait()
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                self.stop = True        # serve what we have, then exit
                break
            if self.skip_fn is not None and self.skip_fn(item[0], item[1]):
                continue                # tombstoned while queued
            items.append(item)
        return items

    def run(self):
        while not self.stop:
            item = self.pool_q.get()
            if item is _SHUTDOWN:
                break
            if self.stop:
                # shutdown raced our dequeue: abandon the item, but route it
                # through the same tombstone accounting the post-join queue
                # drain applies, so redundant work is still counted
                if self.skip_fn is not None:
                    self.skip_fn(item[0], item[1])
                continue
            if self.skip_fn is not None and self.skip_fn(item[0], item[1]):
                continue            # tombstoned while queued
            if self.batching is not None and self.batching.max_size > 1:
                items = self._collect(item)
            else:
                items = [item]
            if self.delay_fn:
                d = self.delay_fn(self.iid)
                if d > 0:
                    time.sleep(d)
            if len(items) == 1:
                tag, payload, x = items[0]
                out = self._maybe_corrupt(np.asarray(self.fwd(self.params,
                                                              x)))
                if self.on_batch is not None:
                    self.on_batch(1)
                self.on_done(tag, payload, out)
            else:
                # one inference call per trailing-shape group: same-shape
                # queries stack along the leading batch dim and the output
                # splits back per item.  Mixed shapes are NOT padded — for a
                # general fwd, padding would change the outputs — they just
                # cost one extra call, instead of a ValueError that would
                # kill the worker and hang every dequeued future
                groups = {}
                for i, it in enumerate(items):
                    groups.setdefault(np.shape(it[2])[1:], []).append(i)
                outs = [None] * len(items)
                for idxs in groups.values():
                    stacked = np.concatenate([items[i][2] for i in idxs],
                                             axis=0)
                    out = self._maybe_corrupt(
                        np.asarray(self.fwd(self.params, stacked)))
                    if self.on_batch is not None:
                        self.on_batch(len(idxs))
                    ofs = 0
                    for i in idxs:
                        sz = items[i][2].shape[0]
                        outs[i] = out[ofs:ofs + sz]
                        ofs += sz
                if self.on_done_batch is not None:
                    self.on_done_batch(
                        [(it[1], o) for it, o in zip(items, outs)])
                else:
                    for (tag, payload, _), o in zip(items, outs):
                        self.on_done(tag, payload, o)


class ParMFrontend:
    """Frontend: group assembly, encode, dispatch, decode-on-unavailability.

    The canonical constructor is ``ParMFrontend(spec=DeploymentSpec(...))``
    (what ``repro.serving.api.deploy`` calls); the legacy kwarg surface keeps
    working by folding its arguments into a spec first.

    ``strategy`` — a ``ResilienceStrategy`` or registered name
    (``parm`` | ``equal_resources`` | ``replication`` | ``approx_backup`` |
    ``default_slo`` | ``none``); owns pool layout and unavailability behavior.
    ``scheme`` — a ``CodingScheme`` or registered name (``sum`` | ``concat`` |
    ``replication`` | ``approx_backup`` | ``learned``); owns encode/decode
    AND the coding-group size: groups are assembled with ``scheme.k``
    queries, which a ``fixes_k`` scheme (approx_backup: k = 1, one cheap
    backup query per group) decouples from the redundancy-budget ``k`` that
    sizes the pools. ``backend`` selects the jnp or Pallas-kernel hot path
    when ``scheme`` is given by name.

    The PR-1-era ``mode=`` and ``backup_params=`` kwargs are REMOVED: they
    raise ``TypeError`` with a migration message (``strategy=`` /
    ``parity_params=``).
    """

    def __init__(self, fwd=_UNSET, deployed_params=_UNSET,
                 parity_params=_UNSET, *, k=_UNSET, r=_UNSET, m=_UNSET,
                 strategy=_UNSET, scheme=_UNSET, backend=_UNSET, mode=_UNSET,
                 delay_fn=_UNSET, encode_fn=_UNSET, decode_fn=_UNSET,
                 default_prediction=_UNSET, slo_ms=_UNSET,
                 backup_params=_UNSET, parity_fwd=_UNSET, scenario=_UNSET,
                 scenario_seed=_UNSET, scenario_time_scale=_UNSET,
                 scenario_horizon_ms=_UNSET, batching=_UNSET,
                 spec: Optional[DeploymentSpec] = None):
        """``r > 1`` (paper §3.5): ``parity_params`` is a list of r parity
        models, each trained to the j-th Vandermonde combination; r parity
        queries are dispatched per coding group and the decoder solves the
        linear system for up to r concurrent unavailabilities. ``r`` and
        ``backend`` default to the scheme's own values when a scheme
        *instance* is passed; an explicit mismatch raises.

        ``parity_fwd`` — forward function for the parity-pool workers when
        the parity model is a *different architecture* from the deployed
        model (the approx_backup scheme's cheap backup model); defaults to
        ``fwd``.

        ``scenario`` — a fault ``Scenario`` (instance or registered name from
        ``repro.serving.scenarios``, e.g. ``"crash"``); its hazards are
        realized once and injected as per-instance delays through the same
        windows the DES applies, composing with any user ``delay_fn``.
        ``scenario_time_scale`` maps scenario milliseconds to wall-clock
        milliseconds (1.0 = real time); recurring hazards are realized out
        to ``scenario_horizon_ms`` sim-ms, so injection stops after
        ``scenario_horizon_ms * scenario_time_scale`` wall-clock ms —
        raise it for longer experiments."""
        passed = {name: v for name, v in {
            "fwd": fwd, "deployed_params": deployed_params,
            "parity_params": parity_params, "k": k, "r": r, "m": m,
            "strategy": strategy, "scheme": scheme, "backend": backend,
            "delay_fn": delay_fn, "encode_fn": encode_fn,
            "decode_fn": decode_fn,
            "default_prediction": default_prediction, "slo_ms": slo_ms,
            "parity_fwd": parity_fwd,
            "scenario": scenario, "scenario_seed": scenario_seed,
            "scenario_time_scale": scenario_time_scale,
            "scenario_horizon_ms": scenario_horizon_ms,
            "batching": batching}.items() if v is not _UNSET}
        # PR-1-era spellings: removed after one deprecation release
        if mode is not _UNSET:
            raise TypeError(
                "ParMFrontend(mode=...) was removed; pass strategy= (a "
                "registered ResilienceStrategy name or instance)")
        if backup_params is not _UNSET:
            raise TypeError(
                "ParMFrontend(backup_params=...) was removed; approximate "
                "backups are the coded 'approx_backup' scheme — pass "
                "parity_params= (and parity_fwd= for a cheaper "
                "architecture)")
        if spec is None:
            # legacy kwarg surface: remap the old spellings, then build the
            # spec from ONLY the kwargs actually passed — every default
            # comes from DeploymentSpec itself, so the two construction
            # surfaces cannot drift
            kw = dict(passed)
            if "deployed_params" in kw:
                kw["params"] = kw.pop("deployed_params")
            if kw.get("batching") is None:         # legacy "no policy"
                kw.pop("batching", None)
            spec = DeploymentSpec(**kw)
            warnings.warn(
                "the ParMFrontend kwarg surface is a legacy shim; build a "
                "DeploymentSpec and use repro.serving.api.deploy (or "
                "ParMFrontend(spec=...))", DeprecationWarning, stacklevel=2)
        elif passed:
            # a legacy kwarg next to spec= would be silently ignored —
            # deploying with different semantics than the caller wrote
            raise TypeError(
                f"pass either spec= or the legacy kwargs, not both "
                f"(also got {sorted(passed)})")
        self.spec = spec
        self._build(spec)

    # ------------------------------------------------------------------
    def _build(self, spec: DeploymentSpec):
        if spec.fwd is None or spec.params is None:
            # fail at construction, not as a worker-thread crash that only
            # surfaces as futures hanging until their timeout
            raise ValueError(
                "ParMFrontend runs real inference: fwd= and "
                "deployed_params= (spec.fwd / spec.params) are required")
        fwd, m, k = spec.fwd, spec.m, spec.k
        self.strategy = get_strategy(spec.strategy)
        scheme = spec.scheme
        if scheme is None:
            scheme = self.strategy.scheme or "sum"
        # validates k / r / backend against scheme instances
        self.scheme = get_scheme(scheme, k=k, r=spec.r, backend=spec.backend)
        self.k = k
        # group assembly follows the scheme's own group size: a fixes_k
        # scheme (approx_backup) decouples it from the budget k
        self.group_k = self.scheme.k if self.strategy.coded else k
        # a scheme may fix its own parity count (replication: r = k)
        self.r = self.scheme.r if self.strategy.coded else \
            (1 if spec.r is None else spec.r)
        # the deployment's own resolved scheme OBJECT: controller
        # de-escalation restores this instance (not a fresh registry
        # default under the same name), and group dispatch routes by
        # identity against it
        self._base_scheme = self.scheme
        self._base_r = self.r
        self.batching = spec.batching
        self._controller = None if spec.controller is None else \
            get_controller(spec.controller)
        # Parity pools exist from construction (worker threads cannot be
        # spawned, and JAX re-warmed, mid-run), in TWO families:
        #   pools 0 .. r-1             — the deployment's own parity models;
        #   pools r .. r+agn_r-1       — escalation pools running the
        #                                *deployed* parameters, sized by
        #                                Controller.escalation_r.
        # Every controller adjustment that is not an exact return to the
        # deployment base dispatches to the second family — its scheme must
        # be model_agnostic (parity input is a combination of plain
        # queries), so the deployed model IS its parity model.  The base
        # family never serves an escalated group: its pools run trained
        # parity models (e.g. ParM 'sum') whose outputs another code's
        # decoder must not consume.
        self._agn_base = self.r
        self._agn_r = 0
        if self._controller is not None and self.strategy.coded:
            esc = getattr(self._controller, "escalation_r",
                          self._controller.max_r)
            self._agn_r = max(0, int(esc(self.r)))
        self.r_pools = self.r + self._agn_r
        self._user_encode = spec.encode_fn
        self.encode_fn = spec.encode_fn or (
            lambda q: np.asarray(self.scheme.encode(q)))
        self.decode_fn = spec.decode_fn
        self.default_prediction = spec.default_prediction
        self.slo_ms = spec.slo_ms
        self.queries = {}
        self.groups = {}   # gid -> {"members", "outs", "parity": {j: out}}
        self.gid_of = {}
        self.lock = threading.Lock()
        self._next_gid = 0
        self._pending_group = []
        self._early_outs = {}   # outputs that beat their group's assembly
        self._timers = set()    # armed default_slo timers; cancelled at
                                # shutdown so none fires into a dead frontend
        self._shutdown = False
        self.cancelled_queries = 0    # tombstoned originals skipped at dequeue
        self.cancelled_parities = 0   # undispatched parities dropped
        self._n_batches = 0           # main-pool inference calls
        self._n_batch_queries = 0     # queries those calls carried
        # Byzantine bookkeeping: responses the scheme voted out, and how
        # many of the affected predictions were served clean regardless.
        # _detecting is finalized below once the scenario adapters exist:
        # screening only runs when corruption can actually be injected
        self._detecting = False
        self.corrupted_detected = 0
        self.corrupted_corrected = 0
        # controller bookkeeping: the window clock runs in *scenario* ms
        # (wall-clock since construction divided by scenario_time_scale),
        # ticked at the top of submit() and drained at shutdown
        self._origin = time.perf_counter()
        self._adjust_log = []
        self._pending_adj = None        # (Adjustment, window_index) deferred
                                        # to the next group boundary
        self._window_idx = 0
        self._window_counted = set()    # qids already bucketed in a window
        self._ctl_prev = {"detected": 0, "cancel": 0}
        self._last_submit_ms = 0.0
        self._ctl_state = None
        self.parity_served = 0          # parity inference items served

        layout = self.strategy.layout(m, k, self.r)
        scenario = spec.scenario
        if scenario is None:
            scenario = self.strategy.scenario
        self.scenario = None
        delay_fn = spec.delay_fn
        corrupt_fn = None
        if scenario is not None:
            # fault-injection adapters off ONE realized plan: the
            # scenario's hazard windows become per-instance delays
            # (composed with any user delay_fn), and its corrupt windows
            # per-instance output corruption
            self.scenario = get_scenario(scenario)
            pool_sizes = {"main": layout.main}
            if self.strategy.coded and layout.parity:
                for j in range(self.r_pools):
                    pool_sizes[f"parity{j}"] = layout.parity
            delay_fn, corrupt_fn = self.scenario.adapters(
                pool_sizes, seed=spec.scenario_seed,
                horizon_ms=spec.scenario_horizon_ms,
                time_scale=spec.scenario_time_scale, extra=delay_fn)
        # screening costs an lstsq vote under the frontend lock per
        # arrival once a group holds surplus responses — only pay it when
        # corruption can actually exist (the DES gates its revote on a
        # non-empty candidate set the same way)
        self._corrupting = corrupt_fn is not None
        self._detecting = self.strategy.coded and \
            scheme_capabilities(self.scheme).detects_errors and \
            corrupt_fn is not None
        self.main_q = queue.Queue()
        self.workers = []
        self._main_workers = []
        # a controller may retune max_size at runtime, so its main workers
        # always carry the (rebindable) policy object; run() re-reads
        # max_size every dequeue, so a max_size=1 policy batches nothing
        main_batching = self.batching if (
            self.batching.max_size > 1
            or self._controller is not None) else None
        for i in range(layout.main):
            w = ModelInstance(instance_id("main", i), self.main_q, fwd,
                              spec.params, self._on_model_done, delay_fn,
                              skip_fn=self._should_skip,
                              batching=main_batching,
                              on_batch=self._note_batch,
                              on_done_batch=self._on_model_batch_done,
                              corrupt_fn=corrupt_fn)
            w.start()
            self.workers.append(w)
            self._main_workers.append(w)
        if self.strategy.coded:
            parity_params = spec.parity_params
            if parity_params is None:
                # replication-style schemes: the "parity model" is the
                # deployed model itself (decode is a passthrough)
                parity_params = [spec.params] * self.r
            elif not isinstance(parity_params, (list, tuple)):
                parity_params = [parity_params]
            assert len(parity_params) == self.r, \
                (len(parity_params), self.r)
            # escalation pools run the DEPLOYED model end to end: plain
            # fwd + spec.params, never spec.parity_fwd (which may be a
            # different cheap-backup architecture trained for the base
            # code) — a model_agnostic scheme's parity input is a
            # combination of plain queries, so the deployed model IS its
            # parity model
            parity_params = list(parity_params) + \
                [spec.params] * self._agn_r
            self.parity_qs = []
            for j in range(self.r_pools):
                pq = queue.Queue()
                self.parity_qs.append(pq)
                p_fwd = (spec.parity_fwd or fwd) if j < self.r else fwd
                for i in range(layout.parity):
                    w = ModelInstance(instance_id(f"parity{j}", i), pq,
                                      p_fwd,
                                      parity_params[j],
                                      self._on_parity_done, delay_fn,
                                      skip_fn=self._should_skip,
                                      corrupt_fn=corrupt_fn)
                    w.start()
                    self.workers.append(w)
            self.parity_q = self.parity_qs[0]      # back-compat alias
        if self._controller is not None:
            # the base the controller's de-escalation returns to: the
            # deployment's own knobs (same construction as the DES)
            self._ctl_state = self._controller.init(Adjustment(
                scheme=self.scheme.name if self.strategy.coded else None,
                r=self.r if self.strategy.coded else None,
                batch_max_size=self.batching.max_size))

    # ----------------------------------------------------- controller ---
    def _ctl_tick(self, now):
        """Advance the window clock to ``now`` (wall-clock seconds),
        closing every observation window that has fully elapsed.  Runs at
        the top of ``submit`` — the same clock edge the DES models by
        sorting its ctl events ahead of same-time arrivals."""
        ts = self.spec.scenario_time_scale
        now_ms = (now - self._origin) * 1e3 / ts
        with self.lock:
            self._last_submit_ms = max(self._last_submit_ms, now_ms)
        while self._close_window(now_ms):
            pass

    def _close_window(self, now_ms=None):
        """Close window ``[widx*wlen, (widx+1)*wlen)``: bucket completions
        by completion timestamp (scenario ms), counters by per-window
        delta, hand the window to the controller, and apply its adjustment
        — immediately when no group is assembling, else deferred to the
        next group boundary.  Latencies are reported in scenario ms so
        controller thresholds mean the same thing on both engines.

        Returns ``True`` iff a window was closed.  The elapsed check runs
        UNDER the lock: two concurrent ``submit()``s may both observe an
        expired window outside any lock, race into this method, and the
        loser must not close the *next* window early — it re-reads
        ``_window_idx`` under the lock and bails when the winner already
        advanced it past ``now_ms``.  ``now_ms=None`` is the shutdown
        drain: close windows out to the last submit, then stop."""
        ctl = self._controller
        ts = self.spec.scenario_time_scale
        wlen = float(ctl.window_ms)
        with self.lock:
            widx = self._window_idx
            t1 = (widx + 1) * wlen
            if now_ms is not None:
                if t1 > now_ms:
                    return False
            elif widx * wlen >= self._last_submit_ms:
                return False
            recs = []
            for qid, q in self.queries.items():
                if qid in self._window_counted or not q.event.is_set() \
                        or q.completed_by == "flushed":
                    continue
                fin_ms = (q.finish - self._origin) * 1e3 / ts
                if fin_ms < t1:
                    self._window_counted.add(qid)
                    recs.append((q.latency_ms / ts,
                                 q.completed_by == "parity"))
            cancel = self.cancelled_queries + self.cancelled_parities
            win = build_window(
                widx, widx * wlen, t1, recs,
                corrupted_detected=self.corrupted_detected
                - self._ctl_prev["detected"],
                cancellations=cancel - self._ctl_prev["cancel"])
            self._ctl_prev["detected"] = self.corrupted_detected
            self._ctl_prev["cancel"] = cancel
            adj, self._ctl_state = ctl.observe(self._ctl_state, win)
            self._window_idx = widx + 1
            if adj is not None:
                if self._pending_group:
                    self._pending_adj = (adj, widx)
                else:
                    self._apply_adjustment(adj, widx)
        return True

    def _apply_adjustment(self, adj, widx):
        """Lock held.  Retune the CURRENT knobs; in-flight groups keep the
        scheme/r/det they captured at assembly.  Scheme/r apply only to
        coded strategies; batching to any.  The log records the
        post-adjustment knobs — the identical tuples the DES appends, so
        the differential battery compares decision sequences verbatim."""
        if self.strategy.coded and (adj.scheme is not None
                                    or adj.r is not None):
            name = adj.scheme if adj.scheme is not None \
                else self.scheme.name
            want_r = adj.r if adj.r is not None else self.r
            if name == self._base_scheme.name and want_r == self._base_r:
                # de-escalation: restore the deployment's own scheme
                # INSTANCE — re-resolving by name would silently swap a
                # non-default-configured scheme for a registry default,
                # and identity (`is`) is what routes groups back to the
                # trained parity pools
                new = self._base_scheme
            else:
                new = get_scheme(name, k=self.k, r=want_r,
                                 backend=self.spec.backend)
                if not scheme_capabilities(new).model_agnostic:
                    # escalation pools run the deployed parameters; a
                    # trained-parity scheme's decoder would consume the
                    # wrong model's outputs and serve numerically wrong
                    # reconstructions
                    raise ValueError(
                        f"controller adjustment to scheme {name!r} "
                        f"(r={new.r}) is not the deployment base and not "
                        f"model_agnostic — runtime escalation can only "
                        f"target schemes whose parity pool runs the "
                        f"deployed parameters")
                if new.r > self._agn_r:
                    raise ValueError(
                        f"controller adjustment needs r={new.r} "
                        f"escalation pools but only {self._agn_r} were "
                        f"provisioned — raise Controller.escalation_r")
            self.scheme, self.r, self.group_k = new, new.r, new.k
            self._detecting = scheme_capabilities(new).detects_errors and \
                self._corrupting
        if adj.batch_max_size is not None:
            self.batching = replace(self.batching,
                                    max_size=max(1, adj.batch_max_size))
            for w in self._main_workers:
                w.batching = self.batching
        self._adjust_log.append(
            (widx,
             self.scheme.name if self.strategy.coded else None,
             self.r if self.strategy.coded else None,
             self.batching.max_size))

    # ------------------------------------------------------------------
    def submit(self, qid, x):
        """x: one query batch (leading batch dim, usually 1)."""
        q = Query(qid, x, arrival=time.perf_counter())
        if self._controller is not None:
            self._ctl_tick(q.arrival)
        to_encode = None
        with self.lock:
            if self._shutdown:
                # the workers already consumed their shutdown sentinels —
                # enqueuing now would hand back a future that hangs until
                # its timeout instead of failing fast
                raise RuntimeError(
                    "ParMFrontend is shut down; deploy a new session")
            self.queries[qid] = q
            if self.strategy.coded:
                self._pending_group.append(qid)
                self.gid_of[qid] = self._next_gid
                if len(self._pending_group) == self.group_k:
                    gid = self._next_gid
                    members = list(self._pending_group)
                    self._pending_group.clear()
                    self._next_gid += 1
                    # outputs that finished before the group existed
                    outs = {m: self._early_outs.pop(m) for m in members
                            if m in self._early_outs}
                    # capture the CURRENT knobs: a controller adjustment
                    # landing later retunes only subsequent groups — this
                    # one decodes under the scheme/r it was encoded with
                    self.groups[gid] = {"members": members, "outs": outs,
                                        "parity": {}, "corrupt_m": set(),
                                        "scheme": self.scheme,
                                        "r": self.r,
                                        "det": self._detecting}
                    to_encode = (gid, np.stack(
                        [self.queries[m].data for m in members]),
                        self.scheme, self.r)
                    if self._pending_adj is not None:
                        # a deferred adjustment lands exactly at this
                        # group boundary — the DES applies it at the same
                        # edge of its event clock
                        adj, widx = self._pending_adj
                        self._pending_adj = None
                        self._apply_adjustment(adj, widx)
            # enqueue under the same lock as the _shutdown check: a
            # concurrent shutdown() either sees these items in its queue
            # drain, or this submit already raised — never an item enqueued
            # onto dead workers after the drain
            for _ in range(self.strategy.mirror):
                self.main_q.put(("query", qid, x))
        if to_encode is not None:
            # frontend-side encode (1/k network overhead, §3.1); r parity
            # queries, one per parity model (§3.5). Runs outside the lock —
            # a JAX dispatch here would stall every completion callback —
            # which is safe because no parity output for this gid can arrive
            # before these puts
            gid, stacked, g_scheme, g_r = to_encode
            # encode under the scheme the GROUP captured — self.scheme may
            # already point at a controller-adjusted one.  A user encode_fn
            # encodes the DEPLOYMENT's code: groups captured under a
            # controller-escalated scheme must use that scheme's own
            # encoder, or decode would consume parities of the wrong code.
            base = g_scheme is self._base_scheme
            if self._user_encode is not None and base:
                parities = np.asarray(self._user_encode(stacked))
            else:
                parities = np.asarray(g_scheme.encode(stacked))
            # routing: base-scheme groups go to the trained parity pools
            # 0..r-1; escalated groups to the deployed-params escalation
            # pools at offset _agn_base — a trained parity model's outputs
            # must never enter another code's decoder
            ofs = 0 if base else self._agn_base
            with self.lock:
                dead = self._shutdown
                if not dead:
                    for j in range(g_r):
                        self.parity_qs[ofs + j].put(("parity", (gid, j),
                                                     parities[j]))
            if dead:
                # shutdown won the race while we encoded: flush this
                # group's unanswered members like any shutdown leftover
                # instead of leaving their futures to hang
                for m in self.groups[gid]["members"]:
                    q_ = self.queries.get(m)
                    if q_ is not None and not q_.event.is_set():
                        q_.fulfill(self.default_prediction, "flushed")
        if self.strategy.slo_default and self.slo_ms is not None:
            t = threading.Timer(self.slo_ms / 1e3, self._default_fire)
            t.args = (qid, t)
            t.daemon = True
            with self.lock:
                if not self._shutdown:
                    self._timers.add(t)
                    t.start()
        return q

    def _default_fire(self, qid, timer):
        with self.lock:
            # guard against firing into a torn-down frontend: shutdown()
            # cancels armed timers and flips the flag first
            if self._shutdown:
                return
            self._timers.discard(timer)
            q = self.queries.get(qid)
        if q is not None:
            q.fulfill(self.default_prediction, "default")

    # ------------------------------------------------------------------
    def _should_skip(self, tag, payload):
        """Redundant-work tombstone check, called by workers at dequeue.

        An *original* whose prediction already arrived (parity decode won,
        a mirror replica won, or the SLO default fired) is skipped; an
        undispatched *parity* query whose group has every original answered
        is dropped.  Mirrors the DES's dequeue-time cancellation exactly.
        """
        with self.lock:
            if tag == "query":
                q = self.queries.get(payload)
                if q is not None and q.event.is_set():
                    self.cancelled_queries += 1
                    return True
                return False
            # tag == "parity": payload is (gid, j)
            info = self.groups.get(payload[0])
            if info is not None and all(
                    self.queries[m].event.is_set()
                    for m in info["members"]):
                self.cancelled_parities += 1
                return True
            return False

    def _note_batch(self, n):
        with self.lock:
            self._n_batches += 1
            self._n_batch_queries += n

    # ------------------------------------------------------------------
    def _on_model_done(self, tag, qid, out):
        """Single-item completion: the batch-atomic path with one pair."""
        del tag
        self._on_model_batch_done([(qid, out)])

    def _on_model_batch_done(self, pairs):
        """Batch-atomic completion for adaptive batching: record EVERY
        batch-mate's output before any decode decision runs.  Delivering
        the outputs one `_on_model_done` at a time would let the first
        member's `_maybe_decode` treat a batch-mate as missing — and fulfill
        it with an approximate parity reconstruction — even though its exact
        output was computed in the very same inference call."""
        if not self.strategy.coded:
            for qid, out in pairs:
                self.queries[qid].fulfill(out, "model")
            return
        with self.lock:
            touched = {}
            for qid, out in pairs:
                gid = self.gid_of.get(qid)
                info = self.groups.get(gid)
                if info is not None:
                    info["outs"][qid] = out
                    touched[gid] = info
                else:
                    self._early_outs[qid] = out
            # Byzantine screening BEFORE fulfillment: a recorded output a
            # detects_errors scheme votes out must neither answer its own
            # query nor poison later decodes of its group-mates
            for gid, info in touched.items():
                self._screen(info)
            for qid, out in pairs:
                gid = self.gid_of.get(qid)
                info = self.groups.get(gid)
                if info is not None and qid in info["corrupt_m"] and \
                        qid not in info["outs"]:
                    continue        # voted out; _maybe_decode serves it
                self.queries[qid].fulfill(out, "model")
            self._decode_touched(touched)

    def _on_parity_done(self, tag, key, out):
        gid, j = key
        with self.lock:
            self.parity_served += 1     # parity inference actually ran —
                                        # the resource axis of the
                                        # adaptive-redundancy frontier
            info = self.groups.get(gid)
            if info is None:
                return
            info["parity"][j] = out
            self._screen(info)
            self._maybe_decode(gid, info)

    def _recoverable(self, scheme, miss_mask, parity_avail):
        """Which missing rows can be reconstructed now? Delegates to the
        shared ``recoverable_rows`` rule — the same function the DES consults
        — so the two serving layers cannot drift on decode decisions.
        ``scheme`` is the one the GROUP captured at assembly, not the
        frontend's (possibly controller-adjusted) current one."""
        return recoverable_rows(scheme, miss_mask, parity_avail)

    def _screen(self, info):
        """Byzantine vote (``detects_errors`` schemes), with the lock held,
        after new responses were recorded: hand the group's recorded
        responses to ``scheme.flag_errors`` and evict whatever it votes
        out, so a corrupted response neither answers its own query nor
        poisons later decodes of its group-mates.  A voted-out member the
        clean remainder can re-decode right now is left missing for
        ``_maybe_decode`` (which serves it clean and counts it corrected);
        one it cannot is fulfilled with the suspect output — detected but
        uncorrectable, matching the DES's end-of-run drain.  A voted-out
        response whose query was already answered counts as corrected only
        if that answer came from a clean parity reconstruction."""
        if not info["det"]:
            return
        members = info["members"]
        g_scheme, g_r = info["scheme"], info["r"]
        mo, po = info["outs"], info["parity"]
        member_avail = np.array([m in mo for m in members])
        parity_avail = np.array([j in po for j in range(g_r)])
        if member_avail.sum() + parity_avail.sum() <= len(members):
            return                      # no surplus: nothing to vote with
        ref = next(iter(mo.values())) if mo else next(iter(po.values()))
        zeros = np.zeros_like(ref)
        mouts = np.stack([mo.get(m, zeros) for m in members])
        pouts = np.stack([po.get(j, zeros) for j in range(g_r)])
        mflags, pflags = g_scheme.flag_errors(
            mouts, member_avail, pouts, parity_avail)
        for j in np.nonzero(pflags)[0]:
            # eviction is the whole effect: an absent parity can neither be
            # re-delivered nor re-flagged, so no set tracks it
            po.pop(int(j), None)
            self.corrupted_detected += 1
        for i in np.nonzero(mflags)[0]:
            m = members[int(i)]
            out = mo.pop(m)
            info["corrupt_m"].add(m)
            self.corrupted_detected += 1
            q = self.queries[m]
            if q.event.is_set():
                if q.completed_by == "parity":
                    self.corrupted_corrected += 1
                continue
            miss = np.array([mm not in mo for mm in members])
            pa = np.array([j in po for j in range(g_r)])
            if not self._recoverable(g_scheme, miss, pa)[int(i)]:
                # uncorrectable: serve the suspect output rather than hang
                q.fulfill(out, "model")

    def _decode_plan(self, info):
        """Decode decision for one group, with the lock held: returns
        ``(missing, miss_mask, parity_avail)`` — or None when nothing
        recoverable is still unanswered.  A member is missing when the group
        holds no (trustworthy) response for it — a voted-out corrupt
        response leaves its member missing even though the query may already
        be answered, so the decoder never feeds known-bad data (or
        placeholder zeros) into a reconstruction."""
        if not info["parity"]:
            return None
        members = info["members"]
        g_scheme, g_r = info["scheme"], info["r"]
        miss_mask = np.array([m not in info["outs"] for m in members])
        parity_avail = np.array([j in info["parity"]
                                 for j in range(g_r)])
        miss_mask = self._recoverable(g_scheme, miss_mask, parity_avail)
        # only still-unanswered members need serving; answered ones stay in
        # miss_mask so the decode math never uses their absent/evicted data
        missing = [m for m, miss in zip(members, miss_mask)
                   if miss and not self.queries[m].event.is_set()]
        if not missing:
            return None
        return missing, miss_mask, parity_avail

    def _fulfill_clean(self, info, m, recon):
        q = self.queries[m]
        newly = not q.event.is_set()
        q.fulfill(recon, "parity")
        if newly and m in info["corrupt_m"]:
            # this member's own response was voted out as corrupted;
            # it was just served from a clean reconstruction instead
            self.corrupted_corrected += 1

    def _group_outs(self, info):
        """Member outputs stacked [k, ...] (zeros at missing slots — masked
        out of the decode math by the availability coefficients)."""
        any_out = next(iter(info["parity"].values()))
        return np.stack([info["outs"].get(m, np.zeros_like(any_out))
                         for m in info["members"]])

    def _is_fast_plan(self, info, plan):
        """Does this group's decode land on the r=1 subtraction fast path
        (the batchable ``decode_one`` shape)?"""
        missing, miss_mask, _ = plan
        return info["r"] == 1 and len(missing) == 1 and \
            miss_mask.sum() == 1

    def _decode_group(self, info, plan):
        """Per-group decode execution (r=1 fast path: subtraction decoder;
        otherwise the scheme's general masked decode)."""
        missing, miss_mask, parity_avail = plan
        members = info["members"]
        g_scheme, g_r = info["scheme"], info["r"]
        outs = self._group_outs(info)
        if self._is_fast_plan(info, plan):
            j = members.index(missing[0])
            if self.decode_fn is not None:
                recon = self.decode_fn(info["parity"][0], outs, j)
            else:
                recon = np.asarray(g_scheme.decode_one(
                    info["parity"][0], outs, j))
            self._fulfill_clean(info, missing[0], recon)
            return
        any_out = next(iter(info["parity"].values()))
        parity_outs = np.stack([
            info["parity"].get(j, np.zeros_like(any_out))
            for j in range(g_r)])
        recon = np.asarray(g_scheme.decode(
            jnp.asarray(parity_outs), jnp.asarray(outs),
            jnp.asarray(miss_mask), jnp.asarray(parity_avail)))
        for m in missing:
            self._fulfill_clean(info, m, recon[members.index(m)])

    def _maybe_decode(self, gid, info):
        """Called with lock held: reconstruct up to ``n_parities_arrived``
        missing predictions for ONE group (the single-group entry point —
        parity arrivals; batch-atomic completions drain through
        ``_decode_touched``)."""
        del gid
        plan = self._decode_plan(info)
        if plan is not None:
            self._decode_group(info, plan)

    def _decode_touched(self, touched):
        """Batched decode drain for a batch-atomic completion, with the lock
        held: gather EVERY touched group's decode decision first, then
        reconstruct all recoverable groups together — fast-path (r=1,
        one-missing) groups sharing a scheme instance and output shape go
        through ONE ``decode_one_many`` multigroup launch, general-path
        groups sharing a scheme through one vmapped ``decode_many`` solve;
        schemes without the batched surface (or a user ``decode_fn``, or
        ``_FORCE_DECODE="pergroup"``) keep the exact per-group path."""
        plans = []
        for gid, info in touched.items():
            plan = self._decode_plan(info)
            if plan is not None:
                plans.append((info, plan))
        batch_min = 1 if _FORCE_DECODE == "batched" else 2
        if _FORCE_DECODE == "pergroup" or len(plans) < batch_min:
            for info, plan in plans:
                self._decode_group(info, plan)
            return
        fast, general, rest = {}, {}, []
        for info, plan in plans:
            g_scheme = info["scheme"]
            shape = next(iter(info["parity"].values())).shape
            if self._is_fast_plan(info, plan) and self.decode_fn is None \
                    and hasattr(type(g_scheme), "decode_one_many"):
                fast.setdefault((id(g_scheme), shape), []).append(
                    (info, plan))
            elif hasattr(type(g_scheme), "decode_many"):
                general.setdefault((id(g_scheme), shape), []).append(
                    (info, plan))
            else:
                rest.append((info, plan))
        for bucket in fast.values():
            if len(bucket) < batch_min:
                rest.extend(bucket)
                continue
            g_scheme = bucket[0][0]["scheme"]
            idxs = [info["members"].index(plan[0][0])
                    for info, plan in bucket]
            parity_outs = np.stack([info["parity"][0]
                                    for info, _ in bucket])
            outs = np.stack([self._group_outs(info)
                             for info, _ in bucket])
            recons = np.asarray(g_scheme.decode_one_many(
                jnp.asarray(parity_outs), jnp.asarray(outs),
                np.asarray(idxs)))
            for (info, plan), recon in zip(bucket, recons):
                self._fulfill_clean(info, plan[0][0], recon)
        for bucket in general.values():
            if len(bucket) < batch_min:
                rest.extend(bucket)
                continue
            g_scheme = bucket[0][0]["scheme"]
            g_r = bucket[0][0]["r"]
            any_out = next(iter(bucket[0][0]["parity"].values()))
            parity_outs = np.stack([
                np.stack([info["parity"].get(j, np.zeros_like(any_out))
                          for j in range(g_r)]) for info, _ in bucket])
            outs = np.stack([self._group_outs(info)
                             for info, _ in bucket])
            miss = np.stack([plan[1] for _, plan in bucket])
            pa = np.stack([plan[2] for _, plan in bucket])
            recons = np.asarray(g_scheme.decode_many(
                jnp.asarray(parity_outs), jnp.asarray(outs), miss, pa))
            for (info, plan), recon in zip(bucket, recons):
                members = info["members"]
                for m in plan[0]:
                    self._fulfill_clean(info, m, recon[members.index(m)])
        for info, plan in rest:
            self._decode_group(info, plan)

    # ------------------------------------------------------------------
    def wait_all(self, timeout=60.0):
        deadline = time.time() + timeout
        for q in self.queries.values():
            q.event.wait(max(0.0, deadline - time.time()))
        return all(q.event.is_set() for q in self.queries.values())

    def shutdown(self):
        """Idempotent teardown: cancel armed SLO timers, wake every worker
        with a shutdown sentinel (blocking ``get`` — no poll loop to time
        out), flush the partial trailing coding group."""
        with self.lock:
            already = self._shutdown
            self._shutdown = True
            timers, self._timers = self._timers, set()
        for t in timers:
            t.cancel()
        if not already:
            for w in self.workers:
                w.stop = True
            for w in self.workers:
                # one sentinel per worker on its own queue: a worker blocked
                # in get() wakes instantly; a busy one exits after its item
                w.pool_q.put(_SHUTDOWN)
        for w in self.workers:
            w.join(timeout=5.0)
        # account abandoned queue backlog through the same tombstone rule a
        # worker applies at dequeue: a redundant item left behind (its query
        # already answered, or its parity group fully done) counts as
        # cancelled — exactly what the DES reports, where every queued item
        # is eventually popped.  Non-redundant leftovers stay uncounted.
        seen = set()
        for w in self.workers:
            if id(w.pool_q) in seen:
                continue
            seen.add(id(w.pool_q))
            while True:
                try:
                    item = w.pool_q.get_nowait()
                except queue.Empty:
                    break
                if item is not _SHUTDOWN:
                    self._should_skip(item[0], item[1])
        # a workload that isn't a multiple of k leaves a partial coding group
        # behind; fulfill its members so wait_all() can't hang on them
        with self.lock:
            leftovers = list(self._pending_group)
            self._pending_group.clear()
        for qid in leftovers:
            q = self.queries.get(qid)
            if q is not None and not q.event.is_set():
                q.fulfill(self.default_prediction, "flushed")
        if self._controller is not None and not already:
            # drain the window clock out to the last submit — the DES
            # closes the same set (every window whose start precedes the
            # end of arrivals), so the decision sequences stay comparable
            while self._close_window():
                pass

    def stats(self) -> ServingReport:
        """Typed ``ServingReport`` (dict-compatible) with the same fields the
        DES (``repro.serving.simulator.simulate``) reports. Queries flushed
        at shutdown appear in ``completed_by`` but are excluded from the
        latency numbers — their finish time is a shutdown artifact."""
        with self.lock:
            queries = list(self.queries.values())
            cq, cp = self.cancelled_queries, self.cancelled_parities
            nb, nbq = self._n_batches, self._n_batch_queries
            cd, cc = self.corrupted_detected, self.corrupted_corrected
            adjustments = tuple(self._adjust_log)
            windows, ps = self._window_idx, self.parity_served
        lats = np.array([q.latency_ms for q in queries
                         if q.event.is_set() and q.completed_by != "flushed"])
        by = {}
        for q in queries:
            if q.completed_by:
                by[q.completed_by] = by.get(q.completed_by, 0) + 1

        def pct(p):
            return float(np.percentile(lats, p)) if len(lats) else float("nan")

        return ServingReport(
            engine="threads",
            strategy=self.strategy.name,
            scheme=self.scheme.name if self.strategy.coded else None,
            scenario=self.scenario.name if self.scenario else None,
            n=int(len(lats)),
            median_ms=pct(50),
            p99_ms=pct(99),
            p999_ms=pct(99.9),
            mean_ms=float(lats.mean()) if len(lats) else float("nan"),
            max_ms=float(lats.max()) if len(lats) else float("nan"),
            completed_by=by,
            reconstructions=by.get("parity", 0),
            cancelled_queries=cq,
            cancelled_parities=cp,
            batches=nb,
            mean_batch_size=(nbq / nb) if nb else 1.0,
            corrupted_detected=cd,
            corrected=cc,
            controller=self._controller.name if self._controller else None,
            windows=windows,
            adjustments=adjustments,
            parity_served=ps)
