"""Checkpointing: pytree <-> .npz with structure-preserving key paths."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(params):
    leaves, treedef = jax.tree.flatten(params)
    return leaves, treedef


def save(path, params, step=None, extra=None):
    leaves, treedef = _flatten(params)
    arrs, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if a.dtype.kind not in "fiub":         # ml_dtypes (bf16, fp8, ...)
            a = a.astype(np.float32)
        arrs[f"leaf_{i}"] = a
    meta = {"treedef": str(treedef), "n_leaves": len(leaves),
            "dtypes": dtypes, "step": step, "extra": extra or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __meta__=json.dumps(meta), **arrs)


def load(path, like):
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    import jax.numpy as jnp
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        leaves = [z[f"leaf_{i}"] for i in range(meta["n_leaves"])]
    like_leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(like_leaves), "leaf count mismatch"
    out = []
    for got, want in zip(leaves, like_leaves):
        assert got.shape == want.shape, (got.shape, want.shape)
        wdt = jnp.asarray(want).dtype if not hasattr(want, "dtype") \
            else want.dtype
        out.append(np.asarray(jnp.asarray(got).astype(wdt)))
    return jax.tree.unflatten(treedef, out), meta
