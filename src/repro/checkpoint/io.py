"""Checkpointing: pytree <-> .npz with structure-preserving key paths."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(params):
    leaves, treedef = jax.tree.flatten(params)
    return leaves, treedef


def save(path, params, step=None, extra=None):
    leaves, treedef = _flatten(params)
    arrs, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if a.dtype.kind not in "fiub":         # ml_dtypes (bf16, fp8, ...)
            a = a.astype(np.float32)
        arrs[f"leaf_{i}"] = a
    meta = {"treedef": str(treedef), "n_leaves": len(leaves),
            "dtypes": dtypes, "step": step, "extra": extra or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __meta__=json.dumps(meta), **arrs)


def weighted_merge(params_list, weights_list, eps=1e-12):
    """Leaf-wise weighted average of k structurally-identical pytrees:

        merged_leaf = sum_i w_i * leaf_i / (sum_i w_i + eps)

    ``weights_list`` holds one weight pytree per member (same structure as
    the params; leaves broadcastable against the params leaves — per-element
    Fisher diagonals, or scalars for a plain convex combination).  This is
    the merge substrate for Fisher-averaged parity provisioning
    (``repro.core.fisher``): identical members with any positive weights
    merge to (numerically) the members themselves."""
    import jax.numpy as jnp
    assert len(params_list) == len(weights_list) and params_list
    leaves0, treedef = _flatten(params_list[0])
    stacked = [jax.tree.flatten(p)[0] for p in params_list]
    wstacked = [jax.tree.flatten(w)[0] for w in weights_list]
    assert all(len(s) == len(leaves0) for s in stacked), "leaf count mismatch"
    assert all(len(s) == len(leaves0) for s in wstacked), \
        "weight leaf count mismatch"
    out = []
    for li in range(len(leaves0)):
        num, den = None, None
        for p_leaves, w_leaves in zip(stacked, wstacked):
            leaf = jnp.asarray(p_leaves[li], jnp.float32)
            w = jnp.broadcast_to(jnp.asarray(w_leaves[li], jnp.float32),
                                 leaf.shape)
            num = w * leaf if num is None else num + w * leaf
            den = w if den is None else den + w
        dtype = jnp.asarray(leaves0[li]).dtype
        out.append((num / (den + eps)).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def load(path, like):
    """Restore into the structure of ``like`` (shape/dtype verified)."""
    import jax.numpy as jnp
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        leaves = [z[f"leaf_{i}"] for i in range(meta["n_leaves"])]
    like_leaves, treedef = jax.tree.flatten(like)
    assert len(leaves) == len(like_leaves), "leaf count mismatch"
    out = []
    for got, want in zip(leaves, like_leaves):
        assert got.shape == want.shape, (got.shape, want.shape)
        wdt = jnp.asarray(want).dtype if not hasattr(want, "dtype") \
            else want.dtype
        out.append(np.asarray(jnp.asarray(got).astype(wdt)))
    return jax.tree.unflatten(treedef, out), meta
