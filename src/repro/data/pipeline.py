"""Deterministic synthetic data pipelines.

No datasets ship with the container, so both the LM substrate and the paper's
image-classification reproduction run on synthetic-but-learnable tasks:

* ``lm_stream``      — order-2 Markov token stream (a model that learns the
                       transition table drops well below unigram entropy).
* ``cluster_images`` — Gaussian-cluster "images": class c has a fixed random
                       template; samples are template + noise. Mirrors the
                       difficulty knobs of CIFAR-like tasks while training in
                       seconds on 1 CPU core; used for paper Figs 6/7/9/10
                       reproductions.
* ``batched``        — epoch shuffler/batcher.
"""
from __future__ import annotations

import numpy as np


def lm_stream(vocab, n_tokens, seed=0, branch=4):
    """Order-2 Markov chain over ``vocab`` with ``branch`` successors per
    state — entropy ~= log(branch) << log(vocab)."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, vocab, branch))
    probs = rng.dirichlet(np.ones(branch), size=(vocab, vocab))
    out = np.empty(n_tokens, np.int32)
    a, b = rng.integers(0, vocab, 2)
    for i in range(n_tokens):
        nxt = rng.choice(succ[a, b], p=probs[a, b])
        out[i] = nxt
        a, b = b, nxt
    return out


def lm_batches(vocab, batch, seq, n_batches, seed=0):
    stream = lm_stream(vocab, batch * (seq + 1) * n_batches + 1, seed)
    toks = stream[: batch * (seq + 1) * n_batches]
    return toks.reshape(n_batches, batch, seq + 1)[:, :, : seq + 1]


def cluster_images(n, n_classes=10, image_shape=(32, 32, 3), noise=1.0,
                   seed=0, templates=None):
    """Returns (x [n, *image_shape] float32, y [n] int32, templates)."""
    rng = np.random.default_rng(seed)
    if templates is None:
        templates = rng.normal(0, 1, size=(n_classes,) + tuple(image_shape))
    y = rng.integers(0, n_classes, size=n)
    x = templates[y] + rng.normal(0, noise, size=(n,) + tuple(image_shape))
    return x.astype(np.float32), y.astype(np.int32), templates


def batched(x, y, batch, seed=0, epochs=1):
    rng = np.random.default_rng(seed)
    n = len(x)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            sel = order[i:i + batch]
            yield x[sel], y[sel]
