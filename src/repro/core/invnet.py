"""Coded-InvNet-style scheme: encode through an invertible coupling network
(Coded-InvNet for Resilient Prediction Serving Systems, arXiv:2106.06445;
PAPERS.md).

ParM combines *queries* linearly and asks a trained parity model to act
linearly over them.  Coded-InvNet flips the burden onto the representation:
conduct the linear code in the latent space of a small invertible network g,

    p_j  =  g^-1( sum_i  c_ji * g(x_i) )                (encode)

and serve the parities with the DEPLOYED model itself — no parity training.
Whenever the deployed model factors through g (F = head . g, the
Coded-InvNet training recipe), the parity output is *exactly* the linear
combination of the member outputs,

    F(p_j) = head( sum_i c_ji g(x_i) ) = sum_i c_ji F(x_i)   (head linear),

so the inherited ``LinearScheme`` output-code decode is exact inversion —
bit-exact on an integer-valued invertible substrate (locked by test).  For
arbitrary deployed models the same pipeline runs as an approximation, just
like fisher's convex parity queries.

``g`` here is a stack of additive coupling layers over the *flattened
feature dim* (NICE-style): split features into halves (x1, x2),

    y2 = x2 + t(x1)        y1 = x1 + t'(y2)             (one layer, 2 steps)

with ``t`` a small pointwise scalar MLP shared across positions (params are
feature-size independent, so one scheme instance serves any query shape).
Additive coupling has unit Jacobian and an exact inverse by subtraction —
``g_inverse(g_forward(x)) == x`` to float roundoff, exactly on integers.
The coupling projection reuses the ``learned_encoder`` Pallas kernel shape
(``ops.learned_project_op``, [H,B,F]x[H,1] -> [1,B,F]) under
``backend="pallas"``.

Because ``encode`` is overridden (non-linear), ``fused_parity_outputs``
automatically takes its exact unfused fallback — the serving layers need no
edits, which is the point of the registry.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.scheme import Capabilities, LinearScheme, register_scheme


def init_coupling_params(hidden=8, seed=0, n_layers=2):
    """Deterministic coupling-MLP params: ``n_layers`` layers, each a
    pointwise scalar MLP  u -> w2^T relu(w1 * u + b1)  (w1 [H], b1 [H],
    w2 [H, 1]) — feature-size independent by construction."""
    key = jax.random.PRNGKey(seed)
    layers = []
    for _ in range(n_layers):
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "w1": (jax.random.normal(k1, (hidden,)) * 0.8).astype(
                jnp.float32),
            "b1": (jax.random.normal(k2, (hidden,)) * 0.1).astype(
                jnp.float32),
            "w2": (jax.random.normal(k3, (hidden, 1))
                   * (0.5 / hidden)).astype(jnp.float32),
        })
    return layers


def _shift(layer, u, use_pallas=False):
    """Pointwise coupling shift t(u): u [B, F'] -> [B, F'] through the
    scalar MLP; the [H,B,F']x[H,1] projection runs the ``learned_encoder``
    Pallas kernel under ``use_pallas``."""
    h = jax.nn.relu(jnp.einsum("h,bf->hbf", layer["w1"], u)
                    + layer["b1"][:, None, None])
    if use_pallas:
        from repro.kernels import ops
        return ops.learned_project_op(h, layer["w2"])[0]
    return jnp.einsum("hr,hbf->rbf", layer["w2"], h)[0]


def _pad_to(t, f):
    """Zero-pad / truncate the shift's feature dim to ``f`` (odd feature
    counts make the halves unequal; padding keeps coupling invertible)."""
    if t.shape[1] == f:
        return t
    if t.shape[1] > f:
        return t[:, :f]
    return jnp.pad(t, ((0, 0), (0, f - t.shape[1])))


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _g_forward_flat(layers, x, use_pallas=False):
    """x [B, F] -> g(x) [B, F]: additive coupling, alternating halves."""
    f1 = x.shape[1] // 2
    x1, x2 = x[:, :f1], x[:, f1:]
    for layer in layers:
        x2 = x2 + _pad_to(_shift(layer, x1, use_pallas), x2.shape[1])
        x1 = x1 + _pad_to(_shift(layer, x2, use_pallas), x1.shape[1])
    return jnp.concatenate([x1, x2], axis=1)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _g_inverse_flat(layers, y, use_pallas=False):
    """Exact inverse of ``_g_forward_flat`` by subtraction, reversed."""
    f1 = y.shape[1] // 2
    y1, y2 = y[:, :f1], y[:, f1:]
    for layer in reversed(layers):
        y1 = y1 - _pad_to(_shift(layer, y2, use_pallas), y1.shape[1])
        y2 = y2 - _pad_to(_shift(layer, y1, use_pallas), y2.shape[1])
    return jnp.concatenate([y1, y2], axis=1)


@dataclass(frozen=True)
class InvNetScheme(LinearScheme):
    """Invertible-coupling encode over the Vandermonde output code; see
    module docstring.  ``coupling_params=None`` initialises deterministic
    couplings from ``coupling_seed`` (registry-name resolution in the DES
    and the differential battery serve a well-defined code)."""

    hidden: int = 8
    n_layers: int = 2
    coupling_seed: int = 0
    coupling_params: Optional[list] = None
    name: str = "invnet"

    def __post_init__(self):
        super().__post_init__()
        if self.coupling_params is None:
            object.__setattr__(
                self, "coupling_params",
                init_coupling_params(self.hidden, self.coupling_seed,
                                     self.n_layers))

    def capabilities(self) -> Capabilities:
        # model_agnostic: the deployed model serves the coupled parity
        # queries — provisioning returns references, never trains
        return Capabilities(model_agnostic=True)

    def provision_parity(self, deployed_params, ctx):
        """No parity training: the deployed model serves g^-1-space parity
        queries (exactly when it factors through g, approximately
        otherwise)."""
        del ctx
        return [deployed_params] * self.r

    def with_params(self, coupling_params):
        """A copy of this scheme serving ``coupling_params`` (checkpoint
        deserialization path, mirroring ``LearnedScheme.with_params``)."""
        return replace(self, coupling_params=coupling_params)

    def g_forward(self, x):
        """x [B, ...] -> g(x) [B, ...]: the invertible representation the
        linear code is conducted in, applied per sample over the flattened
        trailing feature dims (exposed for substrate construction and the
        invertibility tests)."""
        x = jnp.asarray(x).astype(jnp.float32)
        flat = x.reshape(x.shape[0], -1)
        out = _g_forward_flat(self.coupling_params, flat,
                              use_pallas=(self.backend == "pallas"))
        return out.reshape(x.shape)

    def g_inverse(self, y):
        y = jnp.asarray(y).astype(jnp.float32)
        flat = y.reshape(y.shape[0], -1)
        out = _g_inverse_flat(self.coupling_params, flat,
                              use_pallas=(self.backend == "pallas"))
        return out.reshape(y.shape)

    def encode(self, queries):
        """[k, ...] -> [r, ...]:  g^-1( coeffs @ g(queries) ),  the linear
        code conducted per-sample in g's latent space.  Queries are
        interpreted as [k, B, features...] (B = 1 when absent), matching the
        ``learned`` encoder's convention."""
        q = jnp.asarray(queries).astype(jnp.float32)
        assert q.shape[0] == self.k, q.shape
        flat = q.reshape(self.k, q.shape[1], -1) if q.ndim >= 3 else \
            q.reshape(self.k, 1, -1)                       # [k, B, F]
        k, b, f = flat.shape
        use_pallas = self.backend == "pallas"
        lat = _g_forward_flat(self.coupling_params, flat.reshape(k * b, f),
                              use_pallas=use_pallas).reshape(k, b, f)
        enc = jnp.einsum("rk,kbf->rbf", self.coeffs.astype(lat.dtype), lat)
        out = _g_inverse_flat(self.coupling_params,
                              enc.reshape(self.r * b, f),
                              use_pallas=use_pallas)
        return out.reshape((self.r,) + q.shape[1:])

    __call__ = encode


register_scheme(
    "invnet",
    lambda k, r=1, backend="jnp", **kw: InvNetScheme(
        k=k, r=r, backend=backend, **kw))
