"""Fisher-averaged parity models: training-free provisioning by checkpoint
merging (Erasure Coded Neural Network Inference via Fisher Averaging,
arXiv:2409.01420; PAPERS.md).

ParM trains a parity model by distillation (paper §3.3).  The Fisher line
observes that when the k deployed members are themselves neural checkpoints,
a parity model can be *merged* instead of trained: take the
Fisher-information-weighted average of the member checkpoints,

    theta*_j  =  ( sum_i  c_ji * F_i (.) theta_i )
                 / ( sum_i  c_ji * F_i )            (leaf-wise, elementwise)

where F_i is member i's diagonal Fisher — the expected squared gradient of
its own log-likelihood, estimated from a small calibration batch — and c_ji
are the parity row's combination weights.  Parameters a member is confident
about (high curvature) dominate the merge; zero gradient steps run.

``FisherScheme`` packages this behind the scheme-owned provisioning API
(DESIGN.md §14):

* **encode / decode** — the plain linear output code, with the Vandermonde
  coefficient rows normalised to sum to 1 (row-stochastic).  Row
  normalisation keeps the code MDS (each row is a positive rescale of a
  Vandermonde row) while making every parity query a *convex combination*
  of the members — the merged model is evaluated in-distribution rather
  than at k-times-scaled inputs, which is what makes the untrained merged
  parity model accurate.
* **provision_parity** — computes each member's diagonal Fisher over
  ``calib_n`` calibration samples from ``ctx.x_train`` and merges leaf-wise
  through ``repro.checkpoint.io.weighted_merge``.  ``deployed_params`` may
  be a list/tuple of k member checkpoints (the paper's setting) or a single
  pytree (this repo's serving default: one checkpoint deployed across all k
  members) — identical members merge to (numerically) the deployed params
  themselves, so the parity pool serves the deployed model on convex
  parity queries.

The scheme is NOT ``model_agnostic``: the provisioned params are a merge
*product*, not references to the deployed params, so controller escalation
(which reuses deployed-params pools) cannot target it.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheme import (Capabilities, LinearScheme, register_scheme,
                               vandermonde)


@functools.partial(jax.jit, static_argnames=("fwd",))
def _diag_fisher_jit(params, x, *, fwd):
    def nll(p, xi):
        logits = fwd(p, xi[None])[0]
        logp = jax.nn.log_softmax(logits)
        # empirical Fisher at the model's own prediction (no labels needed:
        # calibration is unlabelled serving-side data)
        return -logp[jnp.argmax(jax.lax.stop_gradient(logits))]
    grads = jax.vmap(jax.grad(nll), in_axes=(None, 0))(params, x)
    return jax.tree.map(lambda g: jnp.mean(jnp.square(g), axis=0), grads)


def diag_fisher(fwd, params, x_calib):
    """Diagonal empirical Fisher of ``params`` under ``fwd`` over the
    calibration batch ``x_calib`` [n, ...]: per-leaf mean squared
    per-example gradient of the self-predicted negative log-likelihood."""
    return _diag_fisher_jit(params, jnp.asarray(x_calib), fwd=fwd)


def _row_normalized_vandermonde(k, r):
    C = np.asarray(vandermonde(k, r), np.float64)   # C[j, i] = (i+1)**j > 0
    return (C / C.sum(axis=1, keepdims=True)).astype(np.float32)


@dataclass(frozen=True)
class FisherScheme(LinearScheme):
    """Linear code with row-stochastic coefficients + Fisher-merged parity
    provisioning; see module docstring.  ``calib_n`` caps the calibration
    batch drawn from ``ctx.x_train``; ``fisher_floor`` is added to every
    Fisher diagonal so zero-curvature leaves fall back to the plain
    coefficient-weighted convex average (and identical members always merge
    to themselves)."""

    name: str = "fisher"
    calib_n: int = 64
    fisher_floor: float = 1e-8

    def __post_init__(self):
        super().__post_init__()
        object.__setattr__(
            self, "_coeffs",
            jnp.asarray(_row_normalized_vandermonde(self.k, self.r)))

    def capabilities(self) -> Capabilities:
        # deliberately NOT model_agnostic: the provisioned parity params are
        # a merge product, not references to the deployed params
        return Capabilities()

    def provision_parity(self, deployed_params, ctx):
        """Fisher-weighted checkpoint merge — zero gradient steps.

        One merged pytree per parity row j, member i weighted elementwise by
        ``c_ji * (F_i + fisher_floor)``.  ``deployed_params``: list/tuple of
        k member checkpoints, or one pytree deployed across all members."""
        from repro.checkpoint.io import weighted_merge
        members = list(deployed_params) \
            if isinstance(deployed_params, (list, tuple)) \
            else [deployed_params] * self.k
        if len(members) != self.k:
            raise ValueError(
                f"fisher provisioning needs one checkpoint per member: got "
                f"{len(members)} for k={self.k}")
        x = np.asarray(ctx.x_train)[:self.calib_n]
        distinct = {}          # id -> fisher; one deployed checkpoint => one
        fishers = []           # fisher pass, not k identical ones
        for m in members:
            if id(m) not in distinct:
                distinct[id(m)] = diag_fisher(ctx.fwd, m, x)
            fishers.append(distinct[id(m)])
        C = np.asarray(self.coeffs, np.float64)              # [r, k]
        parity_params = []
        for j in range(self.r):
            weights = [
                jax.tree.map(
                    lambda f, c=C[j, i]: c * (f + self.fisher_floor),
                    fishers[i])
                for i in range(self.k)]
            parity_params.append(weighted_merge(members, weights))
        return parity_params


register_scheme(
    "fisher",
    lambda k, r=1, backend="jnp", **kw: FisherScheme(
        k=k, r=r, backend=backend, **kw))
