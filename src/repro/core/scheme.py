"""Pluggable coding-scheme layer: the ``CodingScheme`` protocol + registry.

ParM's central claim (paper §3.2-§3.5) is that the *code* is a swappable,
simple component — the learning lives in the parity model.  This module makes
that claim structural: every encoder/decoder pair is a ``CodingScheme`` with a
uniform surface

    scheme.encode(queries)                      # [k, ...] -> [r, ...]
    scheme.decode(parity_outs, outputs, missing_mask, parity_avail=None)
    scheme.decode_one(parity_out, outputs, missing_idx)   # r=1 hot path
    scheme.coeffs                               # [r, k] combination matrix
    scheme.k, scheme.r, scheme.name

and both serving layers (``repro.serving.runtime`` and
``repro.serving.simulator``) resolve schemes *only* through the registry:

    register_scheme("myscheme", factory)        # one file, one call
    get_scheme("myscheme", k=4, r=2, backend="pallas")

Built-in entries:

* ``sum``          — the paper's addition/Vandermonde code (§3.2, §3.5).
* ``concat``       — the task-specific downsample-and-grid image code (§4.2.3).
* ``replication``  — each query mirrored (r = k identity code); decode is a
                     passthrough.  Registering it here is what lets
                     replication run through the coded serving path instead of
                     being a simulator-only special case.
* ``approx_backup``— §5.2.6 approximate backups expressed as a degraded-
                     quality scheme: k = 1 groups, one cheap backup model per
                     group, decode is a passthrough of the (approximate)
                     backup output.  Registering it here is what lets the
                     ``approx_backup`` strategy ride the coded serving path
                     instead of being a special ``backup`` pool in both
                     serving layers.
* ``learned``      — ``repro.core.learned.LearnedScheme``: a trainable
                     encoder (Vandermonde base code + a small MLP residual
                     over the coding dimension) trained jointly with the
                     parity models; decode is still the linear output code.
* ``approxifer``   — ``repro.core.approxifer.ApproxIFERScheme``: the
                     ApproxIFER-style rational-interpolation code.  No
                     parity model is trained (``model_agnostic``) — the
                     deployed model serves the encoded queries — and the
                     decoder adapts its arity to however many responses
                     arrived, voting out erroneous (Byzantine) responses
                     when it holds surplus ones (``detects_errors``).
* ``fisher``       — ``repro.core.fisher.FisherScheme``: training-free
                     parity models built by Fisher-information-weighted
                     merging of the k deployed checkpoints (arXiv:2409.01420)
                     — ``provision_parity`` merges leaf-wise via
                     ``checkpoint/io.py``; encode/decode stay the linear
                     output code, zero gradient steps.
* ``invnet``       — ``repro.core.invnet.InvNetScheme``: Coded-InvNet-style
                     encoding (arXiv:2106.06445) through a small invertible
                     additive-coupling network g: parities are
                     g^-1(C @ g(x)), decode is the exact linear output code
                     on the invertible substrate; no parity training
                     (``model_agnostic`` — the deployed model serves the
                     encoded queries).

Capability flags (``model_agnostic`` / ``trainable`` / ``fixes_k`` /
``dynamic_arity`` / ``detects_errors`` / ``approximate``) are declared by a
scheme's ``capabilities() -> Capabilities`` method and read by every train /
serving / eval call site through ``scheme_capabilities(scheme)`` — the old
per-attribute duck-typing is deprecated (readable one release).  Parity-model
provisioning is likewise scheme-owned: ``provision_parity(deployed_params,
ctx)`` returns the r parity params lists (DESIGN.md §14), with
``repro.core.parity.default_provision`` as the distillation/joint-training
default.

``backend="jnp" | "pallas"`` selects the implementation of the hot paths:
``pallas`` routes encode / r=1-decode through the Pallas TPU kernels in
``repro.kernels`` (interpret mode on CPU), ``jnp`` uses the pure-jnp
reference.  The general r>1 least-squares decode always runs in jnp — it is a
tiny [k, k] solve off the latency-critical path.

Linear schemes additionally expose the fused/batched hot-path surface
(DESIGN.md §12): ``encode_forward(queries, weights)`` fuses encode with the
parity models' first forward matmul in one launch, and
``decode_one_many`` / ``decode_many`` decode ALL recoverable groups of a
batch-atomic completion in one launch instead of per-group calls.  Schemes
without these methods simply keep the per-group path — both serving engines
feature-test with ``hasattr``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, fields
from typing import Callable, Dict, Optional, Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core.codes import ConcatEncoder, vandermonde

BACKENDS = ("jnp", "pallas")


@runtime_checkable
class CodingScheme(Protocol):
    """Structural protocol every coding scheme satisfies (duck-typed; concrete
    schemes need not inherit from anything)."""

    k: int
    r: int
    name: str

    @property
    def coeffs(self): ...                                     # [r, k]

    def encode(self, queries): ...                            # [k,...]->[r,...]

    def decode(self, parity_outs, outputs, missing_mask,
               parity_avail=None): ...

    def decode_one(self, parity_out, outputs, missing_idx): ...


# ----------------------------------------------------------- capabilities ---
@dataclass(frozen=True)
class Capabilities:
    """The declared capability surface of a coding scheme.

    One frozen record replacing the scattered per-attribute duck-typing the
    serving/training/eval layers used to do (``getattr(scheme, "...")``).
    A scheme declares its flags by defining ``capabilities() ->
    Capabilities``; call sites read them ONLY through
    ``scheme_capabilities(scheme)``, which also keeps legacy attribute-style
    schemes working one release (with a ``DeprecationWarning``).

    * ``model_agnostic`` — no parity model is trained: the deployed model
      itself serves the encoded queries (``provision_parity`` returns r
      references to the deployed params), which is also what makes the
      scheme a valid controller-escalation target;
    * ``trainable``      — the encoder has trainable parameters, optimised
      jointly with the parity models (the ``learned`` scheme);
    * ``fixes_k``        — the scheme owns its group size (approx_backup:
      k = 1) independent of the caller's redundancy-budget k;
    * ``dynamic_arity``  — recoverability is a response COUNT, not a fixed
      mask rule (approxifer);
    * ``detects_errors`` — the decoder can vote out erroneous (Byzantine)
      responses from surplus ones;
    * ``approximate``    — reconstructions are degraded-quality; the DES
      runs the parity pool at ``cfg.approx_speedup``.
    """

    model_agnostic: bool = False
    trainable: bool = False
    fixes_k: bool = False
    dynamic_arity: bool = False
    detects_errors: bool = False
    approximate: bool = False


class _deprecated_flag:
    """Class-attribute descriptor keeping the pre-``capabilities()`` boolean
    flags readable one release: reading warns toward
    ``scheme_capabilities()`` and returns the declared value."""

    def __init__(self, name, value):
        self.name, self.value = name, value

    def __get__(self, obj, objtype=None):
        warnings.warn(
            f"reading scheme.{self.name} is deprecated; use "
            f"repro.core.scheme.scheme_capabilities(scheme).{self.name}",
            DeprecationWarning, stacklevel=2)
        return self.value


def scheme_capabilities(scheme) -> Capabilities:
    """THE capability-dispatch entry point for train/serving/eval layers.

    Schemes that define ``capabilities()`` are read through it; schemes
    that still declare the old boolean class attributes get them collected
    into a ``Capabilities`` record with a ``DeprecationWarning`` (one
    release of compatibility); schemes declaring neither get the default
    (all-False) record."""
    fn = getattr(type(scheme), "capabilities", None)
    if fn is not None:
        return fn(scheme)
    found = {}
    for f in fields(Capabilities):
        # read via the field name (never a literal attribute spelling) so
        # legacy schemes keep working without this module itself becoming a
        # duck-typing call site
        v = getattr(scheme, f.name, None)
        if v is not None:
            found[f.name] = bool(v)
    if found:
        warnings.warn(
            f"scheme {getattr(scheme, 'name', scheme)!r} declares "
            f"capability attributes ({sorted(found)}) but no "
            f"capabilities() method; attribute-style flags are deprecated "
            f"— define capabilities() -> Capabilities",
            DeprecationWarning, stacklevel=2)
    return Capabilities(**found)


def _check_backend(backend):
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")


def recoverable_rows(scheme, missing_mask, parity_avail):
    """Which missing rows can be reconstructed right now?

    The single recoverability rule BOTH serving layers consult (the threaded
    ``ParMFrontend`` and the DES ``simulate``), so their decode decisions
    cannot drift.  A scheme may refine it with an optional
    ``recoverable(missing_mask, parity_avail)`` method — replication's
    per-row replica arrival, or approxifer's dynamic-arity count (decode
    whenever the total number of *arrived* responses reaches k, however
    they split between members and parities); the default is the MDS rule —
    all-or-nothing while #missing <= #parities arrived.
    """
    missing_mask = np.asarray(missing_mask, bool)
    parity_avail = np.asarray(parity_avail, bool)
    rec_fn = getattr(scheme, "recoverable", None)
    if rec_fn is not None:
        return np.asarray(rec_fn(missing_mask, parity_avail), bool)
    if missing_mask.sum() <= parity_avail.sum():
        return missing_mask
    return np.zeros_like(missing_mask)


def decode_cost(scheme, n_missing):
    """Relative decode cost for reconstructing ``n_missing`` rows, in units
    of one r=1 subtraction decode (the calibration point of
    ``SimConfig.decode_ms``).  Schemes may provide their own
    ``decode_cost(n_missing)``; the default models the r>1 masked
    least-squares path as scaling linearly with the missing count."""
    fn = getattr(scheme, "decode_cost", None)
    if fn is not None:
        return float(fn(n_missing))
    return 1.0 if n_missing <= 1 else float(n_missing)


def encode_cost(scheme):
    """Relative encode cost per coding group, in units of one linear-
    combination encode (the calibration point of ``SimConfig.encode_ms``).
    Schemes may provide their own ``encode_cost()``; identity "encodes"
    (replication, approximate backups) charge 0 — no frontend math runs."""
    fn = getattr(scheme, "encode_cost", None)
    if fn is not None:
        return float(fn())
    return 1.0


def _pallas_encode(queries, coeffs, r):
    """Route encode through the Pallas kernel, one launch per parity row."""
    from repro.kernels import ops
    q = jnp.asarray(queries)
    batched = q.ndim > 1
    if not batched:                       # [k] -> [k, 1]
        q = q[:, None]
    if q.ndim == 2:                       # [k, F] -> [k, 1, F]
        q = q[:, None, :]
        out = jnp.stack([ops.parity_encode_op(q, coeffs[j])[0]
                         for j in range(r)])
    else:
        out = jnp.stack([ops.parity_encode_op(q, coeffs[j])
                         for j in range(r)])
    return out if batched else out[:, 0]


def _pallas_decode_many(parity_outs, outputs, missing_idxs, coeffs):
    """Route the batched r=1 subtraction decode through the multigroup
    Pallas kernel: all G stacked groups reconstructed in one launch."""
    from repro.kernels import ops
    outs = jnp.asarray(outputs)
    po = jnp.asarray(parity_outs)
    G, k = outs.shape[:2]
    batched = outs.ndim > 3
    flat = outs.reshape(G, k, 1, -1) if not batched else \
        outs.reshape(G, k, outs.shape[2], -1)
    pf = po.reshape((G,) + flat.shape[2:])
    out = ops.multigroup_decode_op(pf, flat, missing_idxs, coeffs)
    return out.reshape(po.shape)


def _pallas_decode_one(parity_out, outputs, missing_idx, coeffs):
    """Route the r=1 subtraction decode through the Pallas kernel."""
    from repro.kernels import ops
    outs = jnp.asarray(outputs)
    po = jnp.asarray(parity_out)
    k = outs.shape[0]
    batched = outs.ndim > 2
    flat = outs.reshape(k, 1, -1) if not batched else \
        outs.reshape(k, outs.shape[1], -1)
    pf = po.reshape(flat.shape[1:])
    out = ops.parity_decode_op(pf, flat, missing_idx, coeffs=coeffs)
    return out.reshape(po.shape)


@dataclass(frozen=True)
class LinearScheme:
    """The paper's addition code, generalised to r >= 1 Vandermonde rows
    (§3.5).  r=1 reduces to P = sum X_i with the subtraction decoder.

    All decode math reads ``self.coeffs``, so subclasses that override the
    coefficient matrix (or ``encode``) stay internally consistent."""

    k: int
    r: int = 1
    backend: str = "jnp"
    name: str = "sum"

    def __post_init__(self):
        _check_backend(self.backend)
        # cache: coeffs sits on the non-jitted serving hot path (encode and
        # decode run under the frontend lock) and the frozen dataclass can
        # never change it
        object.__setattr__(
            self, "_coeffs",
            jnp.asarray(vandermonde(self.k, self.r), jnp.float32))

    @property
    def coeffs(self):
        return self._coeffs

    def encode(self, queries):
        """queries [k, ...] -> parities [r, ...]."""
        queries = jnp.asarray(queries)
        assert queries.shape[0] == self.k, queries.shape
        if self.backend == "pallas":
            return _pallas_encode(queries, self.coeffs, self.r)
        c = self.coeffs.astype(queries.dtype)
        return jnp.tensordot(c, queries, axes=1)

    __call__ = encode

    def encode_forward(self, queries, weights):
        """Fused coded hot path (DESIGN.md §12): encode the [r, k] projection
        over the coding dim AND apply each parity row's first forward matmul
        in one launch.  queries [k, B, ...] (trailing feature dims flattened
        to F); weights [r, F, V] — one first-layer matrix per parity row
        (parity models train independently), or [F, V] shared.  Returns
        [r, B, V].  ``backend="pallas"`` runs
        ``kernels/fused_encode_forward.py``; jnp is the fallback with the
        reference semantics (encode, then per-row matmul)."""
        queries = jnp.asarray(queries)
        assert queries.shape[0] == self.k, queries.shape
        weights = jnp.asarray(weights)
        if weights.ndim == 2:
            weights = jnp.broadcast_to(weights, (self.r,) + weights.shape)
        if self.backend == "pallas":
            from repro.kernels import ops
            return ops.fused_encode_forward_op(queries, self.coeffs, weights)
        flat = queries.reshape(queries.shape[0], queries.shape[1], -1)
        c = self.coeffs.astype(flat.dtype)
        enc = jnp.tensordot(c, flat, axes=1)                 # [r, B, F]
        return jnp.einsum("rbf,rfv->rbv", enc, weights.astype(flat.dtype))

    def decode_one(self, parity_out, outputs, missing_idx):
        """r=1 subtraction path: F_hat(X_j) = (F_P(P) - sum_{i!=j} c_i F(X_i))
        / c_j."""
        if self.backend == "pallas":
            return _pallas_decode_one(parity_out, outputs, missing_idx,
                                      self.coeffs[0])
        c = self.coeffs[0].astype(jnp.float32)          # [k]
        outs = jnp.asarray(outputs).astype(jnp.float32)
        mask = (jnp.arange(self.k) != missing_idx)
        avail_sum = jnp.einsum("k,k...->...", c * mask, outs)
        po = jnp.asarray(parity_out).astype(jnp.float32)
        return (po - avail_sum) / c[missing_idx]

    def decode_one_many(self, parity_outs, outputs, missing_idxs):
        """Batched ``decode_one`` over G stacked groups — ONE launch
        (``kernels/multigroup_decode.py``) instead of G per-group calls.
        parity_outs [G, ...]; outputs [G, k, ...]; missing_idxs [G] ints.
        Both serving engines' batch-decode drains route recoverable groups
        here when more than one lands at once."""
        if self.backend == "pallas":
            return _pallas_decode_many(parity_outs, outputs,
                                       jnp.asarray(missing_idxs),
                                       self.coeffs[0])
        c = self.coeffs[0].astype(jnp.float32)               # [k]
        outs = jnp.asarray(outputs).astype(jnp.float32)
        idx = jnp.asarray(missing_idxs)
        avail = c[None, :] * (jnp.arange(self.k)[None, :] != idx[:, None])
        avail_sum = jnp.einsum("gk,gk...->g...", avail, outs)
        po = jnp.asarray(parity_outs).astype(jnp.float32)
        inv = (1.0 / c[idx]).reshape((-1,) + (1,) * (po.ndim - 1))
        return (po - avail_sum) * inv

    def decode_many(self, parity_outs, outputs, missing_masks,
                    parity_avail=None):
        """Batched ``decode`` over G stacked groups: the masked
        least-squares solve for every group runs as a single vmapped
        computation (``kernels/multigroup_decode.multigroup_lstsq``) instead
        of G sequential solves.  parity_outs [G, r, ...]; outputs
        [G, k, ...]; missing_masks [G, k]; parity_avail [G, r] (default all
        arrived).  Always jnp, like ``decode`` — the [k, k] solves are off
        the latency-critical path; batching them is the win."""
        from repro.kernels.multigroup_decode import multigroup_lstsq
        parity_outs = jnp.asarray(parity_outs)
        if parity_avail is None:
            parity_avail = jnp.ones(parity_outs.shape[:2], bool)
        return multigroup_lstsq(self.coeffs, parity_outs,
                                jnp.asarray(outputs),
                                jnp.asarray(missing_masks),
                                jnp.asarray(parity_avail))

    def decode(self, parity_outs, outputs, missing_mask, parity_avail=None):
        """General masked least-squares decode (exact while #missing <=
        #available parities; ``parity_avail`` [r] marks which parity outputs
        arrived — a parity model can straggle too).  Always jnp — a [k, k]
        solve off the hot path; jit-stable shapes for any missing pattern."""
        C = self.coeffs                                  # [r, k]
        parity_outs = jnp.asarray(parity_outs)
        if parity_avail is not None:
            pa = jnp.asarray(parity_avail).astype(jnp.float32)[:, None]
            C = C * pa
            parity_outs = parity_outs * pa.reshape(
                (-1,) + (1,) * (parity_outs.ndim - 1))
        outs = jnp.asarray(outputs).astype(jnp.float32)
        missing_mask = jnp.asarray(missing_mask)
        avail = (~missing_mask).astype(jnp.float32)
        rhs = parity_outs.astype(jnp.float32) - jnp.einsum(
            "rk,k...->r...", C * avail[None, :], outs)   # [r, ...]
        # Solve C_miss @ y = rhs for the missing columns via normal equations
        # restricted to missing columns: M = C * miss
        M = C * missing_mask.astype(jnp.float32)[None, :]        # [r, k]
        G = M.T @ M + 1e-9 * jnp.eye(self.k)                     # [k, k]
        # y_missing = pinv: solve G y = M^T rhs
        mt_rhs = jnp.einsum("rk,r...->k...", M, rhs)
        flat = mt_rhs.reshape(self.k, -1)
        sol = jnp.linalg.solve(G, flat).reshape(mt_rhs.shape)    # [k, ...]
        mm = missing_mask.reshape((self.k,) + (1,) * (outs.ndim - 1))
        return jnp.where(mm, sol, outs)

    # decode cost: linear schemes use the module-level ``decode_cost``
    # default — one subtraction decode for a single missing row, the masked
    # least-squares solve scaling with the missing count beyond that

    def capabilities(self) -> Capabilities:
        """Plain linear codes declare no special capabilities."""
        return Capabilities()

    def provision_parity(self, deployed_params, ctx):
        """Default provisioning: delegate to the per-row distillation / joint
        training owned by ``repro.core.parity`` (DESIGN.md §14)."""
        from repro.core.parity import default_provision  # lazy: parity
        return default_provision(self, deployed_params, ctx)  # imports us


@dataclass(frozen=True)
class ConcatScheme(LinearScheme):
    """§4.2.3 task-specific image code: encode downsamples k images into a
    g x g grid (g = ceil(sqrt(k))), decode is the r=1 subtraction decoder over
    model *outputs* (the output code is still addition)."""

    name: str = "concat"

    def __post_init__(self):
        super().__post_init__()
        if self.r != 1:
            raise ValueError(
                f"concat scheme supports r=1 only, got r={self.r}")
        object.__setattr__(self, "_encoder", ConcatEncoder(self.k, 1))

    def encode(self, queries):
        """queries [k, B, H, W, C] -> [1, B, H, W, C]."""
        return self._encoder(jnp.asarray(queries))

    __call__ = encode


@dataclass(frozen=True)
class ReplicationScheme:
    """Replication expressed as a code: the coefficient matrix is I_k, so
    "encoding" mirrors each query (r = k parity queries) and decode is a
    passthrough — the j-th replica's output *is* the j-th reconstruction.

    Plugging this into the coded serving path (parity models = the deployed
    model) gives classic 2x replication through the exact same group/decode
    machinery as ParM, which is the point of the registry."""

    k: int
    r: Optional[int] = None       # always k; None means "let me set it"
    backend: str = "jnp"
    name: str = "replication"

    def __post_init__(self):
        _check_backend(self.backend)
        if self.r not in (None, self.k):
            raise ValueError(
                f"replication scheme has r == k, got r={self.r} k={self.k}")
        object.__setattr__(self, "r", self.k)
        object.__setattr__(self, "_coeffs",
                           jnp.eye(self.k, dtype=jnp.float32))

    @property
    def coeffs(self):
        return self._coeffs

    def encode(self, queries):
        """Each query is its own parity query: [k, ...] -> [k, ...]."""
        queries = jnp.asarray(queries)
        assert queries.shape[0] == self.k, queries.shape
        return queries

    __call__ = encode

    def decode_one(self, parity_out, outputs, missing_idx):
        """Passthrough: the replica output is the reconstruction."""
        del outputs, missing_idx
        return jnp.asarray(parity_out)

    def decode(self, parity_outs, outputs, missing_mask, parity_avail=None):
        parity_outs = jnp.asarray(parity_outs)
        outputs = jnp.asarray(outputs)
        mm = jnp.asarray(missing_mask).reshape(
            (self.k,) + (1,) * (outputs.ndim - 1))
        if parity_avail is not None:
            pa = jnp.asarray(parity_avail).reshape(mm.shape)
            mm = jnp.logical_and(mm, pa)  # only fill from arrived replicas
        return jnp.where(mm, parity_outs, outputs)

    def recoverable(self, missing_mask, parity_avail):
        """Per-row rule (vs the MDS all-or-nothing default): a missing row is
        recoverable iff its own replica arrived."""
        return np.asarray(missing_mask) & np.asarray(parity_avail)

    def decode_cost(self, n_missing):
        """Decode is a passthrough copy — effectively free."""
        del n_missing
        return 0.0

    def encode_cost(self):
        """"Encoding" mirrors the queries — no frontend math runs."""
        return 0.0

    def capabilities(self) -> Capabilities:
        return Capabilities()

    def provision_parity(self, deployed_params, ctx):
        """Replicas are distilled copies: delegate to the default per-row
        distillation (identity encode means each row mimics the deployed
        model directly)."""
        from repro.core.parity import default_provision  # lazy (circular)
        return default_provision(self, deployed_params, ctx)


@dataclass(frozen=True)
class ApproxBackupScheme(ReplicationScheme):
    """§5.2.6 approximate backups expressed as a degraded-quality coding
    scheme: every query is its own coding group (k = 1), the single "parity
    query" is the query itself, and the parity model is a *cheaper* backup
    model — decode passes its (approximate) output through.

    ``fixes_k = True`` decouples the scheme's group size from the serving
    layers' redundancy-budget k: ``strategy.layout(m, k, r)`` still spends
    the paper's m/k budget on backup instances, while group assembly follows
    ``scheme.k = 1``.  ``approximate = True`` tells the DES to run the parity
    pool at ``cfg.approx_speedup`` times the deployed service rate; in the
    threaded runtime the backup model's params (``parity_params``, with
    ``parity_fwd`` for a different architecture) are what make it cheap.

    Expressing the baseline as a scheme is what removes the dedicated
    ``backup`` pool special case from BOTH serving layers."""

    k: int = 1
    name: str = "approx_backup"
    # legacy attribute spellings: readable one release, warn toward
    # scheme_capabilities() (not dataclass fields — no annotations)
    fixes_k = _deprecated_flag("fixes_k", True)
    approximate = _deprecated_flag("approximate", True)

    def capabilities(self) -> Capabilities:
        # group size is the scheme's own (k = 1), not the budget k; the DES
        # runs the backup pool at cfg.approx_speedup
        return Capabilities(fixes_k=True, approximate=True)

    def __post_init__(self):
        if self.k != 1:
            raise ValueError(
                f"approx_backup scheme has k == 1 (one cheap backup query "
                f"per group), got k={self.k}")
        super().__post_init__()


# --------------------------------------------------------------- registry ---
_SCHEMES: Dict[str, Callable[..., CodingScheme]] = {}


def register_scheme(name: str, factory: Callable[..., CodingScheme] = None,
                    *, override: bool = False):
    """Register a scheme factory ``factory(k, r, backend, **kw)`` under
    ``name``.  Usable as a decorator::

        @register_scheme("mycode")
        class MyScheme: ...

    Registering a *different* factory under an existing name raises unless
    ``override=True`` — a silent replacement would reroute every call site
    that resolves the name (re-registering the same factory is a no-op, so
    module re-imports stay safe)."""
    def _register(f):
        if not override and _SCHEMES.get(name, f) is not f:
            raise ValueError(
                f"coding scheme {name!r} is already registered; pass "
                f"override=True to replace it")
        _SCHEMES[name] = f
        return f
    if factory is None:
        return _register
    return _register(factory)


def list_schemes() -> list:
    """Introspection: registered scheme names, sorted.  Controllers and
    sweeps enumerate candidate actions through this; every listed name
    resolves via ``get_scheme(name, k=...)``."""
    return sorted(_SCHEMES)


def available_schemes():
    return list_schemes()


def get_scheme(scheme, k=None, r=None, *, backend=None, **kw) -> CodingScheme:
    """Resolve ``scheme`` to a CodingScheme.

    * a CodingScheme instance passes through, after validating it against
      any k / r / backend the caller explicitly asked for (``None`` means
      "whatever the instance has" — a silent mismatch would train or serve
      the wrong code).  Schemes with ``fixes_k = True`` (approx_backup) own
      their group size, so the caller's k — the redundancy-*budget* k — is
      not checked against them;
    * a string is looked up in the registry and instantiated with
      ``(k=k, r=r, backend=backend, **kw)`` (r defaults to 1, backend to
      "jnp").
    """
    if not isinstance(scheme, str):
        if not isinstance(scheme, CodingScheme):
            raise TypeError(
                f"not a CodingScheme or registered name: {scheme!r}")
        if k is not None and scheme.k != k and \
                not scheme_capabilities(scheme).fixes_k:
            raise ValueError(
                f"scheme {scheme.name!r} has k={scheme.k}, but k={k} was "
                f"requested")
        if r is not None and scheme.r != r:
            raise ValueError(
                f"scheme {scheme.name!r} has r={scheme.r}, but r={r} was "
                f"requested")
        if backend is not None and \
                getattr(scheme, "backend", backend) != backend:
            raise ValueError(
                f"scheme {scheme.name!r} was built with "
                f"backend={scheme.backend!r}, but backend={backend!r} was "
                f"requested")
        return scheme
    if scheme not in _SCHEMES:
        raise KeyError(
            f"unknown coding scheme {scheme!r}; registered: "
            f"{available_schemes()}")
    if k is None:
        raise ValueError("get_scheme(name, ...) requires k")
    return _SCHEMES[scheme](k=k, r=1 if r is None else r,
                            backend=backend or "jnp", **kw)


register_scheme("sum", LinearScheme)
register_scheme("concat", ConcatScheme)
register_scheme(
    "replication",
    # replication fixes r = k; accept and ignore the caller's r so generic
    # call sites (registry round-trip loops, frontends) need no special case
    lambda k, r=None, backend="jnp", **kw: ReplicationScheme(
        k=k, backend=backend, **kw))
register_scheme(
    "approx_backup",
    # the scheme fixes k = 1 and r = 1; the caller's k is the redundancy
    # budget, which sizes the backup pool, not the group
    lambda k=None, r=None, backend="jnp", **kw: ApproxBackupScheme(
        backend=backend, **kw))

# the learned scheme lives in its own module (encoder init + joint-training
# helpers); importing it registers "learned".  Import at the bottom: it
# subclasses LinearScheme and calls register_scheme from this module.
from repro.core import learned as _learned  # noqa: E402  (registration)

# the approxifer scheme (rational-interpolation code with a dynamic-arity
# decoder) likewise registers itself on import
from repro.core import approxifer as _approxifer  # noqa: E402  (registration)

# the training-free schemes: fisher (checkpoint merging) and invnet
# (invertible-coupling encode) register themselves on import
from repro.core import fisher as _fisher  # noqa: E402  (registration)
from repro.core import invnet as _invnet  # noqa: E402  (registration)

del _learned, _approxifer, _fisher, _invnet
