"""Erasure-coding layer of ParM: encoders and decoders (paper §3.2, §3.5).

ParM deliberately keeps these *simple and fast* — the learning happens in the
parity model, not the code. We provide:

* ``SumEncoder``      — the paper's generic addition encoder, generalised to
                        r >= 1 parities with Vandermonde coefficient rows
                        (r=1, row [1, 1, ..., 1] reduces to P = sum X_i; §3.5's
                        k=2,r=2 example is rows [1,1] and [1,2]).
* ``LinearDecoder``   — the subtraction decoder for r=1 and, in general, the
                        small linear solve that reconstructs up to r missing
                        outputs from any k available (model ∪ parity) outputs.
* ``ConcatEncoder``   — the task-specific image encoder of §4.2.3: downsample
                        each of the k image queries and place them in a grid,
                        keeping the parity query the same size as one query.

All are pure jnp (µs-scale); the hot paths also exist as Pallas TPU kernels in
``repro.kernels`` (parity_encode / parity_decode) validated against these.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


def vandermonde(k: int, r: int) -> np.ndarray:
    """Coefficient matrix C [r, k]: C[j, i] = (i+1)**j.

    Any r columns... more precisely any square submatrix formed by the rows of
    [I_k; C] that can arise from <= r unavailabilities is invertible, which is
    what the decoder needs (MDS property of Vandermonde systems over the
    reals)."""
    return np.vander(np.arange(1, k + 1, dtype=np.float64), r,
                     increasing=True).T.copy()


@dataclass(frozen=True)
class SumEncoder:
    """P_j = sum_i C[j,i] * X_i over feature-aligned queries."""
    k: int
    r: int = 1

    @property
    def coeffs(self):
        return jnp.asarray(vandermonde(self.k, self.r), jnp.float32)

    def __call__(self, queries):
        """queries [k, ...] -> parities [r, ...]."""
        assert queries.shape[0] == self.k, queries.shape
        c = self.coeffs.astype(queries.dtype)
        return jnp.tensordot(c, queries, axes=1)


@dataclass(frozen=True)
class ConcatEncoder:
    """§4.2.3: downsample k images into a g x g grid (g = ceil(sqrt(k))).

    Output spatial size equals one input query, so parity-model input shape
    (and hence network bandwidth overhead, 1/k) is unchanged. r must be 1.
    """
    k: int
    r: int = 1

    def __call__(self, queries):
        """queries [k, B, H, W, C] -> [1, B, H, W, C]."""
        assert self.r == 1
        k, B, H, W, C = queries.shape
        g = math.ceil(math.sqrt(k))
        if H % g != 0 or W % g != 0:
            raise ValueError(
                f"ConcatEncoder with k={k} tiles a {g}x{g} grid, so image "
                f"height and width must be divisible by {g}; got H={H}, "
                f"W={W}. Pad or resize the queries first.")
        h, w = H // g, W // g
        # average-pool each query down to (h, w)
        q = queries.reshape(k * B, g, h, g, w, C).mean(axis=(1, 3))
        q = q.reshape(k, B, h, w, C)
        canvas = jnp.zeros((B, H, W, C), queries.dtype)
        for i in range(k):
            rr, cc = divmod(i, g)
            canvas = canvas.at[:, rr * h:(rr + 1) * h,
                               cc * w:(cc + 1) * w, :].set(q[i])
        return canvas[None]


@dataclass(frozen=True)
class LinearDecoder:
    """Reconstructs missing deployed-model outputs from available model and
    parity-model outputs.

    r = 1 fast path is the paper's subtraction decoder:
        F_hat(X_j) = F_P(P) - sum_{i != j} F(X_i)
    General path solves  C[:, miss] @ Y_miss = parity_out - C[:, avail] @ Y_avail
    (least squares; exact when #missing <= #available parities).
    """
    k: int
    r: int = 1

    @property
    def coeffs(self):
        return jnp.asarray(vandermonde(self.k, self.r), jnp.float32)

    def decode_one(self, parity_out, outputs, missing_idx):
        """r=1 subtraction path. outputs [k, ...] with the missing row
        arbitrary; parity_out [...]. Returns reconstruction of that row."""
        c = self.coeffs[0].astype(jnp.float32)          # [k]
        outs = outputs.astype(jnp.float32)
        mask = (jnp.arange(self.k) != missing_idx)
        avail_sum = jnp.einsum("k,k...->...", c * mask, outs)
        return (parity_out.astype(jnp.float32) - avail_sum) / c[missing_idx]

    def decode(self, parity_outs, outputs, missing_mask, parity_avail=None):
        """General decode. parity_outs [r, ...]; outputs [k, ...] (garbage in
        missing rows); missing_mask [k] bool; ``parity_avail`` [r] bool marks
        which parity outputs arrived (a parity model can be a straggler too —
        decode is exact whenever #available parities >= #missing). Returns
        outputs with missing rows replaced by reconstructions.

        Uses a masked least-squares solve so the whole thing jits with a
        static shape regardless of *which* rows are missing."""
        C = self.coeffs                                  # [r, k]
        if parity_avail is not None:
            pa = jnp.asarray(parity_avail).astype(jnp.float32)[:, None]
            C = C * pa
            parity_outs = parity_outs * pa.reshape(
                (-1,) + (1,) * (parity_outs.ndim - 1))
        outs = outputs.astype(jnp.float32)
        avail = (~missing_mask).astype(jnp.float32)
        rhs = parity_outs.astype(jnp.float32) - jnp.einsum(
            "rk,k...->r...", C * avail[None, :], outs)   # [r, ...]
        # Solve C_miss @ y = rhs for the missing columns via normal equations
        # restricted to missing columns: M = C * miss
        M = C * missing_mask.astype(jnp.float32)[None, :]        # [r, k]
        G = M.T @ M + 1e-9 * jnp.eye(self.k)                     # [k, k]
        # y_missing = pinv: solve G y = M^T rhs
        mt_rhs = jnp.einsum("rk,r...->k...", M, rhs)
        flat = mt_rhs.reshape(self.k, -1)
        sol = jnp.linalg.solve(G, flat).reshape(mt_rhs.shape)    # [k, ...]
        mm = missing_mask.reshape((self.k,) + (1,) * (outs.ndim - 1))
        return jnp.where(mm, sol, outs)


def make_code(k, r=1, kind="sum"):
    """REMOVED (PR-1-era shim, deprecated for nine PRs): resolve codes
    through the scheme registry instead ::

        from repro.core.scheme import get_scheme
        scheme = get_scheme("sum", k=k, r=r)   # or "concat", ...

    — schemes carry encode/decode/coeffs on one object and support backend
    selection.  Raises ``TypeError`` with this migration message."""
    raise TypeError(
        f"make_code(k={k}, r={r}, kind={kind!r}) was removed; use "
        f"repro.core.scheme.get_scheme({kind!r}, k={k}, r={r}) — schemes "
        f"carry encode/decode/coeffs on one object and support backend "
        f"selection")
