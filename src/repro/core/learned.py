"""Learned coding scheme: a trainable encoder through the scheme registry.

ParM deliberately pairs simple linear encoders with a learned parity model
(paper §3); the learned-coded-computation line (Kosaian et al.) and
ApproxIFER (PAPERS.md) show that learning the *code* as well buys accuracy at
the same overhead.  ``LearnedScheme`` realises that extension point
(DESIGN.md §5/§7) without touching either serving layer:

* **encode** — the Vandermonde base code plus a small MLP residual applied
  across the coding dimension, pointwise per feature position::

      E_j(X)  =  sum_i C[j,i] X_i  +  alpha * (W2^T relu(W1^T X + b1))_j

  The residual path is zero-initialised (``alpha = 0``), so a fresh scheme
  encodes *exactly* the ``sum`` code — joint training can only move away
  from the classical code when doing so lowers the parity objective.  The
  MLP mixes only along k (shared across positions), so encode preserves the
  ``[k, ...] -> [r, ...]`` shape contract for any query shape.

* **decode** — inherited from ``LinearScheme`` unchanged: the *output*-space
  code is still the ``coeffs`` combination the parity model is distilled
  toward, so ``recoverable_rows`` / ``decode_cost`` keep their MDS
  semantics and the DES needs no new rules.

* **training** — ``train_parity_models(..., scheme="learned")`` detects
  ``trainable = True`` and optimises encoder and parity models *jointly*
  (``repro.core.parity._train_joint``); the returned scheme carries the
  trained, frozen encoder params for serving.

* **inference** — ``encode`` runs the frozen encoder; with
  ``backend="pallas"`` the linear base code and the final ``[H] -> [r]``
  projection run through the Pallas kernels
  (``repro.kernels.learned_encoder``), the jnp path is used for training
  (the kernels define no VJP).

Encoder params are a plain pytree (``{"w1", "b1", "w2", "alpha"}``) —
``repro.checkpoint.io.save/load`` serialises them as-is, and
``scheme.with_params(loaded)`` rebuilds the serving scheme (DESIGN.md §7).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheme import (Capabilities, LinearScheme, _deprecated_flag,
                               _pallas_encode, register_scheme)


def init_encoder_params(k, r, hidden, seed=0, alpha=0.0):
    """He-init MLP over the coding dimension; ``alpha`` gates the residual
    path (0 = start exactly at the linear base code)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {
        "w1": (jax.random.normal(k1, (k, hidden))
               * np.sqrt(2.0 / k)).astype(jnp.float32),
        "b1": jnp.zeros((hidden,), jnp.float32),
        "w2": (jax.random.normal(k2, (hidden, r))
               * np.sqrt(1.0 / hidden)).astype(jnp.float32),
        "alpha": jnp.asarray(alpha, jnp.float32),
    }


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def _encode_flat(enc, coeffs, q, use_pallas=False):
    """q [k, B, F] -> [r, B, F]: linear base code + alpha * MLP residual."""
    r = coeffs.shape[0]
    h = jax.nn.relu(jnp.einsum("kh,kbf->hbf", enc["w1"], q)
                    + enc["b1"][:, None, None])
    if use_pallas:
        from repro.kernels import ops
        lin = _pallas_encode(q, coeffs, r)
        proj = ops.learned_project_op(h, enc["w2"])
    else:
        lin = jnp.einsum("rk,kbf->rbf", coeffs.astype(q.dtype), q)
        proj = jnp.einsum("hr,hbf->rbf", enc["w2"], h)
    return lin + enc["alpha"] * proj


def learned_encode(enc_params, coeffs, queries, use_pallas=False):
    """Shape-generic encode: ``[k, ...] -> [r, ...]`` for any trailing query
    shape (vectors, batched features, images).  Differentiable w.r.t.
    ``enc_params`` on the jnp path — the joint training objective calls this
    directly; the Pallas path is inference-only."""
    q = jnp.asarray(queries).astype(jnp.float32)
    k = q.shape[0]
    r = coeffs.shape[0]
    flat = q.reshape(k, q.shape[1], -1) if q.ndim >= 3 else \
        q.reshape(k, 1, -1)
    out = _encode_flat(enc_params, coeffs, flat, use_pallas=use_pallas)
    return out.reshape((r,) + q.shape[1:])


@dataclass(frozen=True)
class LearnedScheme(LinearScheme):
    """Trainable encoder over the Vandermonde base code; see module
    docstring.  ``enc_params=None`` initialises a fresh (identity-to-sum)
    encoder from ``enc_seed`` — deterministic, so registry-name resolution
    in the DES and the differential battery serve a well-defined code."""

    hidden: int = 16
    enc_seed: int = 0
    enc_params: Optional[dict] = None
    name: str = "learned"

    # legacy attribute spelling: readable one release, warns toward
    # scheme_capabilities(scheme).trainable
    trainable = _deprecated_flag("trainable", True)

    def capabilities(self) -> Capabilities:
        # trainable: train_parity_models switches to the joint
        # encoder+parity objective and returns the trained scheme
        return Capabilities(trainable=True)

    def __post_init__(self):
        super().__post_init__()
        if self.enc_params is None:
            object.__setattr__(
                self, "enc_params",
                init_encoder_params(self.k, self.r, self.hidden,
                                    self.enc_seed))

    def encode(self, queries):
        """Frozen-encoder inference path ([k, ...] -> [r, ...])."""
        queries = jnp.asarray(queries)
        assert queries.shape[0] == self.k, queries.shape
        return learned_encode(self.enc_params, self.coeffs, queries,
                              use_pallas=(self.backend == "pallas"))

    __call__ = encode

    def encode_with_params(self, enc_params, queries):
        """Differentiable encode for the joint training objective (always
        jnp — the Pallas kernels define no VJP)."""
        return learned_encode(enc_params, self.coeffs, queries,
                              use_pallas=False)

    def with_params(self, enc_params):
        """A copy of this scheme serving ``enc_params`` (the training hook's
        return path, and the deserialization path for checkpointed
        encoders)."""
        return replace(self, enc_params=enc_params)


register_scheme(
    "learned",
    lambda k, r=1, backend="jnp", **kw: LearnedScheme(
        k=k, r=r, backend=backend, **kw))
