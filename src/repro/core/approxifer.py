"""ApproxIFER-style rational-interpolation coding scheme ("approxifer").

ApproxIFER (Soleymani et al., PAPERS.md) replaces ParM's learned parity
models with a *model-agnostic* interpolation code: treat the k queries of a
coding group as samples ``X_i = q(z_i)`` of a function over interpolation
nodes ``z_i``, send the interpolant's values at ``r`` extra nodes as the
parity queries, and serve EVERY query — originals and parities — with the
*deployed* model itself.  Because the output trajectory
``g(z) = F(q(z))`` is again (approximately) a low-order function of ``z``,
the decoder simply re-interpolates ``g`` through **whichever responses
actually arrived** and reads the missing members' outputs off the fit.
NeRCC frames the same decode as regression over coded queries.

Consequences realised here, and why this scheme stresses the plugin API:

* **no training** — ``model_agnostic = True``: ``train_parity_models``
  returns the deployed params as the "parity models"; a deployment
  tolerates stragglers with zero retraining, for any deployed model.
* **dynamic decode arity** — recoverability is not a fixed mask rule but a
  count: ALL missing members decode as soon as the total number of arrived
  responses (available members + arrived parities) reaches k.  The scheme
  owns that rule via ``recoverable`` (the hook ``recoverable_rows``
  honors), and its ``decode`` consumes however many responses exist.
* **Byzantine robustness** — ``detects_errors = True``: with more than k
  responses in hand the decoder has surplus equations, so gross erroneous
  (corrupted) responses are *voted out* by subset-consistency
  (``flag_errors``) and the affected predictions re-decoded from the
  clean remainder.  Correcting e corruptions needs 2e surplus responses —
  the classical error-correction margin.

Numerics: nodes are a combined Chebyshev grid over [-1, 1] (members and
parities interleaved), encode is the barycentric evaluation of the member
interpolant at the parity nodes — a fixed [r, k] linear map (``coeffs``),
so the Pallas fast path is one ``berrut_encode`` launch — and decode fits
a degree-(k-1) Chebyshev-basis polynomial to the arrived responses by
masked least squares.  ApproxIFER proper uses Berrut's O(1) barycentric
weights for stability at large k; at serving-scale k (<= ~8) the full
barycentric weights are equally stable and make the decoder *exact* on
polynomial data — which is what lets the differential battery hold this
scheme to the same bit-accuracy bar as the linear codes.
"""

from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations

import jax.numpy as jnp
import numpy as np

from repro.core.scheme import (Capabilities, _check_backend,
                               _deprecated_flag, register_scheme)

def chebyshev_nodes(n: int) -> np.ndarray:
    """n Chebyshev points of the first kind on (-1, 1), decreasing."""
    t = np.arange(1, n + 1, dtype=np.float64)
    return np.cos((2.0 * t - 1.0) * np.pi / (2.0 * n))


def split_nodes(k: int, r: int):
    """Interleave one combined Chebyshev grid of k + r points into member
    and parity nodes: parity nodes are spread evenly through the grid (a
    clustered extra-node set would condition the refit poorly), members
    take the rest.  Deterministic in (k, r)."""
    n = k + r
    grid = chebyshev_nodes(n)
    pidx = sorted({int((s + 0.5) * n / r) for s in range(r)})
    midx = [t for t in range(n) if t not in pidx]
    return grid[midx], grid[pidx]


def lagrange_eval_matrix(nodes: np.ndarray, at: np.ndarray) -> np.ndarray:
    """L[j, i] = i-th Lagrange basis polynomial of ``nodes`` at ``at[j]``
    (barycentric form; float64 for conditioning)."""
    nodes = np.asarray(nodes, np.float64)
    at = np.asarray(at, np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        bary = 1.0 / (at[:, None] - nodes[None, :])       # [m, n]
    exact = ~np.isfinite(bary)
    bary = np.where(exact, 0.0, bary)
    w = np.array([1.0 / np.prod(nodes[i] - np.delete(nodes, i))
                  for i in range(len(nodes))])            # barycentric weights
    num = bary * w[None, :]
    out = num / num.sum(axis=1, keepdims=True)
    # evaluation point coincides with a node: the basis is an indicator
    hit = exact.any(axis=1)
    out[hit] = exact[hit].astype(np.float64)
    return out


def chebyshev_design(nodes: np.ndarray, deg: int) -> np.ndarray:
    """Design matrix A[t, d] = T_d(nodes[t]) for d = 0..deg-1."""
    nodes = np.asarray(nodes, np.float64)
    a = np.empty((len(nodes), deg))
    a[:, 0] = 1.0
    if deg > 1:
        a[:, 1] = nodes
    for d in range(2, deg):
        a[:, d] = 2.0 * nodes * a[:, d - 1] - a[:, d - 2]
    return a


@dataclass(frozen=True)
class ApproxIFERScheme:
    """Rational-interpolation code with a straggler-adaptive decoder; see
    module docstring.  ``err_tol`` is the absolute residual above which a
    surplus-checked response is voted out as corrupted."""

    k: int
    r: int = 1
    backend: str = "jnp"
    name: str = "approxifer"
    err_tol: float = 100.0

    # legacy attribute spellings of the capability flags: readable one
    # release with a DeprecationWarning steering toward
    # scheme_capabilities(scheme)
    model_agnostic = _deprecated_flag("model_agnostic", True)
    detects_errors = _deprecated_flag("detects_errors", True)
    dynamic_arity = _deprecated_flag("dynamic_arity", True)

    def capabilities(self) -> Capabilities:
        # model_agnostic: no parity model is trained — the deployed model
        # serves the encoded queries too; detects_errors: the decoder votes
        # out grossly erroneous responses when the group holds surplus ones
        # (see flag_errors); dynamic_arity: recoverability is a response
        # COUNT (arrived >= k), not a fixed mask rule (see recoverable)
        return Capabilities(model_agnostic=True, detects_errors=True,
                            dynamic_arity=True)

    def provision_parity(self, deployed_params, ctx):
        """No parity training: the deployed model itself serves the encoded
        queries (the decoder re-interpolates its outputs), so the "parity
        models" are r references to the deployed params."""
        del ctx
        return [deployed_params] * self.r

    def __post_init__(self):
        _check_backend(self.backend)
        if self.k < 2:
            raise ValueError(
                f"approxifer interpolates over k >= 2 queries, got "
                f"k={self.k}")
        if self.r < 1:
            raise ValueError(f"r must be >= 1, got r={self.r}")
        z, w = split_nodes(self.k, self.r)
        object.__setattr__(self, "_member_nodes", z)
        object.__setattr__(self, "_parity_nodes", w)
        # encode IS a fixed linear map: the member interpolant evaluated at
        # the parity nodes
        coeffs = lagrange_eval_matrix(z, w)               # [r, k]
        object.__setattr__(
            self, "_coeffs", jnp.asarray(coeffs, jnp.float32))
        # decode design: T_0..T_{k-1} at every node (members then parities)
        nodes = np.concatenate([z, w])
        design = chebyshev_design(nodes, self.k)          # [k + r, k]
        object.__setattr__(
            self, "_design", jnp.asarray(design, jnp.float32))
        object.__setattr__(self, "_design_np", design)
        # r=1 hot path: reconstructing member j from the k - 1 other
        # members plus parity 0 is again a fixed linear map per j
        one = np.zeros((self.k, self.k + 1))
        for j in range(self.k):
            arr = np.concatenate([np.delete(z, j), w[:1]])
            lj = lagrange_eval_matrix(arr, z[j:j + 1])[0]  # [k]
            one[j, :self.k - 1] = lj[:self.k - 1]
            one[j, self.k] = lj[self.k - 1]
        object.__setattr__(self, "_decode_one_w", one)

    @property
    def coeffs(self):
        return self._coeffs

    @property
    def member_nodes(self):
        return self._member_nodes

    @property
    def parity_nodes(self):
        return self._parity_nodes

    # ------------------------------------------------------------- encode --
    def encode(self, queries):
        """queries [k, ...] -> parity queries [r, ...]: the member
        interpolant evaluated at the r extra Chebyshev nodes."""
        queries = jnp.asarray(queries)
        assert queries.shape[0] == self.k, queries.shape
        if self.backend == "pallas":
            from repro.kernels import ops
            q = queries
            batched = q.ndim > 1
            if not batched:
                q = q[:, None]
            out = ops.berrut_encode_op(q, self.coeffs)
            return out if batched else out[:, 0]
        c = self.coeffs.astype(queries.dtype)
        return jnp.tensordot(c, queries, axes=1)

    __call__ = encode

    def encode_cost(self):
        """One linear pass over the group — the calibration point."""
        return 1.0

    # ------------------------------------------------------------- decode --
    def decode(self, parity_outs, outputs, missing_mask, parity_avail=None):
        """Straggler-adaptive decode: fit the degree-(k-1) Chebyshev-basis
        interpolant through every response that arrived (masked least
        squares over the k + r node grid) and evaluate it at the missing
        members' nodes.  Arity is whatever arrived — exact whenever at
        least k responses are in, for data on a degree-(k-1) trajectory."""
        parity_outs = jnp.asarray(parity_outs).astype(jnp.float32)
        outs = jnp.asarray(outputs).astype(jnp.float32)
        missing_mask = jnp.asarray(missing_mask)
        if parity_avail is None:
            parity_avail = jnp.ones((self.r,), bool)
        avail = jnp.concatenate([
            (~missing_mask).astype(jnp.float32),
            jnp.asarray(parity_avail).astype(jnp.float32)])      # [k + r]
        y = jnp.concatenate([outs, parity_outs], axis=0)         # [k + r, ...]
        a = self._design * avail[:, None]                        # [k + r, k]
        g = a.T @ a + 1e-9 * jnp.eye(self.k)
        rhs = jnp.einsum("td,t...->d...", a, y * avail.reshape(
            (-1,) + (1,) * (y.ndim - 1)))
        flat = rhs.reshape(self.k, -1)
        c = jnp.linalg.solve(g, flat).reshape(rhs.shape)         # [k, ...]
        fit = jnp.einsum("td,d...->t...", self._design[:self.k], c)
        mm = missing_mask.reshape((self.k,) + (1,) * (outs.ndim - 1))
        return jnp.where(mm, fit, outs)

    def decode_one(self, parity_out, outputs, missing_idx):
        """r=1 hot path: the refit through (k - 1 members + the parity) is
        a fixed linear combination per missing index, so it routes through
        the same subtraction-decode Pallas kernel as the linear codes."""
        w = self._decode_one_w[missing_idx]               # [k + 1]
        beta = w[self.k]
        # synthesize coeffs c with c[i] = -alpha_i / beta (i != j) and
        # c[j] = 1 / beta: parity_decode computes
        # (parity - sum_i c_i * out_i) / c[j] = beta * parity + alpha . out
        alpha = w[:self.k - 1]                            # [k - 1] weights
        c = np.empty(self.k, np.float64)
        pos = 0
        for i in range(self.k):
            if i == missing_idx:
                c[i] = 1.0 / beta
            else:
                c[i] = -alpha[pos] / beta
                pos += 1
        if self.backend == "pallas":
            from repro.core.scheme import _pallas_decode_one
            return _pallas_decode_one(parity_out, outputs, missing_idx,
                                      jnp.asarray(c, jnp.float32))
        cj = jnp.asarray(c, jnp.float32)
        outs = jnp.asarray(outputs).astype(jnp.float32)
        mask = jnp.arange(self.k) != missing_idx
        avail_sum = jnp.einsum("k,k...->...", cj * mask, outs)
        po = jnp.asarray(parity_out).astype(jnp.float32)
        return (po - avail_sum) / cj[missing_idx]

    # ------------------------------------------------- dynamic-arity rules --
    def recoverable(self, missing_mask, parity_avail):
        """Dynamic arity: every missing member decodes as soon as the total
        arrived-response count (available members + arrived parities)
        reaches k — the decoder interpolates through whatever arrived, so
        there is no per-row or fixed-mask structure to consult."""
        missing_mask = np.asarray(missing_mask, bool)
        parity_avail = np.asarray(parity_avail, bool)
        arrived = (~missing_mask).sum() + parity_avail.sum()
        if arrived >= self.k:
            return missing_mask
        return np.zeros_like(missing_mask)

    def decode_cost(self, n_missing):
        """One refit of the [k, k] system serves ALL missing rows at once,
        so the hint is flat in n_missing (roughly two subtraction decodes
        of setup), unlike the linear default that scales per row."""
        del n_missing
        return 2.0

    # ---------------------------------------------------- Byzantine voting --
    def max_correctable(self, n_arrived: int) -> int:
        """Errors correctable from ``n_arrived`` responses: the surplus
        over k pays 2 responses per corrected error."""
        return max(0, (n_arrived - self.k) // 2)

    def flag_errors(self, member_outs, member_avail, parity_outs,
                    parity_avail):
        """Vote out grossly erroneous responses by subset consistency.

        Given the responses that arrived (``member_avail`` [k] /
        ``parity_avail`` [r] mark arrivals), search for the smallest set of
        e <= (n_arrived - k) / 2 responses whose removal leaves the rest
        consistent with one degree-(k-1) interpolant (residuals under
        ``err_tol``).  Returns boolean ``(member_flags [k],
        parity_flags [r])`` — all False when the group lacks the surplus
        to vote, or when everything is consistent.  Pure numpy: this runs
        on the frontend's decode path, outside jit, on <= k + r responses.
        """
        member_avail = np.asarray(member_avail, bool)
        parity_avail = np.asarray(parity_avail, bool)
        mo = np.asarray(member_outs, np.float64).reshape(self.k, -1)
        po = np.asarray(parity_outs, np.float64).reshape(self.r, -1)
        idxs = np.concatenate([np.nonzero(member_avail)[0],
                               self.k + np.nonzero(parity_avail)[0]])
        n_t = len(idxs)
        mflags = np.zeros(self.k, bool)
        pflags = np.zeros(self.r, bool)
        e_max = self.max_correctable(n_t)
        if e_max < 1:
            return mflags, pflags
        vals = np.concatenate([mo, po], axis=0)[idxs]     # [n_t, D]
        design = self._design_np[idxs]                    # [n_t, k]

        def residual(sel):
            a = design[sel]
            y = vals[sel]
            c, *_ = np.linalg.lstsq(a, y, rcond=None)
            return np.abs(a @ c - y).max()

        if residual(np.arange(n_t)) <= self.err_tol:
            return mflags, pflags                          # all consistent
        for e in range(1, e_max + 1):
            for drop in combinations(range(n_t), e):
                keep = np.setdiff1d(np.arange(n_t), drop)
                if residual(keep) <= self.err_tol:
                    for t in drop:
                        node = idxs[t]
                        if node < self.k:
                            mflags[node] = True
                        else:
                            pflags[node - self.k] = True
                    return mflags, pflags
        return mflags, pflags                              # ambiguous: abstain


register_scheme(
    "approxifer",
    lambda k, r=1, backend="jnp", **kw: ApproxIFERScheme(
        k=k, r=r, backend=backend, **kw))
