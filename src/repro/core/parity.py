"""Parity models (paper §3.3): construction, training-data generation and the
distillation training loop.

A parity model F_P shares the deployed model's architecture (same average
runtime => parity instances keep pace at 1/k the query rate, §5.2.6) but is
trained on parity queries with targets that are the code's linear combination
of deployed-model outputs:

    F_P( E(X_1..X_k) )  ~=  sum_i C[j,i] * F(X_i)      (one model per parity j)

Training data is generated from the deployed model's own training set when
available, else from live queries (§3.3); labels come from deployed-model
inference (distillation) or, when labelled data exists, from summed one-hot
labels — both modes are supported below.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheme import (LinearScheme, ReplicationScheme, get_scheme,
                               scheme_capabilities)
from repro.training.loss import parity_mse
from repro.training.optim import AdamConfig, adam_init, adam_update

# schemes whose (un-overridden) encode is exactly the coeffs product, so the
# per-row training set can be built with one einsum instead of a full encode
_ROW_SEPARABLE_ENCODES = (LinearScheme.encode, ReplicationScheme.encode)

# test hook for the fused encode->forward serving path below: None = fuse
# whenever the (scheme, parity model) pair is eligible, False = always take
# the exact unfused fallback, True = require fusion (raise if ineligible)
_FORCE_FUSED = None


def _first_layer_split(parity_params, parity_fwd):
    """Detect the linear/MLP parity substrate fusion applies to.

    Fusion is sound only when the parity forward is the canonical
    reshape-then-matmul chain, so the check is exact: ``parity_fwd`` must BE
    ``models.linear.linear_fwd`` (params ``{"w": [F, V]}``, tail = identity)
    or ``models.cnn.mlp_fwd`` (params ``{"w": [...], "b": [...]}``, tail =
    bias + relu + the remaining layers), and every parity row's first-layer
    matrix must share one shape.  Returns ``(stacked first-layer weights
    [r, F, V], per-row tail fns)`` or ``None`` (caller falls back to the
    unfused encode + per-row forward)."""
    from repro.models.cnn import mlp_fwd
    from repro.models.linear import linear_fwd

    def one(p):
        if parity_fwd is linear_fwd and isinstance(p, dict) and \
                set(p) == {"w"} and getattr(p["w"], "ndim", 0) == 2:
            return p["w"], None
        if parity_fwd is mlp_fwd and isinstance(p, dict) and \
                set(p) == {"w", "b"} and isinstance(p["w"], (list, tuple)):
            def tail(h, p=p):
                h = h + p["b"][0]
                for i in range(1, len(p["w"])):
                    h = jax.nn.relu(h) @ p["w"][i] + p["b"][i]
                return h
            return p["w"][0], tail
        return None
    splits = [one(p) for p in parity_params]
    if any(s is None for s in splits) or \
            len({tuple(s[0].shape) for s in splits}) != 1:
        return None
    return jnp.stack([jnp.asarray(s[0]) for s in splits]), \
        [s[1] for s in splits]


def fused_parity_outputs(scheme, queries, parity_params, parity_fwd):
    """Serve all r parity rows for stacked coding groups: queries
    [k, B, ...] -> parity outputs [r, B, V].

    The coded hot path (DESIGN.md §12): when ``scheme``'s encode is the
    un-overridden linear coeffs product and every parity model is a
    linear/MLP substrate (see ``_first_layer_split``), encode and the first
    forward matmul run fused — one ``kernels/fused_encode_forward.py``
    launch under ``backend="pallas"``, one fused einsum otherwise — and only
    the per-row MLP tail (bias/relu/rest) runs separately.  Any other
    (scheme, model) pair takes the exact unfused fallback,
    ``scheme.encode`` + per-row ``parity_fwd``."""
    queries = jnp.asarray(queries)
    fusable = type(scheme).encode is LinearScheme.encode and \
        isinstance(scheme, LinearScheme) and _FORCE_FUSED is not False
    split = _first_layer_split(parity_params, parity_fwd) if fusable \
        else None
    if split is not None and \
            split[0].shape[1] == int(np.prod(queries.shape[2:])):
        weights, tails = split
        h = scheme.encode_forward(queries, weights)          # [r, B, V1]
        return jnp.stack([h[j] if tails[j] is None else tails[j](h[j])
                          for j in range(scheme.r)])
    if _FORCE_FUSED is True:
        raise ValueError(
            "fused parity serving forced (_FORCE_FUSED=True) but the "
            "(scheme, parity model) pair is not fusable")
    enc = scheme.encode(queries)
    return jnp.stack([parity_fwd(parity_params[j], enc[j])
                      for j in range(scheme.r)])


def group_queries(x, k, rng):
    """Randomly group n samples into floor(n/k) coding groups: [G, k, ...]."""
    n = (len(x) // k) * k
    order = rng.permutation(len(x))[:n]
    return x[order].reshape(len(x) // k, k, *x.shape[1:]), order[:n]


def make_parity_dataset(x, fx, k, scheme, j, rng):
    """Training set for the j-th parity model: parity queries are the
    scheme's j-th encoded row, targets the j-th coefficient-row combination
    of deployed outputs.

    x: queries [n, ...]; fx: deployed outputs F(x) [n, V].
    Returns (parity queries [G, ...], targets [G, ...])."""
    groups, order = group_queries(x, k, rng)
    fx_groups = fx[order].reshape(groups.shape[0], k, *fx.shape[1:])
    coeff_row = np.asarray(scheme.coeffs, np.float32)[j]
    if type(scheme).encode in _ROW_SEPARABLE_ENCODES:
        # un-overridden linear encode: compute only row j instead of encoding
        # all r rows over the full training set and keeping one
        parities = np.einsum("k,gk...->g...", coeff_row, groups)
    else:
        # custom encoders (concat, learned): the parity model must train on
        # exactly what the frontend will feed it — [k, G, ...] -> [r, G, ...]
        parities = np.asarray(scheme.encode(np.moveaxis(groups, 1, 0)))[j]
    targets = np.einsum("k,gk...->g...", coeff_row, fx_groups)
    return np.asarray(parities, np.float32), np.asarray(targets, np.float32)


@dataclass
class ParityTrainer:
    """Trains one parity model with MSE distillation (Adam, paper §4.1
    hyperparameters: lr=1e-3, L2=1e-5, minibatch 32-64)."""
    fwd: callable                   # fwd(params, x) -> outputs
    opt: AdamConfig = AdamConfig(lr=1e-3, weight_decay=1e-5)

    def train(self, params, parities, targets, batch=64, epochs=5, seed=0,
              log_every=0):
        opt_state = adam_init(params, self.opt)

        @jax.jit
        def step(params, opt_state, xb, yb):
            def loss_fn(p):
                return parity_mse(self.fwd(p, xb), yb)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = adam_update(grads, opt_state, params,
                                            self.opt)
            return params, opt_state, loss

        rng = np.random.default_rng(seed)
        losses = []
        n = len(parities)
        for ep in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch + 1, batch):
                sel = order[i:i + batch]
                params, opt_state, loss = step(params, opt_state,
                                               parities[sel], targets[sel])
                losses.append(float(loss))
            if log_every:
                print(f"  parity epoch {ep}: loss={losses[-1]:.5f}")
        return params, losses


def _train_joint(scheme, parity_fwd, init_fn, x, fx, epochs, seed, batch,
                 opt=None, log_every=0):
    """Joint encoder + parity objective for trainable schemes (DESIGN.md §7):
    minimise  mean_j MSE( F_P_j( E_theta(X)_j ),  sum_i C[j,i] F(X_i) )
    over (theta, parity params) together.  The decode targets stay the
    linear ``coeffs`` combination — the *output* code is untouched, so the
    scheme's decode / recoverability semantics hold for the trained encoder.

    Returns ``(parity_params list, scheme.with_params(trained_theta))``."""
    k, r = scheme.k, scheme.r
    rng = np.random.default_rng(seed)
    groups, order = group_queries(np.asarray(x), k, rng)        # [G, k, ...]
    fxg = fx[order].reshape(groups.shape[0], k, *fx.shape[1:])
    C = np.asarray(scheme.coeffs, np.float32)
    targets = np.einsum("rk,gk...->rg...", C, fxg)              # [r, G, V]
    qk = np.ascontiguousarray(np.moveaxis(groups, 1, 0))        # [k, G, ...]
    params = {"enc": scheme.enc_params,
              "parity": [init_fn(jax.random.PRNGKey(seed + 17 * j))
                         for j in range(r)]}
    opt = opt or AdamConfig(lr=1e-3, weight_decay=1e-5)
    state = adam_init(params, opt)

    @jax.jit
    def step(params, state, qb, tb):
        def loss_fn(p):
            enc_q = scheme.encode_with_params(p["enc"], qb)     # [r, b, ...]
            return sum(parity_mse(parity_fwd(p["parity"][j], enc_q[j]),
                                  tb[j]) for j in range(r)) / r
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = adam_update(grads, state, params, opt)
        return params, state, loss

    n_groups = groups.shape[0]
    b = min(batch, n_groups)
    losses = []
    for ep in range(epochs):
        order = rng.permutation(n_groups)
        for i in range(0, n_groups - b + 1, b):
            sel = order[i:i + b]
            params, state, loss = step(params, state,
                                       jnp.asarray(qk[:, sel]),
                                       jnp.asarray(targets[:, sel]))
            losses.append(float(loss))
        if log_every:
            print(f"  joint encoder+parity epoch {ep}: "
                  f"loss={losses[-1]:.5f}")
    return params["parity"], scheme.with_params(params["enc"]), losses


@dataclass
class ParityTrainContext:
    """Everything a scheme's ``provision_parity`` hook may need (DESIGN.md
    §14): the deployed forward fn, a parity-model initialiser, training /
    calibration data and the distillation hyperparameters.

    ``scheme`` starts as the scheme being provisioned and is REPLACED by the
    joint-training path when the encoder itself is trained (``learned``) —
    ``train_parity_models`` returns ``ctx.scheme``, so a hook that retrains
    or re-parameterises the scheme publishes the new instance here.

    ``deployed_outputs(deployed_params)`` lazily computes (and caches) the
    distillation targets F(x_train) — or the scaled one-hot labels when
    ``use_true_labels`` — so training-free hooks (fisher, invnet,
    approxifer) never pay for the full forward pass."""

    fwd: Callable                        # fwd(params, x) -> outputs
    init_fn: Optional[Callable]          # init_fn(key) -> parity params
    x_train: Any                         # [n, ...] queries
    epochs: int = 5
    seed: int = 0
    batch: int = 64
    use_true_labels: bool = False
    labels: Any = None
    n_classes: Optional[int] = None
    parity_fwd: Optional[Callable] = None   # defaults to fwd
    scheme: Any = None                   # published (possibly retrained)
    _fx: Any = field(default=None, repr=False)

    @property
    def pfwd(self):
        return self.parity_fwd or self.fwd

    def deployed_outputs(self, deployed_params):
        if self._fx is None:
            if self.use_true_labels:
                # scaled one-hot labels (paper §4.1's label-sum variant)
                self._fx = np.eye(self.n_classes,
                                  dtype=np.float32)[self.labels] * 10.0
            else:
                self._fx = np.asarray(jax.jit(self.fwd)(
                    deployed_params, jnp.asarray(self.x_train)))
        return self._fx


def default_provision(scheme, deployed_params, ctx: ParityTrainContext):
    """The stock provisioning path schemes delegate to: per-row MSE
    distillation (paper §3.3), or the joint encoder+parity objective for
    ``trainable`` schemes (the trained scheme is published on
    ``ctx.scheme``).  Legacy attribute-style ``model_agnostic`` schemes
    (no ``provision_parity`` of their own) still short-circuit to r
    references of the deployed params here."""
    caps = scheme_capabilities(scheme)
    if caps.model_agnostic:
        return [deployed_params] * scheme.r
    fx = ctx.deployed_outputs(deployed_params)
    if caps.trainable:
        parity_params, trained, _ = _train_joint(
            scheme, ctx.pfwd, ctx.init_fn, ctx.x_train, fx,
            epochs=ctx.epochs, seed=ctx.seed, batch=ctx.batch)
        ctx.scheme = trained
        return parity_params
    rng = np.random.default_rng(ctx.seed)
    parity_params = []
    for j in range(scheme.r):
        pq, tg = make_parity_dataset(np.asarray(ctx.x_train), fx, scheme.k,
                                     scheme, j, rng)
        key = jax.random.PRNGKey(ctx.seed + 17 * j)
        pp = ctx.init_fn(key)
        trainer = ParityTrainer(fwd=ctx.pfwd)
        pp, _ = trainer.train(pp, pq, tg, batch=ctx.batch, epochs=ctx.epochs,
                              seed=ctx.seed + j)
        parity_params.append(pp)
    return parity_params


def train_parity_models(deployed_params, fwd, init_fn, x_train, k, r=None,
                        scheme="sum", epochs=5, seed=0, batch=64,
                        use_true_labels=False, labels=None, n_classes=None,
                        encoder_kind=None, parity_fwd=None):
    """End-to-end §3.3 pipeline, dispatched through the scheme-owned
    ``provision_parity(deployed_params, ctx)`` hook (DESIGN.md §14): trains
    (or merges, or aliases) one parity params list per parity row of
    ``scheme`` (a ``CodingScheme`` instance or registered name; ``r``
    defaults to 1 for names and to the scheme's own r for instances — an
    explicit mismatch raises).  Grouping follows ``scheme.k`` — a
    ``fixes_k`` scheme (approx_backup: k=1) owns its group size, which turns
    the default distillation into plain backup-model training for it.

    What provisioning means is the scheme's call:

    * default (``sum``/``concat``/``replication``/``approx_backup``) — the
      per-row MSE distillation loop (``default_provision``);
    * ``learned`` — the joint encoder+parity objective; the *returned
      scheme* carries the trained, frozen encoder;
    * ``approxifer`` / ``invnet`` — no training at all: the deployed model
      itself serves the encoded queries (r references to
      ``deployed_params``);
    * ``fisher`` — Fisher-weighted checkpoint merging; zero gradient steps.

    ``parity_fwd`` lets the parity model be a different architecture from
    the deployed model (the approx_backup scheme's cheap backup); defaults
    to ``fwd``.

    Returns ``(list of scheme.r parity params, scheme)`` — the scheme object
    carries ``encode`` / ``decode`` / ``decode_one`` / ``coeffs`` for
    serving."""
    if encoder_kind is not None:
        raise TypeError(
            "train_parity_models(encoder_kind=...) was removed; pass "
            "scheme= (a registered name or CodingScheme instance), e.g. "
            "train_parity_models(..., scheme='sum')")
    scheme = get_scheme(scheme, k=k, r=r)
    ctx = ParityTrainContext(
        fwd=fwd, init_fn=init_fn, x_train=x_train, epochs=epochs, seed=seed,
        batch=batch, use_true_labels=use_true_labels, labels=labels,
        n_classes=n_classes, parity_fwd=parity_fwd, scheme=scheme)
    hook = getattr(type(scheme), "provision_parity", None)
    if hook is None:
        parity_params = default_provision(scheme, deployed_params, ctx)
    else:
        parity_params = hook(scheme, deployed_params, ctx)
    return parity_params, ctx.scheme
