"""Parity models (paper §3.3): construction, training-data generation and the
distillation training loop.

A parity model F_P shares the deployed model's architecture (same average
runtime => parity instances keep pace at 1/k the query rate, §5.2.6) but is
trained on parity queries with targets that are the code's linear combination
of deployed-model outputs:

    F_P( E(X_1..X_k) )  ~=  sum_i C[j,i] * F(X_i)      (one model per parity j)

Training data is generated from the deployed model's own training set when
available, else from live queries (§3.3); labels come from deployed-model
inference (distillation) or, when labelled data exists, from summed one-hot
labels — both modes are supported below.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codes import SumEncoder, ConcatEncoder, LinearDecoder
from repro.training.loss import parity_mse
from repro.training.optim import AdamConfig, adam_init, adam_update


def group_queries(x, k, rng):
    """Randomly group n samples into floor(n/k) coding groups: [G, k, ...]."""
    n = (len(x) // k) * k
    order = rng.permutation(len(x))[:n]
    return x[order].reshape(len(x) // k, k, *x.shape[1:]), order[:n]


def make_parity_dataset(x, fx, k, encoder, coeff_row, rng):
    """Returns (parity queries [G, ...], targets [G, ...]).

    x: queries [n, ...]; fx: deployed outputs F(x) [n, V]."""
    groups, order = group_queries(x, k, rng)
    fx_groups = fx[order].reshape(groups.shape[0], k, *fx.shape[1:])
    # encoder consumes [k, B, ...]
    parities = encoder(np.moveaxis(groups, 1, 0))[  # [r, G, ...] -> row 0
        0] if isinstance(encoder, ConcatEncoder) else None
    if parities is None:
        c = np.asarray(coeff_row, np.float32)
        parities = np.einsum("k,gk...->g...", c, groups)
    targets = np.einsum("k,gk...->g...", np.asarray(coeff_row, np.float32),
                        fx_groups)
    return np.asarray(parities, np.float32), np.asarray(targets, np.float32)


@dataclass
class ParityTrainer:
    """Trains one parity model with MSE distillation (Adam, paper §4.1
    hyperparameters: lr=1e-3, L2=1e-5, minibatch 32-64)."""
    fwd: callable                   # fwd(params, x) -> outputs
    opt: AdamConfig = AdamConfig(lr=1e-3, weight_decay=1e-5)

    def train(self, params, parities, targets, batch=64, epochs=5, seed=0,
              log_every=0):
        opt_state = adam_init(params, self.opt)

        @jax.jit
        def step(params, opt_state, xb, yb):
            def loss_fn(p):
                return parity_mse(self.fwd(p, xb), yb)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = adam_update(grads, opt_state, params,
                                            self.opt)
            return params, opt_state, loss

        rng = np.random.default_rng(seed)
        losses = []
        n = len(parities)
        for ep in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch + 1, batch):
                sel = order[i:i + batch]
                params, opt_state, loss = step(params, opt_state,
                                               parities[sel], targets[sel])
                losses.append(float(loss))
            if log_every:
                print(f"  parity epoch {ep}: loss={losses[-1]:.5f}")
        return params, losses


def train_parity_models(deployed_params, fwd, init_fn, x_train, k, r=1,
                        encoder_kind="sum", epochs=5, seed=0, batch=64,
                        use_true_labels=False, labels=None, n_classes=None):
    """End-to-end §3.3 pipeline. Returns (list of r parity params, encoder,
    decoder)."""
    from repro.core.codes import make_code, vandermonde
    encoder, decoder = make_code(k, r, encoder_kind)
    fx = np.asarray(jax.jit(fwd)(deployed_params, jnp.asarray(x_train)))
    if use_true_labels:
        fx = np.eye(n_classes, dtype=np.float32)[labels] * 10.0  # scaled one-hot
    C = vandermonde(k, r)
    rng = np.random.default_rng(seed)
    parity_params = []
    for j in range(r):
        pq, tg = make_parity_dataset(np.asarray(x_train), fx, k, encoder,
                                     C[j], rng)
        key = jax.random.PRNGKey(seed + 17 * j)
        pp = init_fn(key)
        trainer = ParityTrainer(fwd=fwd)
        pp, _ = trainer.train(pp, pq, tg, batch=batch, epochs=epochs,
                              seed=seed + j)
        parity_params.append(pp)
    return parity_params, encoder, decoder
