"""Parity models (paper §3.3): construction, training-data generation and the
distillation training loop.

A parity model F_P shares the deployed model's architecture (same average
runtime => parity instances keep pace at 1/k the query rate, §5.2.6) but is
trained on parity queries with targets that are the code's linear combination
of deployed-model outputs:

    F_P( E(X_1..X_k) )  ~=  sum_i C[j,i] * F(X_i)      (one model per parity j)

Training data is generated from the deployed model's own training set when
available, else from live queries (§3.3); labels come from deployed-model
inference (distillation) or, when labelled data exists, from summed one-hot
labels — both modes are supported below.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheme import LinearScheme, ReplicationScheme, get_scheme
from repro.training.loss import parity_mse
from repro.training.optim import AdamConfig, adam_init, adam_update

# schemes whose (un-overridden) encode is exactly the coeffs product, so the
# per-row training set can be built with one einsum instead of a full encode
_ROW_SEPARABLE_ENCODES = (LinearScheme.encode, ReplicationScheme.encode)


def group_queries(x, k, rng):
    """Randomly group n samples into floor(n/k) coding groups: [G, k, ...]."""
    n = (len(x) // k) * k
    order = rng.permutation(len(x))[:n]
    return x[order].reshape(len(x) // k, k, *x.shape[1:]), order[:n]


def make_parity_dataset(x, fx, k, scheme, j, rng):
    """Training set for the j-th parity model: parity queries are the
    scheme's j-th encoded row, targets the j-th coefficient-row combination
    of deployed outputs.

    x: queries [n, ...]; fx: deployed outputs F(x) [n, V].
    Returns (parity queries [G, ...], targets [G, ...])."""
    groups, order = group_queries(x, k, rng)
    fx_groups = fx[order].reshape(groups.shape[0], k, *fx.shape[1:])
    coeff_row = np.asarray(scheme.coeffs, np.float32)[j]
    if type(scheme).encode in _ROW_SEPARABLE_ENCODES:
        # un-overridden linear encode: compute only row j instead of encoding
        # all r rows over the full training set and keeping one
        parities = np.einsum("k,gk...->g...", coeff_row, groups)
    else:
        # custom encoders (concat, learned): the parity model must train on
        # exactly what the frontend will feed it — [k, G, ...] -> [r, G, ...]
        parities = np.asarray(scheme.encode(np.moveaxis(groups, 1, 0)))[j]
    targets = np.einsum("k,gk...->g...", coeff_row, fx_groups)
    return np.asarray(parities, np.float32), np.asarray(targets, np.float32)


@dataclass
class ParityTrainer:
    """Trains one parity model with MSE distillation (Adam, paper §4.1
    hyperparameters: lr=1e-3, L2=1e-5, minibatch 32-64)."""
    fwd: callable                   # fwd(params, x) -> outputs
    opt: AdamConfig = AdamConfig(lr=1e-3, weight_decay=1e-5)

    def train(self, params, parities, targets, batch=64, epochs=5, seed=0,
              log_every=0):
        opt_state = adam_init(params, self.opt)

        @jax.jit
        def step(params, opt_state, xb, yb):
            def loss_fn(p):
                return parity_mse(self.fwd(p, xb), yb)
            loss, grads = jax.value_and_grad(loss_fn)(params)
            params, opt_state = adam_update(grads, opt_state, params,
                                            self.opt)
            return params, opt_state, loss

        rng = np.random.default_rng(seed)
        losses = []
        n = len(parities)
        for ep in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch + 1, batch):
                sel = order[i:i + batch]
                params, opt_state, loss = step(params, opt_state,
                                               parities[sel], targets[sel])
                losses.append(float(loss))
            if log_every:
                print(f"  parity epoch {ep}: loss={losses[-1]:.5f}")
        return params, losses


def train_parity_models(deployed_params, fwd, init_fn, x_train, k, r=None,
                        scheme="sum", epochs=5, seed=0, batch=64,
                        use_true_labels=False, labels=None, n_classes=None,
                        encoder_kind=None):
    """End-to-end §3.3 pipeline: trains one parity model per parity row of
    ``scheme`` (a ``CodingScheme`` instance or registered name; ``r`` defaults
    to 1 for names and to the scheme's own r for instances — an explicit
    mismatch raises).

    Returns ``(list of scheme.r parity params, scheme)`` — the scheme object
    carries ``encode`` / ``decode`` / ``decode_one`` / ``coeffs`` for serving.

    ``encoder_kind=`` is a deprecated alias for ``scheme=``."""
    if encoder_kind is not None:
        warnings.warn(
            "train_parity_models(encoder_kind=...) is deprecated; pass "
            "scheme= (a registered name or CodingScheme instance)",
            DeprecationWarning, stacklevel=2)
        scheme = encoder_kind
    scheme = get_scheme(scheme, k=k, r=r)
    fx = np.asarray(jax.jit(fwd)(deployed_params, jnp.asarray(x_train)))
    if use_true_labels:
        fx = np.eye(n_classes, dtype=np.float32)[labels] * 10.0  # scaled one-hot
    rng = np.random.default_rng(seed)
    parity_params = []
    for j in range(scheme.r):
        pq, tg = make_parity_dataset(np.asarray(x_train), fx, k, scheme,
                                     j, rng)
        key = jax.random.PRNGKey(seed + 17 * j)
        pp = init_fn(key)
        trainer = ParityTrainer(fwd=fwd)
        pp, _ = trainer.train(pp, pq, tg, batch=batch, epochs=epochs,
                              seed=seed + j)
        parity_params.append(pp)
    return parity_params, scheme
