"""Accuracy metrics of the paper (§4.1): available accuracy A_a, degraded-mode
accuracy A_d (every one-of-k-unavailable scenario simulated, as the paper's
evaluation does), and overall accuracy A_o(f_u) = (1-f_u) A_a + f_u A_d."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def topk_accuracy(logits, labels, k=1):
    if k == 1:
        return float((np.argmax(logits, -1) == labels).mean())
    topk = np.argsort(logits, -1)[:, -k:]
    return float((topk == labels[:, None]).any(-1).mean())


def degraded_accuracy(parity_outs, member_outs, labels, decoder, topk=1):
    """Simulate each one-unavailable scenario per coding group.

    parity_outs [G, r, V]; member_outs [G, k, V]; labels [G, k].
    Returns A_d — accuracy of reconstructed predictions only."""
    G, k, V = member_outs.shape
    hits, total = 0, 0
    for j in range(k):
        recon = np.asarray(jax.vmap(
            lambda po, mo: decoder.decode_one(po[0], mo, j))(
                jnp.asarray(parity_outs), jnp.asarray(member_outs)))
        hits += _topk_hits(recon, labels[:, j], topk)
        total += G
    return hits / total


def _topk_hits(logits, labels, k):
    if k == 1:
        return int((np.argmax(logits, -1) == labels).sum())
    topk = np.argsort(logits, -1)[:, -k:]
    return int((topk == labels[:, None]).any(-1).sum())


def overall_accuracy(a_a, a_d, f_u):
    """Paper Eq. (1)."""
    return (1.0 - f_u) * a_a + f_u * a_d


def default_prediction_accuracy(n_classes):
    """Clipper's baseline: return a default prediction when the SLO is
    violated — no better than a random/constant guess."""
    return 1.0 / n_classes


def iou(box_a, box_b):
    """Intersection-over-union for the object-localization task (§4.2.1).
    Boxes [..., 4] as (x0, y0, x1, y1)."""
    ax0, ay0, ax1, ay1 = np.moveaxis(box_a, -1, 0)
    bx0, by0, bx1, by1 = np.moveaxis(box_b, -1, 0)
    ix = np.maximum(0, np.minimum(ax1, bx1) - np.maximum(ax0, bx0))
    iy = np.maximum(0, np.minimum(ay1, by1) - np.maximum(ay0, by0))
    inter = ix * iy
    area_a = np.maximum(0, ax1 - ax0) * np.maximum(0, ay1 - ay0)
    area_b = np.maximum(0, bx1 - bx0) * np.maximum(0, by1 - by0)
    return inter / np.maximum(area_a + area_b - inter, 1e-9)
