"""Composable model assembly for all assigned architecture families.

A model is a stack of ``n_layers`` layers with a repeating *superblock* of
length ``cfg.period`` (1 for uniform stacks; 8 for Jamba's 1-attn:7-mamba
interleave; 5 for Llama-vision's cross-attn insertion; ...). Parameters for
the superblocks are stacked along a leading "group" axis and the stack is
executed with ``lax.scan`` (+ optional remat), which keeps compiled HLO size
independent of depth — essential for 94-layer dry-runs on the 512-device mesh.

Three entry points per model:
  * ``forward``      — full-sequence teacher-forced logits (training)
  * ``prefill``      — full-sequence + returns per-layer KV/SSM caches
  * ``decode_step``  — one token through the cached stack (serving decode)
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE


# --------------------------------------------------------------------------
# Layer plan
# --------------------------------------------------------------------------
def layer_plan(cfg, role="decoder"):
    """Tuple of per-layer specs for one superblock period."""
    plan = []
    for i in range(cfg.period):
        if role == "encoder":
            plan.append({"mixer": "attn", "cross": False, "ffn": "mlp",
                         "causal": False})
            continue
        if cfg.attn_every:                       # hybrid (jamba)
            mixer = "attn" if i == cfg.attn_every // 2 else "mamba"
        elif cfg.family == "ssm":
            mixer = "mamba"
        elif cfg.cross_attn_every and i == cfg.cross_attn_every - 1:
            mixer = "none"                       # VLM cross-attn layer
        else:
            mixer = "attn"
        cross = bool(cfg.cross_attn_every and i == cfg.cross_attn_every - 1)
        if cfg.enc_dec and role == "decoder":
            cross = True
        if cfg.family == "ssm":
            ffn = "none"
        elif cfg.n_experts and (i % cfg.moe_every == cfg.moe_every - 1):
            ffn = "moe"
        else:
            ffn = "mlp"
        plan.append({"mixer": mixer, "cross": cross, "ffn": ffn,
                     "causal": True})
    return tuple(plan)


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def init_layer(cfg, key, spec):
    ks = jax.random.split(key, 4)
    p = {}
    if spec["mixer"] == "attn":
        p["attn"] = L.init_attention(cfg, ks[0])
    elif spec["mixer"] == "mamba":
        p["mamba"] = M.init_mamba(cfg, ks[0])
    if spec["cross"]:
        p["cross"] = L.init_attention(cfg, ks[1], cross=True)
    if spec["ffn"] == "mlp":
        p["mlp"] = L.init_mlp(cfg, ks[2])
    elif spec["ffn"] == "moe":
        p["moe"] = MOE.init_moe(cfg, ks[2])
    return p


def _init_stack(cfg, key, n_groups, plan):
    def one_group(k):
        kl = jax.random.split(k, len(plan))
        return tuple(init_layer(cfg, kl[i], plan[i])
                     for i in range(len(plan)))
    return jax.vmap(one_group)(jax.random.split(key, n_groups))


def init_params(cfg, key):
    ks = jax.random.split(key, 5)
    dt = cfg.dtype
    D, V = cfg.d_model, cfg.vocab
    p = {
        "embed": (jax.random.normal(ks[0], (V, D)) * 0.02).astype(dt),
        "blocks": _init_stack(cfg, ks[1], cfg.n_groups, layer_plan(cfg)),
        "final_norm": L.make_norm(cfg, D),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[2], (D, V))
                        / math.sqrt(D)).astype(dt)
    if cfg.enc_dec:
        assert cfg.n_enc_layers % cfg.period == 0
        p["encoder"] = {
            "blocks": _init_stack(cfg, ks[3], cfg.n_enc_layers // cfg.period,
                                  layer_plan(cfg, role="encoder")),
            "final_norm": L.make_norm(cfg, D),
        }
    return p


# --------------------------------------------------------------------------
# Single layer forward
# --------------------------------------------------------------------------
def _layer_fwd(cfg, spec, p, x, ctx):
    """Full-sequence layer. Returns (x, aux, cache_entry)."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    if spec["mixer"] == "attn":
        h = L.apply_norm(cfg, p["attn"]["norm"], x)
        o, (k, v) = L.self_attention_fwd(
            cfg, p["attn"], h, ctx["rope"], window=ctx["window"]) \
            if spec["causal"] else _bidir_attn(cfg, p["attn"], h, ctx)
        x = x + o
        if ctx["collect_cache"]:
            W = ctx["window"]
            if W and k.shape[1] > W:
                k, v = k[:, -W:], v[:, -W:]
            pad = ctx["cache_len"] - k.shape[1]
            if pad > 0:
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cache["attn"] = {"k": k, "v": v}
    elif spec["mixer"] == "mamba":
        h = L.apply_norm(cfg, p["mamba"]["norm"], x)
        o, state = M.ssd_fwd(cfg, p["mamba"], h,
                             return_state=ctx["collect_cache"])
        x = x + o
        if ctx["collect_cache"]:
            cache["ssm"] = state
    if spec["cross"]:
        h = L.apply_norm(cfg, p["cross"]["cross_norm"], x)
        o, (ck, cv) = L.cross_attention_fwd(cfg, p["cross"], h,
                                            ctx["cross_embeds"])
        x = x + o
        if ctx["collect_cache"]:
            cache["cross"] = {"k": ck, "v": cv}
    if spec["ffn"] == "mlp":
        h = L.apply_norm(cfg, p["mlp"]["norm"], x)
        x = x + L.mlp_fwd(cfg, p["mlp"], h)
    elif spec["ffn"] == "moe":
        h = L.apply_norm(cfg, p["moe"]["norm"], x)
        o, a = MOE.moe_fwd(cfg, p["moe"], h)
        x = x + o
        aux = aux + a
    return x, aux, cache


def _bidir_attn(cfg, p, h, ctx):
    q, k, v = L._qkv(cfg, p, h, h)
    cos, sin = ctx["rope"]
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)
    o = L.flash_attention_xla(q, k, v, causal=False)
    B, S, H, hd = o.shape
    return o.reshape(B, S, H * hd) @ p["wo"], (k, v)


def _layer_decode(cfg, spec, p, x, lcache, pos, ctx):
    """One-token layer step. Returns (x, new_cache_entry)."""
    new = {}
    if spec["mixer"] == "attn":
        h = L.apply_norm(cfg, p["attn"]["norm"], x)
        o, kv = L.self_attention_decode(cfg, p["attn"], h, lcache["attn"],
                                        pos, ctx["rope"],
                                        window=ctx["window"])
        x = x + o
        new["attn"] = kv
    elif spec["mixer"] == "mamba":
        h = L.apply_norm(cfg, p["mamba"]["norm"], x)
        o, st = M.ssd_decode(cfg, p["mamba"], h, lcache["ssm"])
        x = x + o
        new["ssm"] = st
    if spec["cross"]:
        h = L.apply_norm(cfg, p["cross"]["cross_norm"], x)
        ck, cv = lcache["cross"]["k"], lcache["cross"]["v"]
        o, _ = L.cross_attention_fwd(cfg, p["cross"], h, (ck, cv),
                                     from_cache=True)
        x = x + o
        new["cross"] = lcache["cross"]
    if spec["ffn"] == "mlp":
        h = L.apply_norm(cfg, p["mlp"]["norm"], x)
        x = x + L.mlp_fwd(cfg, p["mlp"], h)
    elif spec["ffn"] == "moe":
        h = L.apply_norm(cfg, p["moe"]["norm"], x)
        o, _ = MOE.moe_fwd(cfg, p["moe"], h)
        x = x + o
    return x, new


# --------------------------------------------------------------------------
# Stack (scan over superblocks)
# --------------------------------------------------------------------------
def _stack_fwd(cfg, stacked, x, ctx, plan, remat=False):
    def body(carry, gp):
        x, aux = carry
        x = constrain(x, ("batch", None, None))
        caches = []
        for i, spec in enumerate(plan):
            x, a, c = _layer_fwd(cfg, spec, gp[i], x, ctx)
            aux = aux + a
            caches.append(c)
        return (constrain(x, ("batch", None, None)), aux), tuple(caches)

    if remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    stacked)
    return x, aux, caches


def _stack_decode(cfg, stacked, caches, x, pos, ctx, plan):
    def body(x, inp):
        gp, gc = inp
        new = []
        for i, spec in enumerate(plan):
            x, c = _layer_decode(cfg, spec, gp[i], x, gc[i], pos, ctx)
            new.append(c)
        return x, tuple(new)

    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------
def _embed(cfg, params, tokens=None, embeds=None):
    if embeds is not None:
        return embeds.astype(cfg.dtype)
    return params["embed"][tokens]


def embed_tokens(cfg, params, tokens):
    """Public: token -> embedding (used by the ParM embedding-space encoder)."""
    return params["embed"][tokens]


def _logits(cfg, params, x, logits_pspec=None):
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = (x @ head).astype(jnp.float32)
    # keep the fp32 logits vocab-sharded on the tensor axis — unsharded
    # [B*S, V] fp32 logits dominate train-step HBM otherwise
    out = constrain(out, ("batch", None, "vocab"))
    if logits_pspec is not None:
        out = jax.lax.with_sharding_constraint(out, logits_pspec)
    return out


def _make_ctx(cfg, S, *, q_offset=0, cross_embeds=None, collect_cache=False,
              cache_len=0):
    pos = q_offset + jnp.arange(S)
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    rope = (L.rope_tables(pos, hd, cfg.rope_theta) if hd else (None, None))
    return {"rope": rope, "window": cfg.sliding_window,
            "cross_embeds": cross_embeds, "collect_cache": collect_cache,
            "cache_len": cache_len}


def run_encoder(cfg, params, frames):
    """Seamless encoder over stubbed frame embeddings [B, S_src, D]."""
    ctx = _make_ctx(cfg, frames.shape[1])
    plan = layer_plan(cfg, role="encoder")
    x = frames.astype(cfg.dtype)
    x, _, _ = _stack_fwd(cfg, params["encoder"]["blocks"], x, ctx, plan)
    return L.apply_norm(cfg, params["encoder"]["final_norm"], x)


def forward(cfg, params, tokens=None, embeds=None, cross_embeds=None,
            remat=False, logits_pspec=None, unembed_last_only=False):
    """Teacher-forced full-sequence logits. Returns (logits_f32, aux).

    ``unembed_last_only`` skips the [B, S, V] unembed and projects only the
    final position — the serving prefill only consumes the last token."""
    if cfg.enc_dec:
        cross_embeds = run_encoder(cfg, params, cross_embeds)
    x = _embed(cfg, params, tokens, embeds)
    ctx = _make_ctx(cfg, x.shape[1], cross_embeds=cross_embeds)
    x, aux, _ = _stack_fwd(cfg, params["blocks"], x, ctx, layer_plan(cfg),
                           remat=remat)
    if unembed_last_only:
        x = x[:, -1:]
    return _logits(cfg, params, x, logits_pspec), aux


def prefill(cfg, params, tokens=None, embeds=None, cross_embeds=None,
            cache_len=0):
    """Process the prompt; returns (last-token logits_f32, cache).

    ``cache_len`` reserves decode slots (>= prompt length, or == window for
    sliding-window archs)."""
    if cfg.enc_dec:
        cross_embeds = run_encoder(cfg, params, cross_embeds)
    x = _embed(cfg, params, tokens, embeds)
    S = x.shape[1]
    if not cache_len:
        cache_len = min(S, cfg.sliding_window) if cfg.sliding_window else S
    ctx = _make_ctx(cfg, S, cross_embeds=cross_embeds, collect_cache=True,
                    cache_len=cache_len)
    x, aux, caches = _stack_fwd(cfg, params["blocks"], x, ctx,
                                layer_plan(cfg))
    return _logits(cfg, params, x[:, -1:]), caches


def decode_step(cfg, params, cache, pos, token=None, embed=None):
    """One decode step at position ``pos`` (0-based, == #tokens already in
    cache). ``pos`` may be a scalar (whole batch at one position) or a [B]
    vector of per-row positions — the slot-batched continuous-decoding path,
    where each batch row is an independent stream. Returns
    (logits_f32 [B,1,V], new_cache)."""
    x = _embed(cfg, params, token, embed)
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    if jnp.ndim(pos):
        # rope tables per batch row: [B, hd//2], consumed by the
        # apply_rope_rows branch inside self_attention_decode
        rope = (L.rope_tables(pos, hd, cfg.rope_theta)
                if hd else (None, None))
    else:
        rope = (L.rope_tables(jnp.full((1,), pos), hd, cfg.rope_theta)
                if hd else (None, None))
    ctx = {"rope": rope, "window": cfg.sliding_window, "cross_embeds": None,
           "collect_cache": False, "cache_len": 0}
    x, new_caches = _stack_decode(cfg, params["blocks"], cache, x, pos, ctx,
                                  layer_plan(cfg))
    return _logits(cfg, params, x), new_caches


def init_cache(cfg, batch, cache_len):
    """Zero caches for decode-only entry (dry-run decode shapes)."""
    plan = layer_plan(cfg)
    S = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len

    def one_layer(spec):
        c = {}
        if spec["mixer"] == "attn":
            c["attn"] = L.init_attn_cache(cfg, batch, S)
        elif spec["mixer"] == "mamba":
            c["ssm"] = M.init_ssm_cache(cfg, batch)
        if spec["cross"]:
            n_ctx = cfg.n_modality_tokens or 1
            KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
            c["cross"] = {"k": jnp.zeros((batch, n_ctx, KV, hd), cfg.dtype),
                          "v": jnp.zeros((batch, n_ctx, KV, hd), cfg.dtype)}
        return c

    per_group = tuple(one_layer(s) for s in plan)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape), per_group)


def param_count(params):
    return sum(x.size for x in jax.tree.leaves(params))
