"""Mixture-of-Experts FFN — GShard-style top-k routing with fixed expert
capacity, implemented with scatter/gather dispatch (no [T, E, C] one-hot
tensor is ever materialised).

Sharding intent (see repro.distributed.sharding): expert weight tensors
[E, D, F] shard E over the 'model' axis and D over 'data' (FSDP); the
dispatch buffer [E, C, D] shards E over 'model' and C over 'data', so the
scatter/gather lowers to an all-to-all between the token-sharded and
expert-sharded layouts — the canonical expert-parallel schedule.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models.layers import make_norm


def init_moe(cfg, key):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = cfg.dtype

    def dense(k, shape, fan_in):
        return (jax.random.normal(k, shape) / math.sqrt(fan_in)).astype(dt)

    p = {
        "router": dense(ks[0], (D, E), D).astype(jnp.float32),
        "w1": dense(ks[1], (E, D, F), D),
        "w3": dense(ks[2], (E, D, F), D),
        "w2": dense(ks[3], (E, F, D), F),
        "norm": make_norm(cfg, D),
    }
    if cfg.n_shared_experts:
        # shared experts fused into one dense SwiGLU of width n_shared * F
        SF = cfg.n_shared_experts * F
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w1": dense(sk[0], (D, SF), D),
            "w3": dense(sk[1], (D, SF), D),
            "w2": dense(sk[2], (SF, D), SF),
        }
    return p


def expert_capacity(n_tokens, cfg):
    c = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor
                      / cfg.n_experts))
    return max(8, -(-c // 8) * 8)   # round up to multiple of 8


def moe_fwd(cfg, p, x, capacity=None):
    """x [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Two execution paths:
      * dense/global (CPU tests, single device): scatter/gather in the global
        program.
      * expert-parallel shard_map (active when launcher logical rules carry a
        mesh with a 'model' axis dividing n_experts): replicated routing +
        local dispatch + per-layer FSDP weight all-gather + one psum('model')
        for the combine. XLA's SPMD partitioner lowers the *global* scatter
        to a replicated fallback (213 GiB/chip on deepseek train_4k —
        EXPERIMENTS.md §Perf iteration 1), so the explicit schedule is the
        production path, not an optimisation.
    """
    from repro.distributed import logical
    rules, sizes, mesh = logical.state()
    if (rules is not None and mesh is not None and sizes.get("model", 1) > 1
            and cfg.n_experts % sizes["model"] == 0):
        return _moe_fwd_ep(cfg, p, x, rules, sizes, mesh, capacity)
    return _moe_fwd_global(cfg, p, x, capacity)


def _moe_fwd_global(cfg, p, x, capacity=None):
    """Reference global-program path."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    T = B * S
    C = capacity or expert_capacity(T, cfg)
    xt = constrain(x.reshape(T, D), ("tokens", None))

    logits = (xt.astype(jnp.float32) @ p["router"])           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)             # [T, K]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalise

    # load-balance auxiliary loss (Switch/GShard form)
    me = probs.mean(axis=0)                                   # [E]
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(me * ce)

    # position of each (token, k) assignment within its expert, GShard cumsum
    flat_e = gate_idx.reshape(-1)                             # [T*K]
    onehot = constrain(jax.nn.one_hot(flat_e, E, dtype=jnp.int32),
                       ("tokens", None))                      # [T*K, E]
    pos = (jnp.cumsum(onehot, axis=0) - 1)                    # [T*K, E]
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]

    # scatter tokens into per-expert buffers; overflow (pos >= C) is dropped.
    # buf shards E over 'model' (expert parallel) and C over 'data', so the
    # token->expert scatter lowers to the canonical all-to-all
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = constrain(jnp.zeros((E, C, D), x.dtype),
                    ("experts", "capacity", None)).at[flat_e, pos].add(
        xt[tok_idx], mode="drop")
    buf = constrain(buf, ("experts", "capacity", None))

    # expert SwiGLU: [E, C, D] x [E, D, F]
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    h = constrain(jnp.einsum("ecf,efd->ecd", h, p["w2"]),
                  ("experts", "capacity", None))

    # gather back with gate weights; dropped tokens contribute zero
    valid = (pos < C)
    got = h[flat_e, jnp.minimum(pos, C - 1)]                  # [T*K, D]
    got = got * (gate_vals.reshape(-1) * valid).astype(got.dtype)[:, None]
    out = constrain(jnp.zeros((T, D), x.dtype).at[tok_idx].add(got),
                    ("tokens", None))

    if cfg.n_shared_experts:
        sp = p["shared"]
        sh = jax.nn.silu(xt @ sp["w1"]) * (xt @ sp["w3"])
        out = out + sh @ sp["w2"]

    return out.reshape(B, S, D), aux


# --------------------------------------------------------------------------
# Expert-parallel shard_map path
# --------------------------------------------------------------------------
def _moe_fwd_ep(cfg, p, x, rules, sizes, mesh, capacity=None):
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    E, K, D, F = cfg.n_experts, cfg.moe_top_k, cfg.d_model, cfg.moe_d_ff
    tp = sizes["model"]
    E_loc = E // tp
    all_axes = tuple(mesh.axis_names)
    B, S, _ = x.shape
    # shard batch over the largest prefix of the batch axes that divides B
    batch_axes = []
    n_batch_shards = 1
    for a in rules.get("batch", ("data",)):
        if a not in sizes:
            continue
        if B % (n_batch_shards * sizes[a]) == 0:
            batch_axes.append(a)
            n_batch_shards *= sizes[a]
        else:
            break
    batch_axes = tuple(batch_axes)
    T_loc = (B // n_batch_shards) * S
    C = capacity or expert_capacity(T_loc, cfg)

    has_shared = bool(cfg.n_shared_experts)

    fsdp = rules.get("fsdp_params", True) and "data" in sizes

    def inner(xb, router, w1, w3, w2, *shared_w):
        # xb [B_loc, S, D]; router [D, E] (replicated);
        # w1/w3 [E_loc, D(_loc), F]; w2 [E_loc, F, D(_loc)]
        m_idx = jax.lax.axis_index("model")
        xt = xb.reshape(-1, D)
        probs = jax.nn.softmax(xt.astype(jnp.float32) @ router, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        # local aux loss, averaged over every mesh axis (identical result on
        # all shards because routing inputs are replicated over 'model')
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
            1.0 / (xt.shape[0] * K))
        aux = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, all_axes)

        # assignments owned by this model-shard's experts
        flat_e = gate_idx.reshape(-1)                      # [T_loc*K]
        local_e = flat_e - m_idx * E_loc
        own = (local_e >= 0) & (local_e < E_loc)
        le = jnp.where(own, local_e, 0)
        oh = jax.nn.one_hot(jnp.where(own, local_e, E_loc), E_loc + 1,
                            dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(oh, axis=0) - 1,
            jnp.where(own, local_e, E_loc)[:, None], axis=1)[:, 0]
        pos = jnp.where(own, pos, C)                       # -> dropped

        tok_idx = jnp.repeat(jnp.arange(xt.shape[0]), K)
        buf = jnp.zeros((E_loc, C, D), x.dtype).at[le, pos].add(
            xt[tok_idx] * own[:, None].astype(x.dtype), mode="drop")

        # FSDP gather of this shard's expert weights (per layer, transient);
        # inference layout (fsdp_params=False) keeps them resident instead
        if fsdp:
            w1g = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
            w3g = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
            w2g = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
        else:
            w1g, w3g, w2g = w1, w3, w2

        h = jnp.einsum("ecd,edf->ecf", buf, w1g)
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w3g)
        h = jnp.einsum("ecf,efd->ecd", h, w2g)

        ok = own & (pos < C)
        got = h[le, jnp.minimum(pos, C - 1)]
        got = got * (gate_vals.reshape(-1) * ok).astype(got.dtype)[:, None]
        out = jnp.zeros_like(xt).at[tok_idx].add(got)

        if has_shared:
            sw1, sw3, sw2 = shared_w                       # [D(_loc),SF_loc]
            if fsdp:
                sw1 = jax.lax.all_gather(sw1, "data", axis=0, tiled=True)
                sw3 = jax.lax.all_gather(sw3, "data", axis=0, tiled=True)
                sw2 = jax.lax.all_gather(sw2, "data", axis=1, tiled=True)
            sh = jax.nn.silu(xt @ sw1) * (xt @ sw3)        # [T, SF_loc]
            out = out + sh @ sw2                           # partial over SF
        out = jax.lax.psum(out, "model")
        return out.reshape(xb.shape), aux

    bspec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0], None,
              None) if batch_axes else P(None, None, None)
    dd = "data" if fsdp else None
    in_specs = [bspec,
                P(None, None),                             # router replicated
                P("model", dd, None),
                P("model", dd, None),
                P("model", None, dd)]
    args = [x, p["router"], p["w1"], p["w3"], p["w2"]]
    if has_shared:
        in_specs += [P(dd, "model"), P(dd, "model"), P("model", dd)]
        args += [p["shared"]["w1"], p["shared"]["w3"], p["shared"]["w2"]]
    out, aux = shard_map(
        inner, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(bspec, P()), check_rep=False)(*args)
    return out, aux
