"""The paper's own deployed-model family for image tasks: the 2-hidden-layer
MLP, a LeNet-5-style CNN, and a small ResNet (CIFAR-scale). Used by the
accuracy-reproduction benches (paper Figs 6/7/9/10); parity models reuse the
same architectures per §3.3 of the paper.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _dense(key, shape):
    return jax.random.normal(key, shape) * math.sqrt(2.0 / shape[0])


def _conv(key, shape):  # HWIO
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape) * math.sqrt(2.0 / fan_in)


def conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ---------------------------------------------------------------- MLP ----
def init_mlp(key, in_dim, hidden=(200, 100), n_out=10):
    dims = (in_dim,) + tuple(hidden) + (n_out,)
    ks = jax.random.split(key, len(dims) - 1)
    return {"w": [_dense(ks[i], (dims[i], dims[i + 1]))
                  for i in range(len(dims) - 1)],
            "b": [jnp.zeros((dims[i + 1],)) for i in range(len(dims) - 1)]}


def mlp_fwd(p, x):
    x = x.reshape(x.shape[0], -1)
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w + b
        if i < len(p["w"]) - 1:
            x = jax.nn.relu(x)
    return x


# --------------------------------------------------------------- LeNet ----
def init_lenet(key, image_shape=(32, 32, 3), channels=(6, 16), n_out=10):
    ks = jax.random.split(key, 4)
    c_in = image_shape[-1]
    flat = (image_shape[0] // 4) * (image_shape[1] // 4) * channels[1]
    return {
        "c1": _conv(ks[0], (5, 5, c_in, channels[0])),
        "c2": _conv(ks[1], (5, 5, channels[0], channels[1])),
        "fc": init_mlp(ks[2], flat, (120, 84), n_out),
    }


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "SAME")


def lenet_fwd(p, x):
    x = _pool(jax.nn.relu(conv2d(x, p["c1"])))
    x = _pool(jax.nn.relu(conv2d(x, p["c2"])))
    return mlp_fwd(p["fc"], x)


# -------------------------------------------------------------- ResNet ----
def init_resnet(key, image_shape=(32, 32, 3), stages=(16, 32, 64), n_out=10,
                blocks_per_stage=2):
    ks = iter(jax.random.split(key, 64))
    p = {"stem": _conv(next(ks), (3, 3, image_shape[-1], stages[0])),
         "stages": []}
    c_in = stages[0]
    for c in stages:
        blocks = []
        for b in range(blocks_per_stage):
            blk = {"c1": _conv(next(ks), (3, 3, c_in if b == 0 else c, c)),
                   "c2": _conv(next(ks), (3, 3, c, c))}
            if b == 0 and c_in != c:
                blk["proj"] = _conv(next(ks), (1, 1, c_in, c))
            blocks.append(blk)
        p["stages"].append(blocks)
        c_in = c
    p["head"] = _dense(next(ks), (c_in, n_out))
    p["head_b"] = jnp.zeros((n_out,))
    return p


def resnet_fwd(p, x):
    x = jax.nn.relu(conv2d(x, p["stem"]))
    for si, blocks in enumerate(p["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = jax.nn.relu(conv2d(x, blk["c1"], stride))
            h = conv2d(h, blk["c2"])
            sc = x if "proj" not in blk else conv2d(x, blk["proj"], stride)
            if stride == 2 and "proj" not in blk:
                sc = sc[:, ::2, ::2, :]
            x = jax.nn.relu(h + sc)
    x = x.mean(axis=(1, 2))
    return x @ p["head"] + p["head_b"]


MODEL_FNS = {"mlp": (init_mlp, mlp_fwd),
             "lenet": (init_lenet, lenet_fwd),
             "resnet": (init_resnet, resnet_fwd)}


def build(kind, key, image_shape=(32, 32, 3), n_out=10):
    if kind == "mlp":
        in_dim = int(jnp.prod(jnp.array(image_shape)))
        return init_mlp(key, in_dim, n_out=n_out), mlp_fwd
    if kind == "lenet":
        return init_lenet(key, image_shape, n_out=n_out), lenet_fwd
    if kind == "resnet":
        return init_resnet(key, image_shape, n_out=n_out), resnet_fwd
    raise ValueError(kind)
