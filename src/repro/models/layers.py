"""Core transformer layers: norms, RoPE, GQA attention (full / sliding /
cross / decode), SwiGLU MLP.

All functions are pure; parameters are plain dict pytrees. Attention for long
sequences uses an online-softmax KV-block scan ("flash" formulation in XLA)
so the lowered HLO never materialises an S x S score matrix. The Pallas TPU
kernels in ``repro.kernels`` implement the same math for the hot paths and are
validated against these (and ``kernels/ref.py``) in interpret mode.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x, scale=None, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        x = x * scale.astype(jnp.float32)
    return x.astype(dt)


def nonparametric_layer_norm(x, eps=1e-5):
    """OLMo-style LayerNorm without learned scale/bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def make_norm(cfg, d, key=None):
    """Returns (params, apply_fn-compatible) norm parameters."""
    if cfg.nonparametric_ln:
        return {}
    return {"scale": jnp.ones((d,), dtype=cfg.dtype)}


def apply_norm(cfg, p, x):
    if cfg.nonparametric_ln:
        return nonparametric_layer_norm(x)
    return rms_norm(x, p["scale"])


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_tables(positions, head_dim, theta):
    """positions [S] -> cos/sin [S, head_dim//2] (float32)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B, S, H, hd]; cos/sin [S, hd//2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :].astype(x.dtype)
    s = sin[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def apply_rope_rows(x, cos, sin):
    """x [B, 1, H, hd]; cos/sin [B, hd//2] — one angle per batch row.

    The per-slot decode path: each cache slot sits at its own position, so
    the rotation varies along batch instead of sequence."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, None, None, :].astype(x.dtype)
    s = sin[:, None, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# --------------------------------------------------------------------------
# Attention (online-softmax KV-block scan)
# --------------------------------------------------------------------------
def _blockify(x, block):
    """[B, S, H, hd] -> [nb, B, block, H, hd] (zero-padded)."""
    B, S, H, hd = x.shape
    nb = -(-S // block)
    pad = nb * block - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x.reshape(B, nb, block, H, hd).transpose(1, 0, 2, 3, 4)


def _block_mask(qpos, kpos, Sk, causal, window):
    valid = kpos[None, :] < Sk
    if causal:
        valid = valid & (kpos[None, :] <= qpos[:, None])
    if window:
        valid = valid & (kpos[None, :] > qpos[:, None] - window)
    return valid


def flash_attention_xla(q, k, v, *, causal=True, window=0, q_offset=0,
                        block=1024):
    """Keyword-friendly wrapper over the custom-VJP core."""
    return _flash_core(q, k, v, causal, window, q_offset, block)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_core(q, k, v, causal=True, window=0, q_offset=0,
                block=1024):
    """q [B,Sq,H,hd]; k,v [B,Sk,KV,hd]. GQA via head repeat.

    XLA analogue of FlashAttention with a *custom VJP*: the forward scans KV
    blocks carrying fp32 (max, denom, acc); the backward recomputes block
    scores instead of saving them, so residuals are O(S*d) — without this,
    the scan's saved exp(s-m) residuals are [nb, B, H, Sq, block] and blow
    past HBM at 4k-32k sequence lengths (EXPERIMENTS.md §Perf, iteration 0).
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, block)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, block):
    # GQA via *grouped einsums*: q is viewed as [B, Sq, KV, rep, hd] and
    # contracted against the un-repeated KV tensors — a materialised
    # jnp.repeat of K/V forced an all-gather + rep x HBM traffic under SPMD
    # (EXPERIMENTS.md §Perf, qwen3-4b iterations).
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = hd ** -0.5
    block = min(block, Sk)
    nb = -(-Sk // block)
    kb = constrain(_blockify(k, block),
                   (None, "batch", None, "kv_heads", None))
    vb = constrain(_blockify(v, block),
                   (None, "batch", None, "kv_heads", None))
    qpos = q_offset + jnp.arange(Sq)
    qs = q.reshape(B, Sq, KV, rep, hd) * scale
    qs = constrain(qs, ("batch", None, "kv_heads", None, None))

    def body(carry, inp):
        m, l, acc = carry                              # [B,KV,rep,Sq(,hd)]
        kblk, vblk, bidx = inp                         # [B,block,KV,hd]
        kpos = bidx * block + jnp.arange(block)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qs, kblk,
                       preferred_element_type=jnp.float32)
        valid = _block_mask(qpos, kpos, Sk, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrqk,bkgd->bgrqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = constrain(jnp.full((B, KV, rep, Sq), NEG_INF, jnp.float32),
                   ("batch", "kv_heads", None, None))
    l0 = constrain(jnp.zeros((B, KV, rep, Sq), jnp.float32),
                   ("batch", "kv_heads", None, None))
    a0 = constrain(jnp.zeros((B, KV, rep, Sq, hd), jnp.float32),
                   ("batch", "kv_heads", None, None, None))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nb)))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).transpose(
        0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))           # [B,KV,rep,Sq]
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_offset, block):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, block)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, block, res, dout):
    q, k, v, out, lse = res                   # lse [B,KV,rep,Sq]
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = hd ** -0.5
    blk = min(block, Sk)
    nb = -(-Sk // blk)
    kb = constrain(_blockify(k, blk), (None, "batch", None, "kv_heads",
                                       None))
    vb = constrain(_blockify(v, blk), (None, "batch", None, "kv_heads",
                                       None))
    qpos = q_offset + jnp.arange(Sq)
    qs = constrain(q.reshape(B, Sq, KV, rep, hd) * scale,
                   ("batch", None, "kv_heads", None, None))
    do = constrain(
        dout.reshape(B, Sq, KV, rep, hd).transpose(0, 2, 3, 1, 4)
        .astype(jnp.float32),
        ("batch", "kv_heads", None, None, None))          # [B,KV,rep,Sq,hd]
    o32 = out.reshape(B, Sq, KV, rep, hd).transpose(0, 2, 3, 1, 4) \
        .astype(jnp.float32)
    delta = (do * o32).sum(-1)                            # [B,KV,rep,Sq]

    def body(dq, inp):
        kblk, vblk, bidx = inp                            # [B,blk,KV,hd]
        kpos = bidx * blk + jnp.arange(blk)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qs, kblk,
                       preferred_element_type=jnp.float32)
        valid = _block_mask(qpos, kpos, Sk, causal, window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                   # [B,KV,rep,Sq,bk]
        dv = jnp.einsum("bgrqk,bgrqd->bkgd", p, do)       # sums over rep
        dp = jnp.einsum("bgrqd,bkgd->bgrqk", do, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])
        dq = dq + jnp.einsum("bgrqk,bkgd->bqgrd", ds, kblk,
                             preferred_element_type=jnp.float32)
        dk = jnp.einsum("bgrqk,bqgrd->bkgd", ds, qs,
                        preferred_element_type=jnp.float32)
        return dq, (dk, dv)

    dq0 = constrain(jnp.zeros((B, Sq, KV, rep, hd), jnp.float32),
                    ("batch", None, "kv_heads", None, None))
    dq, (dkb, dvb) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dq = (dq * scale).reshape(B, Sq, H, hd).astype(q.dtype)
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nb * blk, KV, hd)[:, :Sk]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nb * blk, KV, hd)[:, :Sk]
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def attention_decode_xla(q, k_cache, v_cache, pos, *, window=0):
    """Single-token decode attention. q [B,1,H,hd]; caches [B,S,KV,hd];
    pos [] current position (number of valid cached tokens is pos+1), or
    [B] per-row positions for slot-batched decode (each batch row is an
    independent stream at its own position).

    With a sliding window the cache is a ring buffer of size ``window``; the
    mask then covers every slot already written.
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    # The cache shards its *sequence* dim over whatever mesh axes the batch
    # doesn't use (decode_32k: batch->data, seq->model; long_500k B=1:
    # seq->model+data). kv-heads (often < axis size) stay local and GQA is a
    # grouped einsum — no repeated KV, no all-gather of the cache.
    k_cache = constrain(k_cache, ("batch", "seq", "kv_heads", None))
    v_cache = constrain(v_cache, ("batch", "seq", "kv_heads", None))
    scale = hd ** -0.5
    qg = q[:, 0].reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrd,bkgd->bgrk", qg * scale, k_cache,
                   preferred_element_type=jnp.float32)     # [B,KV,rep,S]
    kpos = jnp.arange(S)
    if jnp.ndim(pos):                               # per-row positions [B]
        if window:
            valid = kpos[None, :] < jnp.minimum(pos + 1, S)[:, None]
        else:
            valid = kpos[None, :] <= pos[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    else:
        if window:
            valid = kpos < jnp.minimum(pos + 1, S)  # ring buffer: slots written
        else:
            valid = kpos <= pos
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# --------------------------------------------------------------------------
# Attention layer (params + forward)
# --------------------------------------------------------------------------
def init_attention(cfg, key, cross=False):
    D, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.dtype

    def dense(k, shape):
        fan_in = shape[0]
        return (jax.random.normal(k, shape) / math.sqrt(fan_in)).astype(dt)

    p = {
        "wq": dense(ks[0], (D, H * hd)),
        "wk": dense(ks[1], (D, KV * hd)),
        "wv": dense(ks[2], (D, KV * hd)),
        "wo": dense(ks[3], (H * hd, D)),
        "norm": make_norm(cfg, D),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((KV * hd,), dt)
        p["bv"] = jnp.zeros((KV * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    if cross:
        p["cross_norm"] = make_norm(cfg, D)
    return p


def _qkv(cfg, p, xq, xkv):
    B, Sq, D = xq.shape
    Skv = xkv.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, Skv, KV, hd)
    v = v.reshape(B, Skv, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def self_attention_fwd(cfg, p, x, rope_cs, *, window=0, q_offset=0,
                       backend=None):
    """Full/causal self attention for train & prefill. Returns (out, (k, v)).

    ``backend`` overrides ``cfg.attn_backend``: "pallas" routes through the
    Pallas flash-attention kernel where it covers the case (causal,
    q_offset == 0); otherwise — and always for "jnp" — the XLA
    online-softmax path runs."""
    q, k, v = _qkv(cfg, p, x, x)
    cos, sin = rope_cs
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    backend = backend or getattr(cfg, "attn_backend", "jnp")
    if backend == "pallas" and not q_offset:
        from repro.kernels import ops as kernel_ops
        o = kernel_ops.flash_attention_op(q, k, v, causal=True, window=window)
    else:
        o = flash_attention_xla(q, k, v, causal=True, window=window,
                                q_offset=q_offset)
    B, S, H, hd = o.shape
    return o.reshape(B, S, H * hd) @ p["wo"], (k, v)


def cross_attention_fwd(cfg, p, x, kv_or_embeds, *, from_cache=False):
    """Cross attention to modality embeddings. Returns (out, (k, v))."""
    if from_cache:
        q = x @ p["wq"]
        if cfg.qkv_bias:
            q = q + p["bq"]
        B, Sq, _ = x.shape
        H, hd = cfg.n_heads, cfg.resolved_head_dim
        q = q.reshape(B, Sq, H, hd)
        if cfg.qk_norm:
            q = rms_norm(q, p["q_norm"])
        k, v = kv_or_embeds
    else:
        q, k, v = _qkv(cfg, p, x, kv_or_embeds)
    o = flash_attention_xla(q, k, v, causal=False)
    B, Sq, H, hd = o.shape
    return o.reshape(B, Sq, H * hd) @ p["wo"], (k, v)


def self_attention_decode(cfg, p, x, cache, pos, rope_cs, *, window=0,
                          backend=None):
    """One-token decode. x [B,1,D]; cache {'k','v'} ring buffers.

    ``pos`` is scalar (whole batch at one position) or [B] (slot-batched
    streams, each at its own position — ``rope_cs`` then holds per-row
    tables [B, hd//2]).  ``backend`` as in :func:`self_attention_fwd`.

    Returns (out, new_cache)."""
    q, k, v = _qkv(cfg, p, x, x)
    cos, sin = rope_cs            # tables for the single position, [1, hd//2]
    vector = bool(jnp.ndim(pos))
    if vector:
        q = apply_rope_rows(q, cos, sin)
        k = apply_rope_rows(k, cos, sin)
    else:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    S = cache["k"].shape[1]
    slot = (pos % S) if window else pos
    if vector:
        # Per-row slot write.  jnp.where keeps untouched rows bit-identical
        # (no arithmetic on them), which the slot-isolation guarantee of the
        # continuous-batching engine relies on.
        sel = (jnp.arange(S)[None, :] == slot[:, None])[:, :, None, None]
        k_cache = jnp.where(sel, k, cache["k"])
        v_cache = jnp.where(sel, v, cache["v"])
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                      axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                      axis=1)
    backend = backend or getattr(cfg, "attn_backend", "jnp")
    if backend == "pallas":
        from repro.kernels import ops as kernel_ops
        o = kernel_ops.decode_attention_op(q[:, 0], k_cache, v_cache, pos)
        o = o[:, None].astype(q.dtype)
    else:
        o = attention_decode_xla(q, k_cache, v_cache, pos, window=window)
    B, _, H, hd = o.shape
    out = o.reshape(B, 1, H * hd) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


def init_attn_cache(cfg, batch, seq_len, cross_len=0):
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    dt = cfg.dtype
    return {"k": jnp.zeros((batch, S, KV, hd), dt),
            "v": jnp.zeros((batch, S, KV, hd), dt)}


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(cfg, key, d_ff=None):
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.dtype

    def dense(k, shape):
        return (jax.random.normal(k, shape) / math.sqrt(shape[0])).astype(dt)

    p = {"w1": dense(ks[0], (D, F)), "w2": dense(ks[1], (F, D)),
         "norm": make_norm(cfg, D)}
    if cfg.act == "silu":                 # SwiGLU
        p["w3"] = dense(ks[2], (D, F))
    return p


def mlp_fwd(cfg, p, x):
    h = x @ p["w1"]
    if cfg.act == "silu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif cfg.act == "relu":
        h = jax.nn.relu(h)
    elif cfg.act == "gelu":
        h = jax.nn.gelu(h)
    return h @ p["w2"]
