"""Linear deployed model — for property tests of the coding layer.

For any *linear* F, the paper's addition/subtraction code is exact with the
identity parity model F_P = F (Table 1, row 1). The hypothesis tests in
``tests/test_coding_properties.py`` assert this exactness invariant for the
encoder/decoder pair, including r > 1 Vandermonde codes.
"""
import jax
import jax.numpy as jnp


def init_linear(key, d_in, d_out):
    return {"w": jax.random.normal(key, (d_in, d_out)) / jnp.sqrt(d_in)}


def linear_fwd(p, x):
    return x.reshape(x.shape[0], -1) @ p["w"]
