"""Mamba2 (SSD — state-space duality) mixer layer.

Training/prefill uses the chunked SSD algorithm [arXiv:2405.21060]: the
sequence is split into chunks of length Q; within-chunk interactions are
computed as a masked quadratic form (attention-like, maps onto the MXU), and
chunk-to-chunk interaction flows through a small recurrent state carried by a
``lax.scan`` — O(L·Q) instead of O(L^2). Decode is the pure recurrence:
``h' = a·h + Δx ⊗ B;  y = C·h' + D·x`` with O(1) state, which is what makes
``long_500k`` native for SSM/hybrid archs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models.layers import make_norm, rms_norm


def _dims(cfg):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = cfg.ssm_groups
    conv_dim = di + 2 * G * N
    return di, H, P, N, G, conv_dim


def init_mamba(cfg, key):
    D = cfg.d_model
    di, H, P, N, G, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    dt = cfg.dtype

    def dense(k, shape, fan_in):
        return (jax.random.normal(k, shape) / math.sqrt(fan_in)).astype(dt)

    d_in_proj = 2 * di + 2 * G * N + H
    # dt bias: inverse softplus of dt ~ U[1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (H,), minval=math.log(1e-3),
                           maxval=math.log(1e-1))
    dt0 = jnp.exp(u)
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "in_proj": dense(ks[0], (D, d_in_proj), D),
        "conv_w": dense(ks[1], (cfg.ssm_conv, conv_dim), cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jax.random.uniform(ks[3], (H,), minval=1.0,
                                            maxval=16.0)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": dense(ks[4], (di, D), di),
        "norm": make_norm(cfg, D),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x [B, L, C]; w [W, C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(y + b)


def _split_in(cfg, p, x):
    di, H, P, N, G, conv_dim = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xBC, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    return z, xBC, dt


def ssd_fwd(cfg, p, x, *, init_state=None, return_state=False):
    """Full-sequence SSD. x [B, L, D] -> (y [B, L, D], state|None).

    ``init_state``/``return_state`` support prefill -> decode handoff.
    """
    B, L0, D = x.shape
    di, H, P, N, G, conv_dim = _dims(cfg)
    Q = min(cfg.ssm_chunk, L0)
    pad = (-L0) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    L = L0 + pad
    nc = L // Q

    z, xBC, dt = _split_in(cfg, p, x)
    if pad:
        # make padded steps identity: delta -> 0 => a=1, dx=0
        step_mask = jnp.arange(L) < L0
        dt = jnp.where(step_mask[None, :, None], dt, -1e9)
    xBC = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, L, H, P)
    Bm = Bm.reshape(B, L, G, N)
    Cm = Cm.reshape(B, L, G, N)

    xs = constrain(xs, ("batch", None, "heads", None))
    delta = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,L,H]
    loga = constrain(-jnp.exp(p["A_log"]) * delta,
                     ("batch", None, "heads"))                       # [B,L,H]
    dx = (xs.astype(jnp.float32) * delta[..., None])                 # Δ·x

    # chunk views
    def ch(t, extra):
        return t.reshape((B, nc, Q) + extra)

    dxc = ch(dx, (H, P))
    Bc = ch(Bm.astype(jnp.float32), (G, N))
    Cc = ch(Cm.astype(jnp.float32), (G, N))
    lac = ch(loga, (H,))
    cum = jnp.cumsum(lac, axis=2)                                    # [B,nc,Q,H]

    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=3)                                 # [B,nc,Q,H,N]
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk (quadratic, parallel over chunks) ----
    # scores[b,c,h,i,j] = (C_i . B_j) * exp(cum_i - cum_j), i >= j
    cb = constrain(jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh),
                   ("batch", None, "heads", None, None))
    dec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])     # [B,nc,i,j,H]
    dec = dec.transpose(0, 1, 4, 2, 3)                               # [B,nc,H,i,j]
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    scores = jnp.where(mask[None, None, None], cb * dec, 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", scores, dxc)

    # ---- chunk state + inter-chunk recurrence ----
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                           # exp(cum_Q - cum_j)
    states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", Bh, seg, dxc)     # [B,nc,H,N,P]
    chunk_decay = jnp.exp(cum[:, :, -1, :])                          # [B,nc,H]

    h0 = (jnp.zeros((B, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    h0 = constrain(h0, ("batch", "heads", None, None))

    def scan_body(h, inp):
        st, cdk = inp                                                # [B,H,N,P],[B,H]
        h_new = h * cdk[..., None, None] + st
        return h_new, h

    xs_scan = (states.transpose(1, 0, 2, 3, 4),
               chunk_decay.transpose(1, 0, 2))
    h_final, h_prevs = jax.lax.scan(scan_body, h0, xs_scan)
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                       # [B,nc,H,N,P]

    inter_dec = jnp.exp(cum)                                         # [B,nc,Q,H]
    y_inter = jnp.einsum("bcihn,bcih,bchnp->bcihp", Ch, inter_dec, h_prevs)

    y = (y_intra + y_inter).reshape(B, L, H, P)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, L, di)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["gate_norm"])
    out = (y @ p["out_proj"])[:, :L0]
    if return_state:
        conv_tail = xBC_tail(cfg, x[:, :L0], p)
        return out, {"ssm": h_final.astype(jnp.float32), "conv": conv_tail}
    return out, None


def xBC_tail(cfg, x, p):
    """Last (conv_width - 1) pre-conv xBC rows, for decode handoff."""
    _, xBC, _ = _split_in(cfg, p, x)
    return xBC[:, -(cfg.ssm_conv - 1):, :]


def init_ssm_cache(cfg, batch):
    di, H, P, N, G, conv_dim = _dims(cfg)
    return {"ssm": jnp.zeros((batch, H, N, P), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype)}


def ssd_decode(cfg, p, x, cache):
    """One-step recurrence. x [B, 1, D] -> (y [B, 1, D], new cache)."""
    B = x.shape[0]
    di, H, P, N, G, conv_dim = _dims(cfg)
    z, xBC, dt = _split_in(cfg, p, x)                 # [B,1,*]
    xBC = xBC[:, 0]
    # conv over (cached tail ++ current)
    win = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # [B,W,C]
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, p["conv_w"])
                           + p["conv_b"])
    new_conv = win[:, 1:, :]

    xs, Bm, Cm = jnp.split(conv_out, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, H, P).astype(jnp.float32)
    Bm = Bm.reshape(B, G, N).astype(jnp.float32)
    Cm = Cm.reshape(B, G, N).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                  # [B,H,N]
    Ch = jnp.repeat(Cm, rep, axis=1)

    delta = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["A_log"]) * delta)         # [B,H]
    dx = xs * delta[..., None]                        # [B,H,P]
    h = cache["ssm"] * a[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", Bh, dx)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)
    y = y + p["D"][None, :, None] * xs
    y = y.reshape(B, 1, di)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["gate_norm"])
    return y @ p["out_proj"], {"ssm": h, "conv": new_conv}
