"""Pallas TPU route for the Berrut/barycentric encode projection — the
fixed linear map from the k member queries to the r rational-interpolation
parity queries of the approxifer scheme,

    out[j] = sum_i C[j, i] * Q[i]          (Q [k, B, F], C [r, k])

This is *exactly* the learned-encoder final projection with the weight
matrix transposed: ``learned_project(h, w)`` computes
``out[j] = sum_h W[h, j] * H[h]`` over its hidden dimension, so with
``h = Q`` (reduce over k instead of H) and ``w = C.T`` the same kernel —
same (r, B-tiles, F-tiles) grid, same HBM->VMEM streaming, same fp32
VREG accumulation, all r output rows in one launch — serves both call
surfaces.  Delegating instead of duplicating keeps one Mosaic kernel to
tune: block sizes, dtype handling and TPU-alignment fixes land in
``learned_encoder.py`` once and both encoders inherit them.
"""

from __future__ import annotations

from repro.kernels.learned_encoder import learned_project


def berrut_encode(q, c, *, block_b=8, block_f=512, interpret=False):
    """q [k, B, F]; c [r, k] -> [r, B, F] (one launch for all r rows)."""
    return learned_project(q, c.T, block_b=block_b, block_f=block_f,
                           interpret=interpret)
