"""Pure-jnp oracles for every Pallas kernel. The kernel tests sweep shapes
and dtypes and assert allclose against these."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def parity_encode_ref(queries, coeffs):
    """queries [k, B, F]; coeffs [k] -> parity [B, F] (fp32 accumulate)."""
    acc = jnp.einsum("k,kbf->bf", coeffs.astype(jnp.float32),
                     queries.astype(jnp.float32))
    return acc.astype(queries.dtype)


def parity_decode_ref(parity_out, outputs, avail_coeffs, inv_c):
    """parity_out [B, V]; outputs [k, B, V]; avail_coeffs [k] (0 at the
    missing index, code coefficient elsewhere); inv_c scalar = 1/c_missing.
    Returns reconstruction [B, V]."""
    s = jnp.einsum("k,kbv->bv", avail_coeffs.astype(jnp.float32),
                   outputs.astype(jnp.float32))
    return ((parity_out.astype(jnp.float32) - s) * inv_c).astype(
        parity_out.dtype)


def fused_encode_forward_ref(queries, coeffs, weights):
    """queries [k, B, F]; coeffs [r, k]; weights [r, F, V] (one first-layer
    matrix per parity row) -> [r, B, V]: encode over the coding dim, then
    each row's first forward matmul (fp32 accumulate throughout)."""
    enc = jnp.einsum("rk,kbf->rbf", coeffs.astype(jnp.float32),
                     queries.astype(jnp.float32))
    out = jnp.einsum("rbf,rfv->rbv", enc, weights.astype(jnp.float32))
    return out.astype(queries.dtype)


def multigroup_decode_ref(parity_outs, outputs, cmat):
    """parity_outs [G, B, V]; outputs [G, k, B, V]; cmat [G, k+1] (per-group
    availability-masked coeffs, 0 at the missing index, with 1/c_missing
    appended).  Returns [G, B, V] — the batched subtraction decode."""
    k = outputs.shape[1]
    s = jnp.einsum("gk,gkbv->gbv", cmat[:, :k].astype(jnp.float32),
                   outputs.astype(jnp.float32))
    inv = cmat[:, k].astype(jnp.float32)[:, None, None]
    return ((parity_outs.astype(jnp.float32) - s) * inv).astype(
        parity_outs.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q [B,Sq,H,hd]; k,v [B,Sk,KV,hd] -> [B,Sq,H,hd] (naive softmax)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    valid = jnp.ones((Sq, Sk), bool)
    if causal:
        valid &= kpos <= qpos
    if window:
        valid &= kpos > qpos - window
    s = jnp.where(valid[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """q [B,H,hd]; caches [B,S,KV,hd]; pos scalar (valid slots: <= pos).
    Returns [B,H,hd]."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    if KV != H:
        k_cache = jnp.repeat(k_cache, H // KV, axis=2)
        v_cache = jnp.repeat(v_cache, H // KV, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * hd ** -0.5
    valid = jnp.arange(S)[None, None, :] <= pos
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bkhd->bhd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)
