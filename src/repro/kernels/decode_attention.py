"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

The decode hot path is bandwidth-bound: one query vector against S cached
keys/values. Grid (batch, kv_block) streams the cache HBM->VMEM once; all H
query heads ride along in a single [H, hd] VMEM tile, and GQA grouping is a
reshape of the head dim (no repeated KV reads — the XLA fallback's
``jnp.repeat`` re-reads the cache rep times, which this kernel removes; see
EXPERIMENTS.md §Perf). Online softmax scratch persists across the KV sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale, block_k, n_kv_blocks, kv_heads, rep):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    H = kv_heads * rep
    q = q_ref[0].astype(jnp.float32) * scale          # [H, hd]
    k = k_ref[0].astype(jnp.float32)                  # [bk, KV, hd]
    # scores per head: head h uses kv-head h // rep
    qg = q.reshape(kv_heads, rep, -1)                 # [KV, rep, hd]
    s = jnp.einsum("grd,kgd->grk", qg, k)             # [KV, rep, bk]
    s = s.reshape(H, block_k)

    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    valid = kpos <= pos_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])                   # [H, bk]
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)                  # [bk, KV, hd]
    pg = p.reshape(kv_heads, rep, block_k)
    o = jnp.einsum("grk,kgd->grd", pg, v).reshape(H, -1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + o
    m_ref[...] = m_new

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
                        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, block_k=512,
                     interpret=False):
    """q [B,H,hd]; caches [B,S,KV,hd]; pos scalar int32 or [B] per-row
    positions (slot-batched decode: each batch row is an independent stream
    at its own position). Returns [B,H,hd]."""
    B, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    block_k = min(block_k, S)
    nk = pl.cdiv(S, block_k)

    kernel = functools.partial(
        _decode_kernel, scale=hd ** -0.5, block_k=block_k, n_kv_blocks=nk,
        kv_heads=KV, rep=rep)

    # A scalar pos broadcasts to [B]; each grid row b then streams its own
    # pos_ref[0], so per-row positions reuse the same kernel body.
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    return pl.pallas_call(
        kernel,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),                 # pos
            pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),      # q
            pl.BlockSpec((1, block_k, KV, hd), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_k, KV, hd), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H,), jnp.float32),
            pltpu.VMEM((H, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k_cache, v_cache)
