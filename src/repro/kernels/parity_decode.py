"""Pallas TPU kernel: ParM subtraction decode —
``recon = (F_P(P) - sum_i avail_c_i * F(X_i)) / c_missing``.

Same tiling story as parity_encode (memory-bound, lane-aligned feature
tiles); the availability mask folds the "which output is missing" control
flow into data so one kernel serves every missing-index case (jit-stable
shapes on the serving hot path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_kernel(c_ref, p_ref, outs_ref, o_ref, *, k):
    # c_ref [k+1] SMEM-ish (avail coeffs + inv_c at the end)
    acc = p_ref[...].astype(jnp.float32)
    for i in range(k):
        acc -= outs_ref[i].astype(jnp.float32) * c_ref[i]
    o_ref[...] = (acc * c_ref[k]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_v",
                                             "interpret"))
def parity_decode(parity_out, outputs, avail_coeffs, inv_c, *, block_b=8,
                  block_v=512, interpret=False):
    """parity_out [B, V]; outputs [k, B, V]; avail_coeffs [k] (0 at missing);
    inv_c scalar. Returns [B, V]."""
    k, B, V = outputs.shape
    block_b = min(block_b, B)
    block_v = min(block_v, V)
    cvec = jnp.concatenate([avail_coeffs.astype(jnp.float32),
                            jnp.asarray(inv_c, jnp.float32)[None]])
    grid = (pl.cdiv(B, block_b), pl.cdiv(V, block_v))
    return pl.pallas_call(
        functools.partial(_decode_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k + 1,), lambda i, j: (0,)),
            pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
            pl.BlockSpec((k, block_b, block_v), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_v), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, V), parity_out.dtype),
        interpret=interpret,
    )(cvec, parity_out, outputs)
