"""Pallas TPU kernel: batched multi-group parity decode.

Under load, a batch-atomic completion (threads engine) or a batched DES
finish event makes SEVERAL coding groups decode-ready at the same instant.
Per-group ``decode_one`` calls pay one kernel launch each; this module
decodes ALL recoverable groups in one launch by stacking the per-group
``(parity_out, outputs, coeffs)`` triples:

    recon[g] = ( P[g] - sum_i avail_c[g, i] * F(X_i)[g] ) * inv_c[g]

The per-group coefficient vectors fold the "which member is missing" control
flow into data (0 at the missing index, 1/c_missing appended), so one kernel
serves every per-group missing pattern — the same trick as
``parity_decode``, batched over the leading group axis.  The grid tiles
(G, B, V); feature tiles lane-aligned, batch tiles sublane-aligned.

``multigroup_lstsq`` is the r>1 / multi-missing generalization: the masked
least-squares decode of ALL stacked groups as a single vmapped XLA
computation (one launch).  Per the scheme-layer rule, the tiny [k, k] solve
itself stays in jnp — only its batching moves here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mg_decode_kernel(c_ref, p_ref, outs_ref, o_ref, *, k):
    # c_ref [1, k+1] (avail coeffs + inv_c); p_ref [1, bb, bv];
    # outs_ref [1, k, bb, bv]; o_ref [1, bb, bv]
    acc = p_ref[0].astype(jnp.float32)
    for i in range(k):
        acc -= outs_ref[0, i].astype(jnp.float32) * c_ref[0, i]
    o_ref[0] = (acc * c_ref[0, k]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_v",
                                             "interpret"))
def multigroup_decode(parity_outs, outputs, cmat, *, block_b=8, block_v=512,
                      interpret=False):
    """parity_outs [G, B, V]; outputs [G, k, B, V]; cmat [G, k+1] — per-group
    availability-masked coeffs (0 at the missing index) with 1/c_missing
    appended.  Returns reconstructions [G, B, V]."""
    G, k, B, V = outputs.shape
    block_b = min(block_b, B)
    block_v = min(block_v, V)
    grid = (G, pl.cdiv(B, block_b), pl.cdiv(V, block_v))
    return pl.pallas_call(
        functools.partial(_mg_decode_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k + 1), lambda g, b, v: (g, 0)),
            pl.BlockSpec((1, block_b, block_v), lambda g, b, v: (g, b, v)),
            pl.BlockSpec((1, k, block_b, block_v),
                         lambda g, b, v: (g, 0, b, v)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_v),
                               lambda g, b, v: (g, b, v)),
        out_shape=jax.ShapeDtypeStruct((G, B, V), parity_outs.dtype),
        interpret=interpret,
    )(cmat, parity_outs, outputs)


@jax.jit
def multigroup_lstsq(coeffs, parity_outs, outputs, missing_masks,
                     parity_avail):
    """Batched masked least-squares decode over G stacked groups.

    coeffs [r, k] (shared — one scheme decodes the whole batch);
    parity_outs [G, r, ...]; outputs [G, k, ...]; missing_masks [G, k] bool;
    parity_avail [G, r] bool.  Returns [G, k, ...] with reconstructed rows at
    the missing positions (same normal-equations math as
    ``LinearScheme.decode``, vmapped so every group solves in one launch)."""
    coeffs = coeffs.astype(jnp.float32)
    k = coeffs.shape[1]

    def one(po, outs, mm, pa):
        C = coeffs * pa.astype(jnp.float32)[:, None]
        po = po.astype(jnp.float32) * pa.reshape(
            (-1,) + (1,) * (po.ndim - 1))
        outs = outs.astype(jnp.float32)
        avail = (~mm).astype(jnp.float32)
        rhs = po - jnp.einsum("rk,k...->r...", C * avail[None, :], outs)
        M = C * mm.astype(jnp.float32)[None, :]
        G = M.T @ M + 1e-9 * jnp.eye(k)
        mt_rhs = jnp.einsum("rk,r...->k...", M, rhs)
        sol = jnp.linalg.solve(G, mt_rhs.reshape(k, -1)).reshape(
            mt_rhs.shape)
        mmr = mm.reshape((k,) + (1,) * (outs.ndim - 1))
        return jnp.where(mmr, sol, outs)

    return jax.vmap(one)(jnp.asarray(parity_outs), jnp.asarray(outputs),
                         jnp.asarray(missing_masks, bool),
                         jnp.asarray(parity_avail, bool))
