"""Pallas TPU kernel: learned-encoder final projection — the linear map
from the encoder MLP's hidden activations to the r parity rows,

    out[j] = sum_h W[h, j] * H[h]          (H [H, B, F], W [H, r])

Structurally the same memory-bound reduction as parity encoding, but over
the hidden dimension H instead of the coding dimension k, with all r output
rows produced by one launch.  The grid tiles (r, B, F); each program
instance streams its H input tiles HBM->VMEM and accumulates one output row
tile in fp32 VREGs.  Feature tiles are lane-aligned (multiples of 128),
batch tiles sublane-aligned (multiples of 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _project_kernel(w_ref, h_ref, o_ref, *, hidden):
    # w_ref block: [H, 1] (column j); h_ref: [H, bb, bf]; o_ref: [1, bb, bf]
    acc = h_ref[0].astype(jnp.float32) * w_ref[0, 0]
    for i in range(1, hidden):
        acc += h_ref[i].astype(jnp.float32) * w_ref[i, 0]
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_f",
                                             "interpret"))
def learned_project(h, w, *, block_b=8, block_f=512, interpret=False):
    """h [H, B, F]; w [H, r] -> [r, B, F]."""
    H, B, F = h.shape
    r = w.shape[1]
    block_b = min(block_b, B)
    block_f = min(block_f, F)
    grid = (r, pl.cdiv(B, block_b), pl.cdiv(F, block_f))
    return pl.pallas_call(
        functools.partial(_project_kernel, hidden=H),
        grid=grid,
        in_specs=[
            pl.BlockSpec((H, 1), lambda j, i, b: (0, j)),     # W column j
            pl.BlockSpec((H, block_b, block_f), lambda j, i, b: (0, i, b)),
        ],
        out_specs=pl.BlockSpec((1, block_b, block_f),
                               lambda j, i, b: (j, i, b)),
        out_shape=jax.ShapeDtypeStruct((r, B, F), h.dtype),
        interpret=interpret,
    )(w.astype(jnp.float32), h)
