"""Pallas TPU kernel: causal/windowed FlashAttention for prefill.

Grid (batch, head, q_block, kv_block) with the KV dimension innermost — on
TPU the grid is executed sequentially over the minor-most axis, so fp32
running-max / denominator / accumulator scratch in VMEM persists across the
KV sweep of each (b, h, q) program. Blocks are MXU-aligned (q/kv blocks
multiples of 128 when the sequence allows; hd is the lane dim).

GQA is handled in the BlockSpec index maps: the KV block for head h comes
from kv-head ``h // (H // KV)`` — no materialised jnp.repeat of the KV tensor
(the XLA fallback in repro.models.layers pays that cost; avoiding it is one
of the §Perf items in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale, causal, window, block_q, block_k, n_kv_blocks,
                  seq_k):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    valid = kpos < seq_k
    if causal:
        valid &= kpos <= qpos
    if window:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    v = v_ref[0, 0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(
                           o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=False):
    """q [B,Sq,H,hd]; k,v [B,Sk,KV,hd] -> [B,Sq,H,hd]."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)

    # layout: [B, H, S, hd]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=nk, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
