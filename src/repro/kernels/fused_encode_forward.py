"""Pallas TPU kernel: fused parity encode -> first forward matmul.

The coded hot path for linear/MLP parity substrates runs encode (the [r, k]
projection over the coding dimension) and the parity model's first matmul as
SEPARATE launches today, materialising the [r, B, F] encoded queries in HBM
between them.  This kernel fuses the two:

    out[j, b, v] = sum_f ( sum_i C[j, i] * X[i, b, f] ) * W[j, f, v]

Queries are flattened to [k, B, F]; each parity row j carries its OWN
first-layer weight matrix W[j] (parity models are trained independently per
row).  The grid tiles (r, B, V, F): a program instance streams its k query
tiles HBM->VMEM, accumulates the encoded tile in fp32 VREGs, multiplies it
into W[j]'s tile on the MXU and accumulates the product into an fp32 VMEM
scratch over the F (contraction) grid axis — the innermost axis, so the
output block is revisited and flushed once on the last F step.  Feature and
value tiles are lane-aligned (multiples of 128), batch tiles sublane-aligned
(multiples of 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(c_ref, q_ref, w_ref, o_ref, acc_ref, *, k, nf, f_total,
                  block_f):
    # c_ref [1, k]; q_ref [k, bb, bf]; w_ref [1, bf, bv]; o_ref [1, bb, bv];
    # acc_ref [bb, bv] fp32 scratch, live across the F grid axis
    f = pl.program_id(3)

    @pl.when(f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    enc = q_ref[0].astype(jnp.float32) * c_ref[0, 0]
    for i in range(1, k):
        enc += q_ref[i].astype(jnp.float32) * c_ref[0, i]
    w = w_ref[0].astype(jnp.float32)
    if f_total % block_f:
        # a trailing partial F block is padded with UNDEFINED values — zero
        # the invalid tail of BOTH operands (0 * garbage/NaN != 0)
        valid = (f * block_f +
                 jax.lax.broadcasted_iota(jnp.int32, (1, block_f), 1)
                 ) < f_total
        enc = jnp.where(valid, enc, 0.0)
        w = jnp.where(valid.reshape(block_f, 1), w, 0.0)
    acc_ref[...] += jnp.dot(enc, w)

    @pl.when(f == nf - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_f", "block_v",
                                             "interpret"))
def fused_encode_forward(queries, coeffs, weights, *, block_b=8, block_f=512,
                         block_v=128, interpret=False):
    """queries [k, B, F]; coeffs [r, k]; weights [r, F, V] -> [r, B, V]."""
    k, B, F = queries.shape
    r, _, V = weights.shape
    block_b = min(block_b, B)
    block_f = min(block_f, F)
    block_v = min(block_v, V)
    nf = pl.cdiv(F, block_f)
    grid = (r, pl.cdiv(B, block_b), pl.cdiv(V, block_v), nf)
    return pl.pallas_call(
        functools.partial(_fused_kernel, k=k, nf=nf, f_total=F,
                          block_f=block_f),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda j, b, v, f: (j, 0)),    # coeffs row j
            pl.BlockSpec((k, block_b, block_f),
                         lambda j, b, v, f: (0, b, f)),
            pl.BlockSpec((1, block_f, block_v),
                         lambda j, b, v, f: (j, f, v)),         # W[j] tile
        ],
        out_specs=pl.BlockSpec((1, block_b, block_v),
                               lambda j, b, v, f: (j, b, v)),
        out_shape=jax.ShapeDtypeStruct((r, B, V), queries.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, block_v), jnp.float32)],
        interpret=interpret,
    )(coeffs.astype(jnp.float32), queries, weights)
