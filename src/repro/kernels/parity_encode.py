"""Pallas TPU kernel: ParM parity encoding — P = sum_i c_i * X_i.

Memory-bound elementwise reduction over the (small, static) coding dimension
k. Queries are flattened to [k, B, F]; the grid tiles (B, F) and each program
instance streams its k input tiles HBM->VMEM, accumulating in fp32 VREGs.
Feature tiles are lane-aligned (multiples of 128); batch tiles sublane-aligned
(multiples of 8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _encode_kernel(c_ref, q_ref, o_ref, *, k):
    # q_ref block: [k, bb, bf]; c_ref: [k] in SMEM; o_ref: [bb, bf]
    acc = q_ref[0].astype(jnp.float32) * c_ref[0]
    for i in range(1, k):
        acc += q_ref[i].astype(jnp.float32) * c_ref[i]
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_f",
                                             "interpret"))
def parity_encode(queries, coeffs, *, block_b=8, block_f=512,
                  interpret=False):
    """queries [k, B, F]; coeffs [k] -> [B, F]."""
    k, B, F = queries.shape
    block_b = min(block_b, B)
    block_f = min(block_f, F)
    grid = (pl.cdiv(B, block_b), pl.cdiv(F, block_f))
    return pl.pallas_call(
        functools.partial(_encode_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i, j: (0,)),          # coeffs (tiny)
            pl.BlockSpec((k, block_b, block_f), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, F), queries.dtype),
        interpret=interpret,
    )(coeffs.astype(jnp.float32), queries)
