"""Jit'd public wrappers around the Pallas kernels.

On a real TPU these call the Mosaic-compiled kernels; on this CPU container
they run in ``interpret=True`` mode (Python-evaluated, numerically identical)
— selected automatically from the backend so the same call sites work in
tests, benches and the serving runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.parity_encode import parity_encode as _encode
from repro.kernels.parity_decode import parity_decode as _decode
from repro.kernels.fused_encode_forward import (
    fused_encode_forward as _fused_ef)
from repro.kernels.multigroup_decode import multigroup_decode as _mg_decode
from repro.kernels.learned_encoder import learned_project as _project
from repro.kernels.berrut_encoder import berrut_encode as _berrut
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.decode_attention import decode_attention as _decode_attn


def _interpret():
    return jax.default_backend() != "tpu"


def parity_encode_op(queries, coeffs, **kw):
    """queries [k, B, ...] (any trailing feature shape); coeffs [k]."""
    k, B = queries.shape[:2]
    flat = queries.reshape(k, B, -1)
    out = _encode(flat, coeffs, interpret=_interpret(), **kw)
    return out.reshape((B,) + queries.shape[2:])


def parity_decode_op(parity_out, outputs, missing_idx, coeffs=None, **kw):
    """parity_out [B, V]; outputs [k, B, V]; missing_idx python int."""
    k = outputs.shape[0]
    c = jnp.ones((k,), jnp.float32) if coeffs is None else \
        jnp.asarray(coeffs, jnp.float32)
    avail = c * (jnp.arange(k) != missing_idx)
    inv_c = 1.0 / c[missing_idx]
    return _decode(parity_out, outputs, avail, inv_c,
                   interpret=_interpret(), **kw)


def fused_encode_forward_op(queries, coeffs, weights, **kw):
    """Fused coded hot path: encode + the first parity-forward matmul in one
    launch.  queries [k, B, ...] (any trailing feature shape, flattened to
    F); coeffs [r, k]; weights [r, F, V] — one first-layer matrix per parity
    row — returns [r, B, V]."""
    k, B = queries.shape[:2]
    flat = queries.reshape(k, B, -1)
    return _fused_ef(flat, jnp.asarray(coeffs, jnp.float32),
                     jnp.asarray(weights), interpret=_interpret(), **kw)


def multigroup_decode_op(parity_outs, outputs, missing_idxs, coeffs, **kw):
    """Batched r=1 subtraction decode over G stacked groups in one launch.

    parity_outs [G, B, V...] (axis 1 is batch when present: [G, V...] inputs
    are treated as batch 1); outputs [G, k, B, V...]; missing_idxs [G] ints;
    coeffs [k] (shared) or [G, k] (per-group).  Returns reconstructions
    shaped like ``parity_outs``."""
    parity_outs = jnp.asarray(parity_outs)
    outputs = jnp.asarray(outputs)
    G, k = outputs.shape[:2]
    if parity_outs.ndim >= 3:
        B = parity_outs.shape[1]
        po = parity_outs.reshape(G, B, -1)
        outs = outputs.reshape(G, k, B, -1)
    else:
        po = parity_outs.reshape(G, 1, -1)
        outs = outputs.reshape(G, k, 1, -1)
    idx = jnp.asarray(missing_idxs)
    c = jnp.asarray(coeffs, jnp.float32)
    if c.ndim == 1:
        c = jnp.broadcast_to(c[None], (G, k))
    avail = c * (jnp.arange(k)[None, :] != idx[:, None])
    inv = 1.0 / jnp.take_along_axis(c, idx[:, None], axis=1)     # [G, 1]
    cmat = jnp.concatenate([avail, inv], axis=1)                 # [G, k+1]
    out = _mg_decode(po, outs, cmat, interpret=_interpret(), **kw)
    return out.reshape(parity_outs.shape)


def berrut_encode_op(queries, coeffs, **kw):
    """Approxifer encode projection: queries [k, B, ...] (any trailing
    feature shape); coeffs [r, k] -> [r, B, ...], one launch for all r."""
    k, B = queries.shape[:2]
    flat = queries.reshape(k, B, -1)
    out = _berrut(flat, coeffs, interpret=_interpret(), **kw)
    return out.reshape((coeffs.shape[0], B) + queries.shape[2:])


def learned_project_op(h, w, **kw):
    """Learned-encoder final projection: h [H, B, ...] (any trailing feature
    shape); w [H, r] -> [r, B, ...]."""
    hd, B = h.shape[:2]
    flat = h.reshape(hd, B, -1)
    out = _project(flat, w, interpret=_interpret(), **kw)
    return out.reshape((w.shape[1], B) + h.shape[2:])


def flash_attention_op(q, k, v, *, causal=True, window=0, **kw):
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=_interpret(), **kw)


def decode_attention_op(q, k_cache, v_cache, pos, **kw):
    return _decode_attn(q, k_cache, v_cache, pos, interpret=_interpret(),
                        **kw)
