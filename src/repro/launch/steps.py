"""Step functions + ShapeDtypeStruct input specs for every
(architecture x input-shape) dry-run combination. No device allocation —
everything here is shape-level until jit.lower()."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES
from repro.models import transformer as T
from repro.training.loss import lm_loss
from repro.training.optim import AdamConfig, adam_update


def pick_opt_config(cfg, n_params):
    """bf16 Adam moments for >=100B-param archs so train_4k fits 16GB HBM
    (DESIGN.md 'Assumptions changed')."""
    mdt = "bfloat16" if n_params > 3e10 else "float32"
    return AdamConfig(lr=3e-4, weight_decay=0.1, moment_dtype=mdt)


def param_shapes(cfg, seed=0):
    return jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(seed)))


def n_params_of(shapes):
    import math
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(shapes))


def input_specs(cfg, shape_name, dtype=None):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    dt = dtype or cfg.dtype
    i32 = jnp.int32

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    if sh.kind == "train":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "vlm":
            batch["cross_embeds"] = sds((B, cfg.n_modality_tokens,
                                         cfg.d_model), dt)
        if cfg.enc_dec:
            batch["frames"] = sds((B, S, cfg.d_model), dt)
        return batch
    if sh.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        if cfg.family == "vlm":
            batch["cross_embeds"] = sds((B, cfg.n_modality_tokens,
                                         cfg.d_model), dt)
        if cfg.enc_dec:
            batch["frames"] = sds((B, S, cfg.d_model), dt)
        return batch
    if sh.kind == "decode":
        return {"token": sds((B, 1), i32)}
    raise ValueError(sh.kind)


def cache_shapes(cfg, shape_name):
    sh = SHAPES[shape_name]
    return jax.eval_shape(
        lambda: T.init_cache(cfg, sh.global_batch, sh.seq_len))


# --------------------------------------------------------------------------
def logits_pspec(batch_axes=("data",)):
    from jax.sharding import PartitionSpec as P
    return P(batch_axes, None, "model")


def make_train_step(cfg, opt_cfg, shard_logits=True,
                    batch_axes=("data",), microbatch=0):
    """``microbatch`` > 1 splits the global batch into that many
    gradient-accumulation steps (lax.scan): live activations and fp32
    loss/grad temporaries shrink ~linearly at the cost of re-running the
    (already remat'd) forward per slice — the §Perf lever for the
    memory-dominated train_4k pairs."""
    lspec = logits_pspec(batch_axes) if shard_logits else None

    def loss_fn(p, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["cross_embeds"] = batch["cross_embeds"]
        if cfg.enc_dec:
            kw["cross_embeds"] = batch["frames"]
        logits, aux = T.forward(cfg, p, tokens=batch["tokens"],
                                remat=True, logits_pspec=lspec, **kw)
        return lm_loss(logits, batch["tokens"], aux, cfg.router_aux_coef)

    def train_step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            m = microbatch
            mb = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def acc_body(carry, one):
                g_acc, l_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, one)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype) / m, g_acc, grads)
                return (g_acc, l_acc + loss / m), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body,
                                            (g0, jnp.zeros(())), mb)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state = adam_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, loss
    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["cross_embeds"] = batch["cross_embeds"]
        if cfg.enc_dec:
            kw["cross_embeds"] = batch["frames"]
        logits, cache = T.prefill(cfg, params, tokens=batch["tokens"], **kw)
        return logits, cache
    return prefill_step


def make_decode_step(cfg, pos):
    def decode_step(params, cache, batch):
        return T.decode_step(cfg, params, cache, pos, token=batch["token"])
    return decode_step


def make_coded_serve_step(cfg, k=2, optimized=False):
    """The paper's technique as one fused pjit program (prefill flavour):
    embed k member query batches, encode (addition, embedding space §3.2 /
    DESIGN.md §3), run the parity model, and return the parity output the
    decoder consumes. The §Perf 'technique-representative' hillclimb pair.

    ``optimized=False`` — paper-faithful baseline: per-member embedding
    (vmap over k, as the frontend would embed each query) and the full
    parity logit sequence.
    ``optimized=True``  — beyond-paper: (a) one fused gather over the
    [k*B, S] token block instead of k serialized gathers, (b) unembed only
    the positions the LM decoder actually consumes (the last token) —
    dropping the [B, S, V] parity-logit matmul to [B, 1, V].
    """
    def coded_step(parity_params, batch):
        toks = batch["tokens"]                  # [k, B, S]
        kk, B, S = toks.shape
        if optimized:
            flat = T.embed_tokens(cfg, parity_params,
                                  toks.reshape(kk * B, S))
            parity_q = flat.reshape(kk, B, S, -1).sum(axis=0)
            logits, _ = T.forward(cfg, parity_params, embeds=parity_q,
                                  unembed_last_only=True)
            return logits, {}
        embeds = jax.vmap(lambda t: T.embed_tokens(cfg, parity_params, t))(
            toks)                               # [k, B, S, D]
        parity_q = embeds.sum(axis=0)
        logits, _ = T.forward(cfg, parity_params, embeds=parity_q)
        return logits[:, -1:], {}
    return coded_step


def coded_input_specs(cfg, shape_name, k=2):
    sh = SHAPES[shape_name]
    return {"tokens": jax.ShapeDtypeStruct(
        (k, sh.global_batch, sh.seq_len), jnp.int32)}
