"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        [--reduced] [--steps 50] [--batch 8] [--seq 64] [--ckpt out.npz]

On this CPU container use ``--reduced`` (the default) — full configs are for
the pod mesh (see repro.launch.dryrun). Trains on the synthetic Markov LM
stream, logs loss, and optionally checkpoints.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.io import save
from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import lm_batches
from repro.launch.steps import n_params_of, param_shapes
from repro.models import transformer as T
from repro.training.optim import AdamConfig, adam_init
from repro.training.train_lib import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale; not for this CPU host)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    print(f"arch={cfg.name} params~{n_params_of(param_shapes(cfg)):,}")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt_cfg = AdamConfig(lr=args.lr, grad_clip=1.0)
    opt_state = adam_init(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False))

    data = lm_batches(cfg.vocab, args.batch, args.seq, args.steps, seed=0)
    t0 = time.time()
    for i in range(args.steps):
        batch = {"tokens": jnp.asarray(data[i])[:, : args.seq]}
        if cfg.family == "vlm":
            batch["cross_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(i),
                (args.batch, cfg.n_modality_tokens, cfg.d_model))
        if cfg.enc_dec:
            batch["frames"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(i), (args.batch, args.seq, cfg.d_model))
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)")
    if args.ckpt:
        save(args.ckpt, params, step=args.steps)
        print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
