"""Roofline derivation from compiled dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` of the SPMD-partitioned executable is *per device*
(verified empirically; see EXPERIMENTS.md §Dry-run methodology), so

    compute term    = flops_per_device / peak_flops
    memory term     = bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / link_bw

Collective bytes are not in cost_analysis; we parse the compiled (post-SPMD,
per-device) HLO text and sum the result-buffer sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
LINK_BW = 50e9               # bytes / s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %all-gather.3 = bf16[16,4096,1408]{2,1,0} all-gather(
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_ONE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text):
    """Sum per-device result bytes of collective ops, bucketed by op kind."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _SHAPE_RE.search(line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        if f" {op}(" not in line and f" {op}-start(" not in line:
            continue
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _ONE_SHAPE.findall(shapes_str))
        out[op] += total
        count[op] += 1
    return out, count


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_detail: dict
    coll_counts: dict
    chips: int

    @property
    def compute_s(self):
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self):
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_detail": self.coll_detail,
            "coll_counts": self.coll_counts,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(compiled, chips):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax < 0.5 wraps the dict in a list
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    detail, counts = collective_bytes(compiled.as_text())
    coll = float(sum(detail.values()))
    return Roofline(flops, byts, coll, detail, counts, chips)


def model_flops(cfg, n_tokens, n_params=None, active_params=None):
    """MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE)."""
    n = active_params if active_params is not None else n_params
    return 6.0 * n * n_tokens


def active_param_count(cfg, n_params):
    """Approximate active params for MoE: replace full expert banks with the
    top-k (+shared) slice."""
    if not cfg.n_experts:
        return n_params
    expert_p = 3 * cfg.d_model * cfg.moe_d_ff       # w1,w2,w3 per expert
    n_moe_layers = cfg.n_layers // cfg.moe_every
    total_experts = n_moe_layers * cfg.n_experts * expert_p
    active_experts = n_moe_layers * cfg.moe_top_k * expert_p
    return n_params - total_experts + active_experts
