"""Roofline derivation from compiled dry-run artifacts.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` of the SPMD-partitioned executable is *per device*
(verified empirically; see EXPERIMENTS.md §Dry-run methodology), so

    compute term    = flops_per_device / peak_flops
    memory term     = bytes_per_device / hbm_bw
    collective term = collective_bytes_per_device / link_bw

Collective bytes are not in cost_analysis; we parse the compiled (post-SPMD,
per-device) HLO text and sum the result-buffer sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
LINK_BW = 50e9               # bytes / s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.:  %all-gather.3 = bf16[16,4096,1408]{2,1,0} all-gather(
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_ONE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype, dims):
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text):
    """Sum per-device result bytes of collective ops, bucketed by op kind."""
    out = {c: 0 for c in _COLLECTIVES}
    count = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _SHAPE_RE.search(line)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        if f" {op}(" not in line and f" {op}-start(" not in line:
            continue
        total = sum(_shape_bytes(dt, dims)
                    for dt, dims in _ONE_SHAPE.findall(shapes_str))
        out[op] += total
        count[op] += 1
    return out, count


@dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_detail: dict
    coll_counts: dict
    chips: int

    @property
    def compute_s(self):
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self):
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self):
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_detail": self.coll_detail,
            "coll_counts": self.coll_counts,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(compiled, chips):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # jax < 0.5 wraps the dict in a list
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    detail, counts = collective_bytes(compiled.as_text())
    coll = float(sum(detail.values()))
    return Roofline(flops, byts, coll, detail, counts, chips)


def model_flops(cfg, n_tokens, n_params=None, active_params=None):
    """MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE)."""
    n = active_params if active_params is not None else n_params
    return 6.0 * n * n_tokens


def active_param_count(cfg, n_params):
    """Approximate active params for MoE: replace full expert banks with the
    top-k (+shared) slice."""
    if not cfg.n_experts:
        return n_params
    expert_p = 3 * cfg.d_model * cfg.moe_d_ff       # w1,w2,w3 per expert
    n_moe_layers = cfg.n_layers // cfg.moe_every
    total_experts = n_moe_layers * cfg.n_experts * expert_p
    active_experts = n_moe_layers * cfg.moe_top_k * expert_p
    return n_params - total_experts + active_experts


# --------------------------------------------------------------------------
# Token-level decode service-time model (coded LM serving calibration)
# --------------------------------------------------------------------------
def _layer_counts(cfg):
    """(n_attn_layers, n_mamba_layers) from the superblock plan."""
    if cfg.attn_every:                  # hybrid: one attn layer per period
        n_periods = cfg.n_layers // cfg.period
        return n_periods, cfg.n_layers - n_periods
    if cfg.family == "ssm":
        return 0, cfg.n_layers
    return cfg.n_layers, 0


def estimate_param_count(cfg):
    """Parameter count from config arithmetic alone — no init, no dry-run.

    Close enough for a roofline service-time model of the big configs
    (qwen3_moe_235b, jamba_1_5_large_398b, mamba2_780m) where materialising
    params to count them is exactly what we cannot afford on CPU."""
    D, V = cfg.d_model, cfg.vocab
    n_attn, n_mamba = _layer_counts(cfg)
    p = V * D                                        # embedding
    if not cfg.tie_embeddings:
        p += D * V                                   # lm_head
    if n_attn and cfg.n_heads:
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        p += n_attn * (D * H * hd + 2 * D * KV * hd + H * hd * D)
    if n_mamba:
        d_inner = cfg.ssm_expand * D
        # in/out projections dominate; conv/dt/A/D terms are noise at scale
        p += n_mamba * 3 * D * d_inner
    # ffn: moe layers carry n_experts (+shared) expert MLPs + router,
    # the rest carry a dense (SwiGLU) MLP
    n_ffn = cfg.n_layers if not (cfg.family == "ssm" and not cfg.attn_every) \
        else 0
    if cfg.n_experts:
        n_moe = cfg.n_layers // cfg.moe_every
        expert_p = 3 * D * cfg.moe_d_ff
        p += n_moe * (cfg.n_experts + cfg.n_shared_experts) * expert_p
        p += n_moe * D * cfg.n_experts               # router
        n_dense = n_ffn - n_moe
    else:
        n_dense = n_ffn
    if cfg.d_ff:
        p += n_dense * 3 * D * cfg.d_ff
    return p


def kv_cache_bytes(cfg, kv_len, batch=1):
    """Decode-step KV traffic: every cached K/V byte is read once per token."""
    n_attn, _ = _layer_counts(cfg)
    S = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    bytes_per = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    cache = 0
    if n_attn and cfg.n_heads:
        KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache = n_attn * 2 * S * KV * hd * bytes_per * batch
    if cfg.ssm_state:
        _, n_mamba = _layer_counts(cfg)
        d_inner = cfg.ssm_expand * cfg.d_model
        n_heads_ssm = max(1, d_inner // cfg.ssm_head_dim)
        cache += n_mamba * n_heads_ssm * cfg.ssm_state * cfg.ssm_head_dim \
            * 4 * batch                              # fp32 SSM state
    return cache


def decode_token_cost(cfg, *, n_params=None, batch=1, kv_len=0, tp=1):
    """Seconds per decode step (one token per active stream).

    Autoregressive decode at small batch is memory-bound: every active
    parameter and every cached KV byte streams HBM->chip once per step, so

        t = (active_param_bytes / tp + kv_bytes) / HBM_BW

    with a compute-term floor for large batch.  ``tp`` is the tensor-
    parallel degree (params shard; the per-chip KV slice stays resident but
    each chip still reads its full shard every step)."""
    if n_params is None:
        n_params = estimate_param_count(cfg)
    active = active_param_count(cfg, n_params)
    bytes_per = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    mem_s = (active * bytes_per / tp
             + kv_cache_bytes(cfg, kv_len, batch) / tp) / HBM_BW
    comp_s = model_flops(cfg, batch, active_params=active) / (tp * PEAK_FLOPS)
    return max(mem_s, comp_s)
