"""Coded-serving launcher: ParM over any assigned LM architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        [--k 2] [--m 4] [--n 60] [--straggle-ms 120]

Builds a reduced deployed LM, distills a parity LM for it (embedding-space
addition code — the ``sum`` entry of the scheme registry, DESIGN.md §2), then
serves single-sequence queries through the declarative serving API
(``deploy(DeploymentSpec(...))`` — DESIGN.md §8) with an injected straggler
instance and prints latency + completion-path statistics.  Degraded-mode
predictions are the decoder's subtraction reconstructions. The ``--strategy``
flag picks any registered ``ResilienceStrategy`` (DESIGN.md §3);
``--batch-size`` enables Clipper-style adaptive batching on the main pool.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.data.pipeline import lm_batches
from repro.models import transformer as T
from repro.serving.api import BatchingPolicy, DeploymentSpec, deploy
from repro.serving.strategy import available_strategies
from repro.training.optim import AdamConfig, adam_init
from repro.training.train_lib import (make_parity_train_step,
                                      make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--n", type=int, default=60)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--strategy", default="parm",
                    choices=available_strategies())
    ap.add_argument("--slo-ms", type=float, default=250.0,
                    help="deadline for the default_slo strategy")
    ap.add_argument("--batch-size", type=int, default=1,
                    help="adaptive-batching max batch size (main pool)")
    ap.add_argument("--batch-delay-ms", type=float, default=2.0,
                    help="max time a worker holds a batch open")
    ap.add_argument("--train-steps", type=int, default=20)
    ap.add_argument("--parity-steps", type=int, default=40)
    ap.add_argument("--straggle-ms", type=float, default=120.0)
    args = ap.parse_args()
    if get_config(args.arch).enc_dec or get_config(args.arch).family == "vlm":
        print("note: modality archs serve text-side queries here; frame/"
              "patch embeddings would ride along in production")

    cfg = get_config(args.arch, reduced=True)
    if cfg.enc_dec or cfg.family == "vlm":
        cfg = cfg.replace(enc_dec=False, n_enc_layers=0, cross_attn_every=0)
    key = jax.random.PRNGKey(0)
    B, S, k = 8, args.seq, args.k

    # 1. deployed LM
    deployed = T.init_params(cfg, key)
    opt = AdamConfig(lr=3e-3)
    tstep = jax.jit(make_train_step(cfg, opt, remat=False))
    ostate = adam_init(deployed, opt)
    data = lm_batches(cfg.vocab, B, S, args.train_steps + 40, seed=0)
    for i in range(args.train_steps):
        deployed, ostate, m = tstep(
            deployed, ostate, {"tokens": jnp.asarray(data[i])[:, :S]})
    print(f"deployed {cfg.name}: loss {float(m['loss']):.3f}")

    # 2. parity LM (distillation)
    parity = T.init_params(cfg, jax.random.PRNGKey(1))
    pstep = jax.jit(make_parity_train_step(cfg, opt))
    pstate = adam_init(parity, opt)

    @jax.jit
    def make_batch(toks):
        embeds = jax.vmap(lambda t: T.embed_tokens(cfg, deployed, t))(toks)
        teacher = jax.vmap(
            lambda t: T.forward(cfg, deployed, tokens=t)[0])(toks)
        return {"embeds": embeds, "teacher": teacher}

    for i in range(args.parity_steps):
        toks = jnp.stack([
            jnp.asarray(data[(i + j) % len(data)][: B // k, :S])
            for j in range(k)])
        parity, pstate, pm = pstep(parity, pstate, make_batch(toks))
    print(f"parity model: final distill MSE {float(pm['loss']):.4f}")

    # 3. serve: queries are token sequences; frontend encodes embeddings
    @jax.jit
    def deployed_fwd(p, emb):
        return T.forward(cfg, p, embeds=emb)[0][:, -1]   # next-token logits

    def embed(tokens):
        return np.asarray(T.embed_tokens(cfg, deployed, tokens))

    slow = {0}

    def delay(iid):
        return args.straggle_ms / 1e3 if iid in slow else 0.0

    extra = {}
    if args.strategy == "default_slo":
        # Clipper baseline: a constant (uniform-logits) default prediction
        # returned at the SLO deadline
        extra = dict(slo_ms=args.slo_ms,
                     default_prediction=np.zeros((1, cfg.vocab), np.float32))
    spec = DeploymentSpec(
        fwd=deployed_fwd, params=deployed, parity_params=parity,
        strategy=args.strategy, k=k, m=args.m, delay_fn=delay,
        batching=BatchingPolicy(max_size=args.batch_size,
                                max_delay_ms=args.batch_delay_ms),
        **extra)
    with deploy(spec, engine="threads") as sess:
        rng = np.random.default_rng(0)
        futs = []
        for i in range(args.n):
            toks = jnp.asarray(data[rng.integers(len(data))][:1, :S])
            futs.append(sess.submit(embed(toks)))
            time.sleep(0.01)
        assert sess.wait_all(timeout=120), "unanswered queries"
        stats = sess.stats()
        lat = np.array([f.latency_ms for f in futs])
        fe = sess.frontend
        lay = fe.strategy.layout(args.m, k, fe.r)
        pools = f"main={lay.main}" + \
            (f" parity={lay.parity}x{fe.r}" if lay.parity else "")
        print(f"\nserved {args.n} queries via '{args.strategy}' "
              f"({pools}; instance 0 straggles {args.straggle_ms:.0f} ms)")
        print(f"latency p50={np.percentile(lat, 50):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms max={lat.max():.1f}ms")
        print(f"completed_by: {stats['completed_by']}")
        if stats["mean_batch_size"] > 1:
            print(f"batching: {stats['batches']} inference calls, "
                  f"mean batch {stats['mean_batch_size']:.2f}")
        if stats["cancellations"]:
            print(f"redundant work cancelled: "
                  f"{stats['cancelled_queries']} originals, "
                  f"{stats['cancelled_parities']} parity queries")
        recon = [f for f in futs if f.completed_by == "parity"]
        if recon:
            print(f"{len(recon)} predictions reconstructed from parity "
                  "outputs (degraded mode)")


if __name__ == "__main__":
    main()
