"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) combination and extract memory / cost / collective evidence.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
        --shape train_4k [--multi-pod] [--mesh test]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
# The dry-run (and ONLY the dry-run) needs 512 placeholder devices so
# jax.make_mesh can build the production mesh. This MUST precede any other
# import — jax locks the device count on first init.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config
from repro.distributed.logical import logical_rules, rules_for_mesh
from repro.distributed.sharding import ShardingRules
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch import steps as ST
from repro.launch.roofline import analyze, active_param_count, model_flops
from repro.training.optim import adam_init


def _mesh_for(name):
    if name == "pod":
        return make_production_mesh(multi_pod=False)
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    if name == "pod_serve8":
        # serving mesh with tp aligned to GQA kv-head counts (§Perf):
        # same 256 chips, (data=32, model=8)
        return make_test_mesh((32, 8), ("data", "model"))
    if name == "test":
        return make_test_mesh((2, 2), ("data", "model"))
    raise ValueError(name)


def adapt_config(arch, shape_name):
    """Per-shape config adjustments, recorded in the output notes."""
    cfg = get_config(arch)
    notes = []
    if shape_name == "long_500k" and not cfg.subquadratic:
        # pure full-attention archs run long-context decode with the
        # sliding-window variant (DESIGN.md §4 'Skips')
        cfg = cfg.replace(sliding_window=8192)
        notes.append("sliding_window=8192 for long_500k")
    return cfg, notes


def run_pair(arch, shape_name, mesh_name="pod", verbose=True,
             step_override=None, microbatch=0):
    t0 = time.time()
    cfg, notes = adapt_config(arch, shape_name)
    sh = SHAPES[shape_name]
    mesh = _mesh_for(mesh_name)
    chips = int(mesh.devices.size)

    pshapes = ST.param_shapes(cfg)
    n_params = ST.n_params_of(pshapes)
    # inference layout: replicate weights over 'data' (no per-layer FSDP
    # gathers) whenever tp-sharded bf16 params fit comfortably in HBM
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    fsdp_params = not (sh.kind != "train"
                       and n_params * 2 / tp_size < 8e9)
    if not fsdp_params:
        notes.append("inference layout: params replicated over data axis")
    rules = ShardingRules(mesh, fsdp_params=fsdp_params)

    psh = rules.params(pshapes)
    batch = ST.input_specs(cfg, shape_name)
    bsh = rules.batch_specs(batch)
    rep = rules.replicated()

    lrules, lsizes = rules_for_mesh(mesh)
    lrules["fsdp_params"] = fsdp_params
    with mesh, logical_rules(lrules, lsizes, mesh):
        if sh.kind == "train":
            opt_cfg = ST.pick_opt_config(cfg, n_params)
            oshapes = jax.eval_shape(lambda p: adam_init(p, opt_cfg),
                                     pshapes)
            osh = rules.opt_state(oshapes, psh)
            fn = step_override(cfg, opt_cfg) if step_override else \
                ST.make_train_step(cfg, opt_cfg,
                                   batch_axes=rules.batch_axes,
                                   microbatch=microbatch)
            jitted = jax.jit(fn, in_shardings=(psh, osh, bsh),
                             out_shardings=(psh, osh, rep),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(pshapes, oshapes, batch)
        elif sh.kind == "prefill":
            fn = step_override(cfg) if step_override else \
                ST.make_prefill_step(cfg)
            out_shapes = jax.eval_shape(fn, pshapes, batch)
            csh = rules.cache_specs(out_shapes[1])
            lsh = rules.logits_spec(sh.global_batch, cfg.vocab)
            jitted = jax.jit(fn, in_shardings=(psh, bsh),
                             out_shardings=(lsh, csh))
            lowered = jitted.lower(pshapes, batch)
        else:  # decode
            cshapes = ST.cache_shapes(cfg, shape_name)
            csh = rules.cache_specs(cshapes)
            lsh = rules.logits_spec(sh.global_batch, cfg.vocab)
            fn = step_override(cfg, sh.seq_len - 1) if step_override else \
                ST.make_decode_step(cfg, sh.seq_len - 1)
            jitted = jax.jit(fn, in_shardings=(psh, csh, bsh),
                             out_shardings=(lsh, csh), donate_argnums=(1,))
            lowered = jitted.lower(pshapes, cshapes, batch)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    roof = analyze(compiled, chips)
    # XLA cost_analysis counts while-loop bodies ONCE (verified: compute
    # term scaled 1/m under m-way microbatching). The layer-stack scan
    # dominates both flops and collective volume, so we also report terms
    # scaled by its trip count (x enc groups for enc-dec; x microbatch).
    # Inner scans (flash KV blocks, SSD chunks) are still counted once —
    # the corrected numbers are lower bounds. Peak-memory numbers from
    # memory_analysis are exact either way.
    scan_trips = cfg.n_groups
    if cfg.enc_dec:
        scan_trips += cfg.n_enc_layers // cfg.period
    scan_trips *= max(1, microbatch)
    n_active = active_param_count(cfg, n_params)
    n_tokens = sh.global_batch * (sh.seq_len if sh.kind != "decode" else 1)
    mf = model_flops(cfg, n_tokens, active_params=n_active)
    if sh.kind == "train":
        mf *= 3.0                      # fwd + bwd
    hlo_flops_total = roof.flops_per_device * chips * scan_trips

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "kind": sh.kind, "n_params": n_params, "n_active_params": n_active,
        "notes": notes,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_live_bytes": mem.argument_size_in_bytes
                               + mem.output_size_in_bytes
                               + mem.temp_size_in_bytes
                               - mem.alias_size_in_bytes,
        },
        "roofline": roof.as_dict(),
        "scan_trips": scan_trips,
        "roofline_scan_corrected": {
            "compute_s": roof.compute_s * scan_trips,
            "memory_s": roof.memory_s * scan_trips,
            "collective_s": roof.collective_s * scan_trips,
        },
        "microbatch": microbatch,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_flops_total
                               if hlo_flops_total else None),
        "compile_s": time.time() - t0,
    }
    if verbose:
        r = roof
        print(f"{arch:24s} {shape_name:12s} {mesh_name:8s} "
              f"compute={r.compute_s*1e3:9.3f}ms memory={r.memory_s*1e3:9.3f}ms "
              f"coll={r.collective_s*1e3:9.3f}ms dom={r.dominant:10s} "
              f"temp/chip={mem.temp_size_in_bytes/2**30:6.2f}GiB "
              f"({result['compile_s']:.0f}s)", flush=True)
    return result


def save(result, out_dir="experiments/dryrun"):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "{arch}__{shape}__{mesh}.json".format(
        **result))
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "pod_serve8", "test"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatch", type=int, default=0)
    args = ap.parse_args()
    mesh_name = "multipod" if args.multi_pod else args.mesh

    pairs = ([(a, s) for a in ARCH_IDS for s in SHAPES]
             if args.all else [(args.arch, args.shape)])
    failures = []
    for arch, shape in pairs:
        try:
            res = run_pair(arch, shape, mesh_name,
                           microbatch=args.microbatch)
            save(res, args.out)
        except Exception as e:
            failures.append((arch, shape, repr(e)))
            print(f"FAIL {arch} {shape}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("dry-run OK")


if __name__ == "__main__":
    main()
