"""Production mesh construction.

Target hardware: TPU v5e pods, 256 chips each. Single-pod mesh is
(data=16, model=16); multi-pod is (pod=2, data=16, model=16) = 512 chips,
with the batch sharded over ('pod', 'data') — the 'pod' axis only ever
carries data-parallel gradient reductions, so the slower inter-pod links see
one all-reduce per step.

``make_production_mesh`` is a function (not a module constant): importing
this module never touches JAX device state. The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; regular tests and benches see the 1 real CPU device.
"""
from __future__ import annotations

import jax

try:                                   # jax >= 0.5 has explicit axis types
    from jax.sharding import AxisType
except ImportError:                    # older jax: meshes are Auto already
    AxisType = None


def _mesh(shape, axes, devices):
    if AxisType is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run via "
            "repro.launch.dryrun which forces 512 host devices")
    return _mesh(shape, axes, devices)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU integration tests (requires forced host devices)."""
    n = 1
    for s in shape:
        n *= s
    return _mesh(shape, axes, jax.devices()[:n])
