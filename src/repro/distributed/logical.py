"""Logical activation-sharding rules (MaxText-style).

XLA's SPMD propagation does not reliably push shardings into ``while``-loop
carries (the flash-attention KV scan, the SSD chunk scan, the layer-stack
scan) — without explicit constraints those loop temporaries compile
*replicated*, which is exactly the 36 GiB/buffer blow-up found in the first
train_4k dry-run (EXPERIMENTS.md §Perf iteration 0).

Model code annotates activations with *logical* axis names
(``constrain(x, ("batch", None, "heads", None))``); the launcher binds
logical names to mesh axes once per run. When no rules are active (CPU unit
tests) constrain() is a no-op. A dim is only sharded when divisible by the
mesh-axis size, and each mesh axis is used at most once per spec.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_STATE = threading.local()


DEFAULT_LOGICAL = {
    "batch": ("data",),
    "tokens": ("data",),          # flattened batch*seq
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "capacity": ("data",),
    "ff": ("model",),
    "d_inner": ("model",),
    # cache sequence dim: takes whatever axes the batch dim left unused
    # (decode_32k -> model; long_500k B=1 -> model+data)
    "seq": ("model", "data"),
    "embed": (),
}


def set_rules(rules, axis_sizes, mesh=None):
    _STATE.rules = rules
    _STATE.sizes = axis_sizes
    _STATE.mesh = mesh


def clear_rules():
    _STATE.rules = None
    _STATE.sizes = None
    _STATE.mesh = None


def state():
    return (getattr(_STATE, "rules", None), getattr(_STATE, "sizes", None),
            getattr(_STATE, "mesh", None))


@contextmanager
def logical_rules(rules, axis_sizes, mesh=None):
    old = state()
    set_rules(rules, axis_sizes, mesh)
    try:
        yield
    finally:
        _STATE.rules, _STATE.sizes, _STATE.mesh = old


def rules_for_mesh(mesh, multi_pod=None):
    rules = dict(DEFAULT_LOGICAL)
    if "pod" in mesh.axis_names:
        rules["batch"] = ("pod", "data")
        rules["tokens"] = ("pod", "data")
    return rules, dict(zip(mesh.axis_names, mesh.devices.shape))


def constrain(x, axes):
    """axes: tuple of logical names (or None) matching x.ndim."""
    rules = getattr(_STATE, "rules", None)
    if rules is None:
        return x
    sizes = _STATE.sizes
    used = set()
    spec = []
    for dim, name in zip(x.shape, axes):
        entry = None
        mesh_axes = rules.get(name, ()) if name else ()
        chosen = []
        prod = 1
        for a in mesh_axes:
            if a in used or a not in sizes or sizes[a] <= 1:
                continue                       # axis taken elsewhere: skip it
            if dim % (prod * sizes[a]) == 0:
                prod *= sizes[a]
                chosen.append(a)
            else:
                break                          # indivisible: stop extending
        if chosen:
            used.update(chosen)
            entry = tuple(chosen) if len(chosen) > 1 else chosen[0]
        spec.append(entry)
    return jax.lax.with_sharding_constraint(x, P(*spec))
