"""Path-based sharding rules: FSDP x tensor x expert parallel.

Mesh axes: ``model`` (tensor/expert parallel, 16-way per pod), ``data``
(FSDP + batch, 16-way), optionally ``pod`` (2-way across pods; batch shards
over ('pod','data')).

Rules are name-driven over the param pytree paths and *divisibility-guarded*:
a dim is sharded on an axis only if it divides evenly (e.g. qwen2's kv=2
heads stay replicated on a 16-way model axis rather than forcing an uneven
partition). Stacked superblock params carry a leading layer-group dim that is
never sharded.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, tree_unflatten, DictKey, SequenceKey


def _path_str(path):
    parts = []
    for p in path:
        if isinstance(p, DictKey):
            parts.append(str(p.key))
        elif isinstance(p, SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class ShardingRules:
    """``fsdp_params=False`` is the *inference* layout: weights replicate
    over the data axis (tensor-parallel only), eliminating the per-layer
    FSDP all-gathers that otherwise dominate serving collectives. Only legal
    when params/tp_size fit HBM — the launcher decides per architecture."""

    def __init__(self, mesh, batch_axes=None, fsdp_params=True):
        self.mesh = mesh
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.tp = "model" if "model" in self.axis_sizes else None
        self.fsdp = ("data" if ("data" in self.axis_sizes and fsdp_params)
                     else None)
        self.fsdp_params = fsdp_params
        if batch_axes is None:
            batch_axes = tuple(a for a in ("pod", "data")
                               if a in self.axis_sizes)
        self.batch_axes = batch_axes

    # ------------------------------------------------------------------
    def _ok(self, dim_size, axis):
        if axis is None:
            return False
        a = self.axis_sizes.get(axis, 1)
        return dim_size % a == 0 and a > 1

    def _axis(self, dim_size, axis):
        return axis if self._ok(dim_size, axis) else None

    def _batch_axis(self, dim_size):
        """Largest prefix of batch_axes that divides dim_size."""
        total = 1
        chosen = []
        for a in self.batch_axes:
            total *= self.axis_sizes[a]
            if dim_size % total == 0:
                chosen.append(a)
            else:
                break
        return tuple(chosen) if chosen else None

    # ------------------------------------------------------------------
    def param_spec(self, path, leaf):
        """PartitionSpec for one parameter."""
        name = _path_str(path)
        shape = leaf.shape
        stacked = name.startswith("blocks") or "/blocks/" in name
        lead = (None,) if stacked else ()
        core = shape[1:] if stacked else shape

        def spec(*axes):
            return P(*(lead + tuple(axes)))

        last = name.rsplit("/", 1)[-1]
        if last in ("scale", "q_norm", "k_norm", "gate_norm", "conv_b",
                    "A_log", "D", "dt_bias"):
            return spec(*([None] * len(core)))
        if last == "embed":
            return P(self._axis(shape[0], self.tp),
                     self._axis(shape[1], self.fsdp))
        if last == "lm_head":
            return P(self._axis(shape[0], self.fsdp),
                     self._axis(shape[1], self.tp))
        if "moe" in name and last in ("w1", "w3") and len(core) == 3:
            return spec(self._axis(core[0], self.tp),      # [E, D, F]
                        self._axis(core[1], self.fsdp), None)
        if "moe" in name and last == "w2" and len(core) == 3:
            return spec(self._axis(core[0], self.tp), None,  # [E, F, D]
                        self._axis(core[2], self.fsdp))
        if last == "router":                            # [D, E]
            return spec(self._axis(core[0], self.fsdp), None)
        if last in ("wq", "wk", "wv", "w1", "w3", "in_proj"):
            return spec(self._axis(core[0], self.fsdp),
                        self._axis(core[1], self.tp))
        if last in ("wo", "w2", "out_proj"):
            return spec(self._axis(core[0], self.tp),
                        self._axis(core[1], self.fsdp))
        if last in ("bq", "bk", "bv"):
            return spec(self._axis(core[0], self.tp))
        if last == "conv_w":                            # [W, C]
            return spec(None, self._axis(core[1], self.tp))
        return spec(*([None] * len(core)))

    def params(self, params_shapes):
        leaves, treedef = tree_flatten_with_path(params_shapes)
        out = [NamedSharding(self.mesh, self.param_spec(path, leaf))
               for path, leaf in leaves]
        return tree_unflatten(treedef, out)

    def opt_state(self, opt_shapes, param_sharding):
        """Moments shard like params; step is replicated."""
        rep = NamedSharding(self.mesh, P())
        return {"mu": jax.tree.map(lambda s: s, param_sharding),
                "nu": jax.tree.map(lambda s: s, param_sharding),
                "step": rep}

    # ------------------------------------------------------------------
    def activations(self, batch):
        return NamedSharding(self.mesh, P(self._batch_axis(batch), None))

    def batch_specs(self, batch_shapes):
        """Shardings for a batch dict of ShapeDtypeStructs: leading dim =
        batch (sharded over batch axes when divisible)."""
        def one(leaf):
            ba = self._batch_axis(leaf.shape[0])
            return NamedSharding(self.mesh,
                                 P(*((ba,) + (None,) * (leaf.ndim - 1))))
        return jax.tree.map(one, batch_shapes)

    def logits_spec(self, batch, vocab=None):
        V_axis = self._axis(vocab, self.tp) if vocab else self.tp
        return NamedSharding(self.mesh, P(self._batch_axis(batch), None,
                                          V_axis))

    def cache_specs(self, cache_shapes):
        """KV/SSM cache shardings. Leaves are stacked [G, B, ...]:
        - attn k/v [G, B, S, KV, hd]: batch over batch-axes when divisible,
          else sequence over 'data' (long_500k B=1); kv-heads over 'model'
          when divisible.
        - ssm state [G, B, H, N, P]: batch, heads over 'model' when possible.
        - conv [G, B, W-1, C]: batch, channels over 'model'.
        - cross k/v [G, B, n_ctx, KV, hd]: like attn.
        """
        def one(path, leaf):
            name = _path_str(path)
            s = leaf.shape
            B = s[1]
            ba = self._batch_axis(B)
            used = set(ba or ())
            if name.endswith("/k") or name.endswith("/v"):
                # sequence shards over whatever the batch left unused
                # (mirrors logical rule "seq": (model, data))
                seq = []
                prod = 1
                data = "data" if "data" in self.axis_sizes else None
                for a in (self.tp, data):
                    if a and a not in used and \
                            s[2] % (prod * self.axis_sizes[a]) == 0:
                        seq.append(a)
                        prod *= self.axis_sizes[a]
                seq_axis = tuple(seq) if len(seq) > 1 else \
                    (seq[0] if seq else None)
                return NamedSharding(self.mesh, P(
                    None, ba, seq_axis, None, None))
            if name.endswith("ssm"):
                return NamedSharding(self.mesh, P(
                    None, ba, self._axis(s[2], self.tp), None, None))
            if name.endswith("conv"):
                return NamedSharding(self.mesh, P(
                    None, ba, None, self._axis(s[3], self.tp)))
            return NamedSharding(self.mesh,
                                 P(*((None, ba) + (None,) * (leaf.ndim - 2))))

        leaves, treedef = tree_flatten_with_path(cache_shapes)
        return tree_unflatten(treedef, [one(p, l) for p, l in leaves])

    def replicated(self):
        return NamedSharding(self.mesh, P())
