"""Accuracy benchmarks — paper §4 (Figs 6, 7, 8, 9, 10 and Table 1).

Datasets are synthetic Gaussian-cluster image tasks (no CIFAR offline); three
noise levels play the role of the paper's easy/medium/hard dataset spread.
Rows print as ``name,value,derived`` CSV.

``--smoke --json PATH`` runs the small deterministic A_d scheme-ranking set
(``bench_ci_smoke``) and merges its ``acc_*`` metrics into the same JSON
document the latency / kernel lanes write, so ``regression_check.py`` can
render the cross-scheme ranking table into the CI step summary.  The
metrics are informational (see the baseline's ``gate`` map): accuracy at
smoke scale moves with training noise, so the gate reports rather than
fails on it.
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.codes import vandermonde
from repro.core.metrics import (degraded_accuracy, iou, overall_accuracy,
                                topk_accuracy)
from repro.core.parity import train_parity_models
from repro.data.pipeline import batched, cluster_images
from repro.models.cnn import build
from repro.training.loss import softmax_xent
from repro.training.optim import AdamConfig, adam_init, adam_update

IMG = (16, 16, 1)
N_CLASSES = 10


def _train_deployed(noise, seed=0, kind="mlp", epochs=3, n=3000):
    x, y, tmpl = cluster_images(n, noise=noise, seed=seed, image_shape=IMG,
                                n_classes=N_CLASSES)
    xt, yt, _ = cluster_images(800, noise=noise, seed=seed + 1,
                               templates=tmpl, image_shape=IMG,
                               n_classes=N_CLASSES)
    params, fwd = build(kind, jax.random.PRNGKey(seed), image_shape=IMG,
                        n_out=N_CLASSES)
    opt = AdamConfig(lr=1e-3)
    st = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        l, g = jax.value_and_grad(lambda p: softmax_xent(fwd(p, xb), yb))(p)
        p, s = adam_update(g, s, p, opt)
        return p, s, l

    for xb, yb in batched(x, y, 64, epochs=epochs):
        params, st, _ = step(params, st, xb, yb)
    return params, fwd, (x, y, xt, yt)


def _eval_parm(params, fwd, data, k, scheme="sum", epochs=5, seed=0):
    x, y, xt, yt = data
    pp, scheme = train_parity_models(
        params, fwd, lambda kk: build(
            "mlp", kk, image_shape=IMG, n_out=N_CLASSES)[0],
        x, k=k, scheme=scheme, epochs=epochs, seed=seed)
    a_a = topk_accuracy(np.asarray(fwd(params, jnp.asarray(xt))), yt)
    rng = np.random.default_rng(seed + 2)
    n = (len(xt) // k) * k
    order = rng.permutation(len(xt))[:n]
    groups = xt[order].reshape(-1, k, *IMG)
    glabels = yt[order].reshape(-1, k)
    member = np.asarray(fwd(params, jnp.asarray(
        groups.reshape(n, *IMG)))).reshape(-1, k, N_CLASSES)
    pq = np.asarray(scheme.encode(jnp.asarray(np.moveaxis(groups, 1, 0))))[0]
    parity_out = np.asarray(fwd(pp[0], jnp.asarray(pq)))[:, None]
    a_d = degraded_accuracy(parity_out, member, glabels, scheme)
    return a_a, a_d


def bench_table1_toy():
    """Table 1: the addition code is exact for linear F, broken for F=X^2."""
    rng = np.random.default_rng(0)
    x1, x2 = rng.normal(size=(2, 100))
    p = x1 + x2
    lin_err = np.abs(2 * p - (2 * x1 + 2 * x2)).max()
    sq_err = np.abs(p ** 2 - (x1 ** 2 + x2 ** 2)).mean()
    print(f"table1_linear_decode_error,{lin_err:.2e},exact")
    print(f"table1_square_decode_error,{sq_err:.3f},nonlinear_breaks_code")


def bench_fig6_degraded_accuracy():
    """A_a vs A_d vs default across 'datasets' (noise levels) at k=2."""
    for name, noise in [("easy", 1.0), ("medium", 2.0), ("hard", 3.0)]:
        params, fwd, data = _train_deployed(noise)
        a_a, a_d = _eval_parm(params, fwd, data, k=2)
        print(f"fig6_{name}_available_Aa,{a_a:.3f},")
        print(f"fig6_{name}_parm_degraded_Ad,{a_d:.3f},"
              f"default={1/N_CLASSES:.2f}")


def bench_fig7_overall_accuracy():
    params, fwd, data = _train_deployed(2.0)
    for k in (2, 3, 4):
        a_a, a_d = _eval_parm(params, fwd, data, k=k)
        for f_u in (0.01, 0.05, 0.1):
            a_o = overall_accuracy(a_a, a_d, f_u)
            a_def = overall_accuracy(a_a, 1 / N_CLASSES, f_u)
            print(f"fig7_k{k}_fu{f_u},{a_o:.4f},default={a_def:.4f}")


def bench_fig8_localization():
    """Object localization (regression): predict a box around the bright
    blob; report mean IoU of deployed vs ParM-reconstructed predictions."""
    n = 3000
    H = 16

    def gen(n, seed):
        r = np.random.default_rng(seed)
        cx, cy = r.integers(3, H - 3, (2, n))
        w = r.integers(3, 6, n)
        x = np.zeros((n, H, H, 1), np.float32)
        for i in range(n):
            x[i, cy[i] - w[i] // 2:cy[i] + w[i] // 2 + 1,
              cx[i] - w[i] // 2:cx[i] + w[i] // 2 + 1, 0] = 1.0
        x += r.normal(0, 0.15, x.shape).astype(np.float32)
        boxes = np.stack([cx - w / 2, cy - w / 2, cx + w / 2, cy + w / 2],
                         -1).astype(np.float32)
        return x, boxes

    x, b = gen(n, 0)
    xt, bt = gen(500, 1)
    params, _ = build("mlp", jax.random.PRNGKey(0), image_shape=(H, H, 1),
                      n_out=4)
    from repro.models.cnn import mlp_fwd as fwd
    opt = AdamConfig(lr=1e-3)
    st = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        l, g = jax.value_and_grad(
            lambda p: jnp.mean((fwd(p, xb) - yb) ** 2))(p)
        p, s = adam_update(g, s, p, opt)
        return p, s, l

    for ep in range(20):
        for i in range(0, n - 64, 64):
            params, st, _ = step(params, st, x[i:i + 64], b[i:i + 64])
    dep_iou = iou(np.asarray(fwd(params, jnp.asarray(xt))), bt).mean()

    k = 2
    pp, scheme = train_parity_models(
        params, fwd, lambda kk: build("mlp", kk, image_shape=(H, H, 1),
                                      n_out=4)[0],
        x, k=k, epochs=15, seed=0)
    ng = (len(xt) // k) * k
    groups = xt[:ng].reshape(-1, k, H, H, 1)
    gb = bt[:ng].reshape(-1, k, 4)
    member = np.asarray(fwd(params, jnp.asarray(
        groups.reshape(ng, H, H, 1)))).reshape(-1, k, 4)
    pq = groups.sum(1)
    pout = np.asarray(fwd(pp[0], jnp.asarray(pq)))
    recon_ious = []
    for j in range(k):
        rec = np.asarray(jax.vmap(
            lambda po, mo: scheme.decode_one(po, mo, j))(jnp.asarray(pout),
                                                         jnp.asarray(member)))
        recon_ious.append(iou(rec, gb[:, j]).mean())
    print(f"fig8_deployed_mean_iou,{dep_iou:.3f},")
    print(f"fig8_parm_reconstructed_iou,{np.mean(recon_ious):.3f},"
          "paper:0.945_vs_0.674")


def bench_fig9_vary_k():
    params, fwd, data = _train_deployed(2.0)
    for k in (2, 3, 4):
        a_a, a_d = _eval_parm(params, fwd, data, k=k)
        print(f"fig9_k{k}_Ad,{a_d:.3f},Aa={a_a:.3f}")


def bench_fig10_task_specific_encoder():
    params, fwd, data = _train_deployed(2.0)
    for k in (2, 4):
        _, a_d_sum = _eval_parm(params, fwd, data, k=k, scheme="sum")
        _, a_d_cat = _eval_parm(params, fwd, data, k=k, scheme="concat")
        print(f"fig10_k{k}_addition_Ad,{a_d_sum:.3f},")
        print(f"fig10_k{k}_concat_Ad,{a_d_cat:.3f},"
              "NOTE:synthetic_gaussian_task_is_near-linear_so_addition_wins;"
              "paper's_CIFAR_images_favor_concat")


def bench_r2_concurrent_failures():
    """§3.5: r=2 parity models tolerate two concurrent unavailabilities."""
    params, fwd, data = _train_deployed(1.5)
    x, y, xt, yt = data
    k, r = 2, 2
    pp, scheme = train_parity_models(
        params, fwd, lambda kk: build("mlp", kk, image_shape=IMG,
                                      n_out=N_CLASSES)[0],
        x, k=k, r=r, epochs=5, seed=0)
    n = (len(xt) // k) * k
    groups = xt[:n].reshape(-1, k, *IMG)
    glabels = yt[:n].reshape(-1, k)
    C = vandermonde(k, r)
    member = np.asarray(fwd(params, jnp.asarray(
        groups.reshape(n, *IMG)))).reshape(-1, k, N_CLASSES)
    pouts = []
    for j in range(r):
        pq = np.einsum("k,gk...->g...", C[j], groups)
        pouts.append(np.asarray(fwd(pp[j], jnp.asarray(pq))))
    pouts = np.stack(pouts, 1)                      # [G, r, V]
    # both members missing -> decode from the two parity outputs alone
    mask = jnp.asarray(np.ones(k, bool))
    recon = np.asarray(jax.vmap(
        lambda po, mo: scheme.decode(po, mo, mask))(jnp.asarray(pouts),
                                                    jnp.asarray(member * 0)))
    hits = (np.argmax(recon, -1) == glabels).mean()
    print(f"r2_both_missing_Ad,{hits:.3f},default={1/N_CLASSES:.2f}")


def bench_unavailability_schemes():
    """Accuracy under unavailability across the scheme registry on the
    resnet18_cifar family (ROADMAP learned-codes item): sum / concat /
    learned / approx_backup, one unavailable query per coding group.  The
    learned code starts AT the sum code (zero-init residual) and is trained
    jointly with its parity model, so it must report A_d >= sum's."""
    from repro.eval.unavailability import accuracy_under_unavailability
    res = accuracy_under_unavailability(
        n_train=3000, n_test=400, noise=0.8, deployed_epochs=4,
        parity_epochs=6, seed=0)
    print(f"resnet18_unavail_available_Aa,{res['A_a']:.3f},")
    for name, a_d in res["schemes"].items():
        print(f"resnet18_unavail_{name}_Ad,{a_d:.3f},")
    gain = res["schemes"]["learned"] - res["schemes"]["sum"]
    print(f"resnet18_unavail_learned_minus_sum,{gain:+.3f},"
          f"learned_ge_sum={res['schemes']['learned'] >= res['schemes']['sum']}")
    apx = res["schemes"]["approxifer"] - res["schemes"]["sum"]
    print(f"resnet18_unavail_approxifer_minus_sum,{apx:+.3f},"
          f"no_parity_training")


def bench_error_rate_sweep():
    """Byzantine robustness: accuracy of the served predictions as the
    per-response error rate grows, sum (serves the garbage) vs approxifer
    (votes it out with r=2 surplus responses and re-decodes)."""
    from repro.eval.unavailability import accuracy_under_errors
    res = accuracy_under_errors(
        schemes=("sum", "approxifer"), error_rates=(0.0, 0.1, 0.25),
        n_train=1500, n_test=400, noise=0.8, k=2, r=2,
        deployed_epochs=3, parity_epochs=4, seed=0)
    for name, per_rate in res["schemes"].items():
        for rate, acc in per_rate.items():
            print(f"resnet18_errors_{name}_rate{rate:g},{acc:.3f},")
    gap = res["schemes"]["approxifer"][0.25] - res["schemes"]["sum"][0.25]
    print(f"resnet18_errors_gap_at_25pct,{gap:+.3f},approxifer_minus_sum")


def bench_ci_smoke():
    """The A_d scheme-ranking smoke set the CI bench lane publishes: one
    shared deployed model, every registered scheme provisioned through
    ``train_parity_models`` and scored under one unavailable member per
    coding group (``repro.eval.unavailability``).  Returns ``acc_*``
    metrics: available accuracy plus per-scheme degraded accuracy."""
    from repro.eval.unavailability import accuracy_under_unavailability
    res = accuracy_under_unavailability(
        n_train=2000, n_test=400, noise=0.8, deployed_epochs=5,
        parity_epochs=5, seed=0)
    out = {"acc_unavail_Aa": round(float(res["A_a"]), 4)}
    for name, a_d in res["schemes"].items():
        out[f"acc_unavail_{name}_Ad"] = round(float(a_d), 4)
    return out


ALL = [bench_table1_toy, bench_fig6_degraded_accuracy,
       bench_fig7_overall_accuracy, bench_fig8_localization,
       bench_fig9_vary_k, bench_fig10_task_specific_encoder,
       bench_r2_concurrent_failures, bench_unavailability_schemes,
       bench_error_rate_sweep]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the deterministic A_d scheme-ranking smoke "
                         "set only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write (or merge into) a metrics JSON document "
                         "(with --smoke); merging preserves an existing "
                         "BENCH_ci.json written by the latency / kernel "
                         "lanes")
    args = ap.parse_args()
    if args.json and not args.smoke:
        ap.error("--json records the smoke metric set; pass --smoke too")
    if args.smoke:
        metrics = bench_ci_smoke()
        for name in sorted(metrics):
            print(f"{name},{metrics[name]},")
        if args.json:
            doc = {"metrics": {}}
            try:
                with open(args.json) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError):
                pass
            if not isinstance(doc.get("metrics"), dict):
                doc["metrics"] = {}
            doc["metrics"].update(metrics)
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            print(f"# merged {len(metrics)} accuracy metrics into "
                  f"{args.json}")
        return
    for fn in ALL:
        fn()


if __name__ == "__main__":
    main()
