"""Tail-latency benchmarks — paper §5 (Figs 11-15) via the discrete-event
simulator, plus §5.2.5 encoder/decoder microbenchmarks on real arrays.

Also runnable standalone (the CI bench-regression gate uses this)::

    PYTHONPATH=src python -m benchmarks.latency --smoke --json BENCH_ci.json
    PYTHONPATH=src python -m benchmarks.latency --scheme learned

``--smoke`` runs the small deterministic DES set gated by
``benchmarks/regression_check.py`` against ``benchmarks/BENCH_baseline.json``
(the DES is driven by seeded numpy RNGs, so smoke metrics are bit-stable
across machines — the gate trips on code changes, not on CI noise).
``--scheme`` narrows the scheme-sweep bench to one registered coding scheme.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.serving.simulator import SimConfig, simulate

NQ = 100_000


def _row(tag, r, extra=""):
    gap = r["p999_ms"] - r["median_ms"]
    print(f"{tag}_median_ms,{r['median_ms']:.2f},{extra}")
    print(f"{tag}_p99.9_ms,{r['p999_ms']:.2f},gap={gap:.2f}")


def bench_fig11_latency_vs_qps():
    """GPU cluster (m=12, 25 ms service) and CPU cluster (m=24, 12 ms)."""
    for cluster, m, svc, rates in [("gpu", 12, 25.0, (200, 270, 330)),
                                   ("cpu", 24, 12.0, (400, 540, 660))]:
        for qps in rates:
            cfg = SimConfig(n_queries=NQ, qps=qps, m=m, k=2, seed=1,
                            service_ms=svc)
            parm = simulate(cfg, "parm")
            er = simulate(cfg, "equal_resources")
            _row(f"fig11_{cluster}_q{qps}_parm", parm)
            _row(f"fig11_{cluster}_q{qps}_eqres", er)
            red = 1 - parm["p999_ms"] / er["p999_ms"]
            gapx = (er["p999_ms"] - er["median_ms"]) / max(
                parm["p999_ms"] - parm["median_ms"], 1e-9)
            print(f"fig11_{cluster}_q{qps}_p999_reduction,{red:.2%},"
                  f"gap_closer_x={gapx:.2f}")


def bench_fig12_vary_k():
    for k in (2, 3, 4):
        cfg = SimConfig(n_queries=NQ, qps=270, m=12, k=k, seed=1)
        parm = simulate(cfg, "parm")
        _row(f"fig12_k{k}_parm", parm, extra=f"redundancy={1/k:.0%}")
    er = simulate(SimConfig(n_queries=NQ, qps=270, m=12, k=2, seed=1),
                  "equal_resources")
    _row("fig12_eqres33pct", er)


def bench_fig13_network_imbalance():
    for ns in (2, 3, 4, 5):
        cfg = SimConfig(n_queries=NQ, qps=270, m=12, k=2, seed=1,
                        n_shuffles=ns)
        parm = simulate(cfg, "parm")
        er = simulate(cfg, "equal_resources")
        gapx = (er["p999_ms"] - er["median_ms"]) / max(
            parm["p999_ms"] - parm["median_ms"], 1e-9)
        print(f"fig13_shuffles{ns}_gap_closer_x,{gapx:.2f},"
              f"parm_p999={parm['p999_ms']:.1f} er_p999={er['p999_ms']:.1f}")


def bench_fig14_light_multitenancy():
    """No network imbalance; light background inference load instead."""
    for qps in (200, 240, 270):
        cfg = SimConfig(n_queries=NQ, qps=qps, m=12, k=2, seed=1,
                        n_shuffles=2, shuffle_delay_ms=(5.0, 15.0))
        parm = simulate(cfg, "parm")
        er = simulate(cfg, "equal_resources")
        gapx = (er["p999_ms"] - er["median_ms"]) / max(
            parm["p999_ms"] - parm["median_ms"], 1e-9)
        print(f"fig14_q{qps}_gap_closer_x,{gapx:.2f},light_load")


def bench_fig15_approx_backup():
    """Approximate-backup baseline destabilises as qps grows (§5.2.6)."""
    for qps in (200, 270, 300, 330):
        cfg = SimConfig(n_queries=NQ, qps=qps, m=12, k=2, seed=1)
        parm = simulate(cfg, "parm")
        ab = simulate(cfg, "approx_backup")
        print(f"fig15_q{qps}_parm_p999,{parm['p999_ms']:.1f},")
        print(f"fig15_q{qps}_approx_backup_p999,{ab['p999_ms']:.1f},"
              f"speedup=1.15x_insufficient")


def bench_sec525_encode_decode_latency():
    """Encoder/decoder wall time on this container (paper: 93-193 us encode,
    8-19 us decode on a c5.9xlarge frontend)."""
    from repro.core.scheme import get_scheme
    for k in (2, 3, 4):
        scheme = get_scheme("sum", k=k, r=1)
        # Cat-v-Dog-scale query: 224x224x3 image
        q = jnp.ones((k, 1, 224, 224, 3))
        outs = jnp.ones((k, 1, 1000))                 # 1000-class predictions
        e = jax.jit(lambda x: scheme.encode(x))
        d = jax.jit(lambda p, o: scheme.decode_one(p, o, 0))
        e(q).block_until_ready()
        d(outs[0], outs).block_until_ready()
        for name, fn, args, iters in [("encode", e, (q,), 100),
                                      ("decode", d, (outs[0], outs), 200)]:
            t0 = time.perf_counter()
            for _ in range(iters):
                fn(*args).block_until_ready()
            us = (time.perf_counter() - t0) / iters * 1e6
            print(f"sec525_{name}_k{k}_us,{us:.0f},"
                  f"paper_{name}~{'93-193' if name == 'encode' else '8-19'}us")


def bench_batching():
    """§5.2.3: ParM holds its advantage at batch sizes 2 and 4."""
    for b, qps in [(1, 300), (2, 460), (4, 584)]:
        cfg = SimConfig(n_queries=NQ // 2, qps=qps / b, m=12, k=2, seed=1,
                        batch_size=b)
        parm = simulate(cfg, "parm")
        er = simulate(cfg, "equal_resources")
        red = 1 - parm["p999_ms"] / er["p999_ms"]
        print(f"fig_batch{b}_p999_reduction,{red:.2%},qps={qps}")


def bench_adaptive_batching():
    """Clipper-style adaptive batching (DeploymentSpec.batching) through the
    DES's per-batch service-time curve: above the unbatched capacity knee
    (m=12 at 25 ms serves ~480 qps), larger max_size keeps the deployment
    stable; redundant-work cancellation rides along, tombstoning queued
    originals/parities the decode already answered."""
    for b in (1, 2, 4, 8):
        cfg = SimConfig(n_queries=NQ // 2, qps=520, m=12, k=2, seed=1,
                        batch_max_size=b)
        res = simulate(cfg, "parm")
        print(f"adaptive_batch{b}_p999_ms,{res['p999_ms']:.2f},"
              f"mean_batch={res['mean_batch_size']:.2f} "
              f"cancelled={res.cancellations}")


def bench_r2_multi_straggler():
    """§3.5: r=2 Vandermonde tolerates two concurrent unavailabilities per
    group. Under correlated whole-pool slowdowns (where groups regularly
    lose several members at once) the second parity model keeps closing the
    tail that r=1 cannot."""
    for r in (1, 2):
        cfg = SimConfig(n_queries=NQ // 2, qps=270, m=12, k=2, r=r, seed=1)
        res = simulate(cfg, "parm", scenario="correlated_slowdown")
        _row(f"fig_r{r}_correlated_parm", res,
             extra=f"recon={res['reconstructions']}")


def bench_scenarios():
    """Every registered fault scenario, parm vs unprotected: crash/restart,
    correlated slowdowns, bursty MMPP arrivals, heterogeneous hardware."""
    from repro.serving.scenarios import available_scenarios
    for scen in available_scenarios():
        cfg = SimConfig(n_queries=NQ // 2, qps=270, m=12, k=2, seed=1)
        parm = simulate(cfg, "parm", scenario=scen)
        none = simulate(cfg, "none", scenario=scen)
        red = 1 - parm["p999_ms"] / none["p999_ms"]
        print(f"scenario_{scen}_p999_reduction,{red:.2%},"
              f"parm={parm['p999_ms']:.1f} none={none['p999_ms']:.1f}")


def bench_scheme_tails(schemes=None):
    """Every registered coding scheme through the SAME coded serving path:
    the registry sweep the plugin API exists for.  ``sum`` and ``learned``
    share decode semantics (identical tails — the learned encoder buys
    accuracy, not latency); ``replication`` pays r=k parity pools;
    ``approx_backup`` is the §5.2.6 baseline as a k=1 scheme."""
    from repro.core.scheme import available_schemes
    for scheme in (schemes or available_schemes()):
        cfg = SimConfig(n_queries=NQ // 2, qps=270, m=12, k=2, seed=1)
        strat = "approx_backup" if scheme == "approx_backup" else "parm"
        res = simulate(cfg, strat, scheme=scheme)
        _row(f"scheme_{scheme}", res, extra=f"recon={res['reconstructions']}")


def bench_frontier_utilization(n_queries=None, utils=(0.55, 0.70, 0.85),
                               schemes=("sum", "replication", "approxifer")):
    """p999-vs-utilization frontier per coding scheme (the million-query
    study the vectorized DES hot path exists for).  Utilization is offered
    load over unbatched main-pool capacity (m servers at ``service_ms``
    each); each (scheme, utilization) point is one seeded run, so the
    frontier ordering — how each code's tail grows as the deployment runs
    hotter — is bit-stable.  Default size is the module-level NQ; the
    ``--frontier`` CLI flag runs it at 10M queries per point."""
    n = NQ if n_queries is None else n_queries
    m, svc = 12, 25.0
    capacity = m * 1000.0 / svc                 # 480 qps unbatched
    for scheme in schemes:
        for util in utils:
            cfg = SimConfig(n_queries=n, qps=util * capacity, m=m, k=2,
                            seed=1, service_ms=svc)
            t0 = time.perf_counter()
            res = simulate(cfg, "parm", scheme=scheme)
            wall = time.perf_counter() - t0
            print(f"frontier_{scheme}_u{int(util * 100)}_p999_ms,"
                  f"{res['p999_ms']:.3f},"
                  f"median={res['median_ms']:.3f} "
                  f"recon={res['reconstructions']} "
                  f"eps={res['events'] / wall / 1e6:.2f}M "
                  f"wall={wall:.1f}s n={n}")


def bench_adaptive_controller():
    """Closed-loop adaptive redundancy: a ``threshold`` controller watching
    live ``ReportWindow`` signals escalates sum/r=1 to approxifer/r=2 (plus
    batching) for the duration of a fault episode, then settles back.  On
    episodic scenarios it beats every static (scheme, r) point on the
    p999-vs-parity-resource frontier: lower tail than static r=1 AND fewer
    parity queries served than static r=2."""
    for scen in ("bursty", "storm"):
        grid = {}
        for tag, scheme, r, ctl in (("adaptive", None, 1, "threshold"),
                                    ("static_sum_r1", None, 1, None),
                                    ("static_sum_r2", "sum", 2, None),
                                    ("static_apx_r2", "approxifer", 2, None)):
            res = simulate(SimConfig(n_queries=SMOKE_NQ, qps=270, m=12, k=2,
                                     r=r, seed=1),
                           "parm", scheme=scheme, scenario=scen,
                           controller=ctl)
            grid[tag] = res
            print(f"ctl_{scen}_{tag}_p999_ms,{res['p999_ms']:.2f},"
                  f"parity_served={res.parity_served} "
                  f"adjustments={len(res.adjustments)}")
        adp = grid["adaptive"]
        dominated = all(adp["p999_ms"] < grid[t]["p999_ms"]
                        for t in grid if t != "adaptive")
        frugal = adp.parity_served < grid["static_sum_r2"].parity_served
        print(f"ctl_{scen}_frontier_dominant,"
              f"{dominated and frugal},"
              f"tail_beats_all_statics={dominated} "
              f"cheaper_than_r2={frugal}")


SMOKE_NQ = 8000      # smoke-set size; recorded in the JSON the gate reads


def bench_ci_smoke():
    """The CI bench-regression set: a small, fully deterministic DES sweep
    (seeded numpy RNG — bit-stable across machines).  Returns
    ``{metric_name: value}``; ``*_ms`` metrics are gated against
    ``benchmarks/BENCH_baseline.json`` by ``benchmarks/regression_check.py``
    (>25% regression fails CI)."""
    out = {}

    def put(tag, res):
        out[f"{tag}_median_ms"] = round(res["median_ms"], 3)
        out[f"{tag}_p999_ms"] = round(res["p999_ms"], 3)
        out[f"{tag}_reconstructions"] = res["reconstructions"]
        out[f"{tag}_cancellations"] = res.cancellations

    n = SMOKE_NQ
    for strat in ("parm", "equal_resources", "replication", "none"):
        put(f"smoke_{strat}",
            simulate(SimConfig(n_queries=n, qps=270, m=12, k=2, seed=1),
                     strat))
    from repro.core.scheme import available_schemes
    for scheme in available_schemes():
        strat = "approx_backup" if scheme == "approx_backup" else "parm"
        put(f"smoke_scheme_{scheme}",
            simulate(SimConfig(n_queries=n, qps=270, m=12, k=2, seed=1),
                     strat, scheme=scheme))
    for r in (1, 2):
        put(f"smoke_r{r}_correlated",
            simulate(SimConfig(n_queries=n, qps=270, m=12, k=2, r=r, seed=1),
                     "parm", scenario="correlated_slowdown"))
    # adaptive-batching sweep above the unbatched capacity knee: the gated
    # p999 metrics document that max_size > 1 stabilizes the overloaded
    # deployment (smoke_batch4 well under smoke_batch1)
    for b in (1, 2, 4):
        put(f"smoke_batch{b}",
            simulate(SimConfig(n_queries=n, qps=520, m=12, k=2, seed=1,
                               batch_max_size=b), "parm"))
    # Byzantine fault class (scenario="byzantine", r=2 so the detecting
    # scheme holds voting surplus): the gate's first cross-scheme accuracy
    # AND latency trend — the detected/corrected counters are the accuracy
    # side (informational, seeded-deterministic), the *_ms metrics the
    # latency side; sum runs the same hazards without detection
    for scheme in ("approxifer", "sum"):
        res = simulate(SimConfig(n_queries=n, qps=270, m=12, k=2, r=2,
                                 seed=1),
                       "parm", scheme=scheme, scenario="byzantine")
        put(f"smoke_byzantine_{scheme}", res)
        out[f"smoke_byzantine_{scheme}_corrupted_detected"] = \
            res["corrupted_detected"]
        out[f"smoke_byzantine_{scheme}_corrected"] = res["corrected"]
    # adaptive-redundancy controller vs the static frontier (the gated
    # *_ms pair locks the dominance ordering: adaptive p999 must stay
    # under the static r=1 p999 on both episodic scenarios; parity_served
    # counters are the resource side, informational)
    for scen in ("bursty", "storm"):
        for tag, ctl in (("adaptive", "threshold"), ("static_r1", None)):
            res = simulate(SimConfig(n_queries=n, qps=270, m=12, k=2,
                                     seed=1),
                           "parm", scenario=scen, controller=ctl)
            put(f"smoke_{tag}_{scen}", res)
            out[f"smoke_{tag}_{scen}_parity_served"] = res.parity_served
            if ctl is not None:
                out[f"smoke_{tag}_{scen}_adjustments"] = \
                    len(res.adjustments)
    # trace-driven / multi-tenant workload smoke (DESIGN.md §11): the two
    # new arrival-process scenarios plus a weighted-fair two-tenant run
    # with per-class SLOs; *_ms rows gate the arrival-process semantics,
    # the violation counters are the informational accuracy side
    for scen in ("diurnal", "flash_crowd"):
        put(f"smoke_{scen}",
            simulate(SimConfig(n_queries=n, qps=270, m=12, k=2, seed=1),
                     "parm", scenario=scen))
    from repro.serving.scenarios import TenantClass
    res = simulate(SimConfig(n_queries=n, qps=270, m=12, k=2, seed=1,
                             tenants=(TenantClass("gold", share=0.3,
                                                  weight=4.0, slo_ms=60.0),
                                      TenantClass("free", share=0.7,
                                                  weight=1.0))),
                   "parm")
    put("smoke_tenants", res)
    for tname, tstats in sorted(res.per_tenant.items()):
        out[f"smoke_tenants_{tname}_p999_ms"] = round(
            tstats["p999_ms"], 3)
        out[f"smoke_tenants_{tname}_slo_violations"] = \
            tstats["slo_violations"]
    # utilization frontier at smoke scale: same (scheme, utilization) grid
    # as bench_frontier_utilization, gating the frontier ORDERING cheaply
    capacity = 12 * 1000.0 / 25.0
    for scheme in ("sum", "replication", "approxifer"):
        for util in (55, 70, 85):
            put(f"smoke_frontier_{scheme}_u{util}",
                simulate(SimConfig(n_queries=n, qps=util / 100.0 * capacity,
                                   m=12, k=2, seed=1),
                         "parm", scheme=scheme))
    # coded LM serving (serving/generation.py, DESIGN.md §13): token-level
    # DES for a big config, service time calibrated from launch/roofline.py
    # (decode_token_cost), below the capacity knee so the coded and uncoded
    # medians match.  The gated ratios lock the acceptance criterion: coded
    # generation's inter-token p999 beats uncoded equal-resources at the
    # same median, under both episodic straggler scenarios.
    from repro.configs.base import get_config
    from repro.serving.generation import GenerationSpec, deploy_lm
    lm_cfg = get_config("qwen3-moe-235b-a22b")
    for scen in ("bursty", "storm"):
        lm = GenerationSpec(cfg=lm_cfg, k=4, r=1, m=12, utilization=0.3,
                            kv_len=4096, tp=8, scenario=scen)
        coded = deploy_lm(lm, engine="sim").replay(n_tokens=n, seed=1)
        uncoded = deploy_lm(lm.replace(strategy="equal_resources"),
                            engine="sim").replay(n_tokens=n, seed=1)
        out[f"smoke_lm_{scen}_coded_p50_ms"] = round(
            coded.inter_token_p50_ms, 3)
        out[f"smoke_lm_{scen}_coded_p999_ms"] = round(
            coded.inter_token_p999_ms, 3)
        out[f"smoke_lm_{scen}_uncoded_p999_ms"] = round(
            uncoded.inter_token_p999_ms, 3)
        out[f"smoke_lm_{scen}_tokens_per_s"] = round(coded.tokens_per_s, 1)
        out[f"smoke_lm_{scen}_reconstructed_steps"] = \
            coded.reconstructed_steps
        out[f"smoke_lm_{scen}_p999_ratio"] = round(
            coded.inter_token_p999_ms / uncoded.inter_token_p999_ms, 4)
        out[f"smoke_lm_{scen}_median_ratio"] = round(
            coded.inter_token_p50_ms / uncoded.inter_token_p50_ms, 4)
    # the 10M-query acceptance point (ISSUE: seeded sum/r=1 on calm must
    # finish < 30 s): p999 is bit-stable and latency-gated; events/sec is
    # machine-dependent, so regression_check gates it as a LOWER bound
    # (*_eps, --eps-threshold); wall seconds ride along informationally
    cfg10 = SimConfig(n_queries=10_000_000, seed=0)
    t0 = time.perf_counter()
    res10 = simulate(cfg10, "parm", scheme="sum", scenario="calm")
    wall = time.perf_counter() - t0
    out["tenmillion_sum_r1_p999_ms"] = round(res10["p999_ms"], 3)
    out["tenmillion_sum_r1_eps"] = round(res10["events"] / wall, 0)
    out["tenmillion_sum_r1_wall_s"] = round(wall, 2)
    for name, value in sorted(out.items()):
        print(f"{name},{value},ci_smoke")
    return out


ALL = [bench_fig11_latency_vs_qps, bench_fig12_vary_k,
       bench_fig13_network_imbalance, bench_fig14_light_multitenancy,
       bench_fig15_approx_backup, bench_sec525_encode_decode_latency,
       bench_batching, bench_adaptive_batching, bench_r2_multi_straggler,
       bench_scenarios, bench_scheme_tails, bench_frontier_utilization,
       bench_adaptive_controller]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the deterministic CI smoke set only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write smoke metrics as JSON (with --smoke)")
    ap.add_argument("--scheme", default=None,
                    help="run the scheme-sweep bench for one registered "
                         "coding scheme (e.g. learned)")
    ap.add_argument("--frontier", action="store_true",
                    help="run the full 10M-query p999-vs-utilization "
                         "frontier study (minutes; the default bench set "
                         "runs the same grid at NQ)")
    args = ap.parse_args()
    if args.json and not args.smoke:
        ap.error("--json records the smoke metric set; pass --smoke too")
    if args.smoke and args.scheme:
        ap.error("--smoke always sweeps every registered scheme; "
                 "drop --scheme")
    if args.frontier:
        bench_frontier_utilization(n_queries=10_000_000)
        return
    if args.smoke:
        metrics = bench_ci_smoke()
        if args.json:
            doc = {"n_queries": SMOKE_NQ, "metrics": metrics}
            try:
                # a baseline refresh must not wipe the hand-maintained
                # per-metric "gate" map (see regression_check.py)
                with open(args.json) as f:
                    prev = json.load(f)
                if isinstance(prev, dict) and "gate" in prev:
                    doc["gate"] = prev["gate"]
            except (OSError, json.JSONDecodeError):
                pass
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            print(f"# wrote {len(metrics)} metrics to {args.json}")
        return
    if args.scheme:
        bench_scheme_tails(schemes=[args.scheme])
        return
    for fn in ALL:
        fn()


if __name__ == "__main__":
    main()
