"""Roofline table (deliverable g): reads the dry-run JSON artifacts and
prints per-(arch x shape x mesh) roofline terms. Source of EXPERIMENTS.md
§Roofline."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def rows(mesh="pod", dryrun_dir=None):
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir or DRYRUN_DIR,
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def bench_roofline_table():
    rs = rows("pod")
    if not rs:
        print("roofline_table,SKIPPED,run repro.launch.dryrun --all first")
        return
    print("# arch,shape,compute_ms,memory_ms,collective_ms,dominant,"
          "useful_flops_ratio,temp_GiB_per_chip")
    for r in rs:
        ro = r["roofline"]
        print(f"roofline_{r['arch']}_{r['shape']},"
              f"{ro['compute_s']*1e3:.3f},"
              f"mem={ro['memory_s']*1e3:.3f}ms "
              f"coll={ro['collective_s']*1e3:.3f}ms "
              f"dom={ro['dominant']} "
              f"useful={r['useful_flops_ratio']:.3f} "
              f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB")


def bench_roofline_table_optimized():
    d = os.path.join(os.path.dirname(__file__), "..",
                     "experiments", "dryrun_opt")
    rs = rows("pod", d)
    if not rs:
        print("roofline_opt,SKIPPED,run dryrun --all --out "
              "experiments/dryrun_opt")
        return
    for r in rs:
        ro = r["roofline"]
        print(f"roofline_opt_{r['arch']}_{r['shape']},"
              f"{ro['compute_s']*1e3:.3f},"
              f"mem={ro['memory_s']*1e3:.3f}ms "
              f"coll={ro['collective_s']*1e3:.3f}ms "
              f"dom={ro['dominant']} "
              f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB")


def bench_multipod_check():
    for tag, d in [("baseline", None),
                   ("optimized", os.path.join(os.path.dirname(__file__),
                                              "..", "experiments",
                                              "dryrun_opt"))]:
        rs = rows("multipod", d)
        print(f"multipod_pairs_compiled_{tag},{len(rs)},of_40")


ALL = [bench_roofline_table, bench_roofline_table_optimized,
       bench_multipod_check]
