"""Microbenchmarks for the Pallas kernel wrappers (interpret mode on CPU —
numbers are correctness-path timings, not TPU performance; TPU perf is
modelled in the roofline table instead).

The fused-hot-path lane (DESIGN.md §12): ``--smoke --json PATH`` emits the
``kernel_*`` metric set the CI bench job gates — fused encode→forward vs the
unfused encode + per-row matmul, the one-launch multigroup decode vs
per-group ``decode_one`` calls, and the scheme-API parity ops on both
backends.  Absolute ``kernel_*_us`` wall-clock timings are machine-dependent
(they gate at a wide per-metric band via the baseline's ``gate`` map);
the ``kernel_*_ratio`` metrics (fused time / unfused time) are
machine-robust and pin fused <= unfused with absolute ``max`` bounds.
When PATH already holds a metrics document (the bench job writes
``BENCH_ci.json`` with ``benchmarks.latency --smoke`` first), the kernel
metrics are merged into it.

``--autotune`` sweeps the fused kernel's ``block_b``/``block_f`` grid
against the ``launch/roofline.py`` prediction and reports the chosen blocks
(also emitted as informational ``kernel_fused_autotune_*`` metrics at smoke
scale).
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=20, warmup=2):
    """Steady-state µs per call.  ``fn`` must be hoisted/jitted ONCE by the
    caller (a fresh lambda per call site re-traces every bench — cold jit
    caches); warmup iterations are separate from the timed ones."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def _fused_inputs(k, r, B, F, V, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (k, B, F), jnp.float32)
    C = jax.random.normal(ks[1], (r, k), jnp.float32)
    W = jax.random.normal(ks[2], (r, F, V), jnp.float32)
    return q, C, W


def _unfused_encode_forward(r):
    """The pre-fusion serving path, hoisted: r per-row Pallas encode
    launches, then the first forward matmul on the materialised parities."""
    from repro.kernels import ops

    def unfused(q, C, W):
        enc = jnp.stack([ops.parity_encode_op(q, C[j]) for j in range(r)])
        return jnp.einsum("rbf,rfv->rbv", enc, W)
    return unfused


def bench_kernel_fused_encode_forward(k=4, B=8, F=512, V=128, iters=20,
                                      out=None, blocks=None):
    """Fused encode→forward (one launch) vs the unfused encode + matmul, at
    r=1 and r=2.  Emits per-variant µs and the fused/unfused ratio."""
    from repro.kernels import ops
    out = {} if out is None else out
    kw = dict(blocks) if blocks else {}
    for r in (1, 2):
        q, C, W = _fused_inputs(k, r, B, F, V)
        fused = functools.partial(ops.fused_encode_forward_op, **kw)
        unfused = _unfused_encode_forward(r)
        fus = _time(fused, q, C, W, iters=iters)
        unf = _time(unfused, q, C, W, iters=iters)
        out[f"kernel_fused_encode_forward_r{r}_us"] = round(fus, 1)
        out[f"kernel_unfused_encode_forward_r{r}_us"] = round(unf, 1)
        out[f"kernel_fused_encode_forward_r{r}_ratio"] = round(fus / unf, 3)
        print(f"kernel_fused_encode_forward_r{r}_us,{fus:.0f},"
              f"unfused={unf:.0f},ratio={fus / unf:.2f},interpret_mode")
    return out


def bench_kernel_multigroup_decode(G=8, k=4, B=4, V=256, iters=20, out=None):
    """One-launch multigroup decode of G recoverable groups vs G per-group
    ``decode_one`` launches, through the scheme API (backend="pallas")."""
    from repro.core.scheme import get_scheme
    import numpy as np
    out = {} if out is None else out
    scheme = get_scheme("sum", k=k, r=1, backend="pallas")
    rng = np.random.default_rng(0)
    po = jnp.asarray(rng.normal(size=(G, B, V)), jnp.float32)
    outs = jnp.asarray(rng.normal(size=(G, k, B, V)), jnp.float32)
    idxs = np.arange(G) % k
    many = scheme.decode_one_many

    def pergroup(po, outs):
        return [scheme.decode_one(po[g], outs[g], int(idxs[g]))
                for g in range(G)]
    mg = _time(many, po, outs, idxs, iters=iters)
    pg = _time(pergroup, po, outs, iters=iters)
    out["kernel_multigroup_decode_us"] = round(mg, 1)
    out["kernel_pergroup_decode_us"] = round(pg, 1)
    out["kernel_multigroup_decode_ratio"] = round(mg / pg, 3)
    print(f"kernel_multigroup_decode_us,{mg:.0f},pergroup={pg:.0f},"
          f"ratio={mg / pg:.2f},interpret_mode")
    return out


def bench_kernel_parity_ops(iters=20, out=None):
    """The parity hot paths through the scheme API, both backends — jnp vs
    the Pallas kernel wrappers (interpret mode here)."""
    from repro.core.scheme import get_scheme
    out = {} if out is None else out
    k = 4
    q = jnp.ones((k, 8, 4096))
    outs = jnp.ones((k, 8, 1000))
    for backend in ("jnp", "pallas"):
        scheme = get_scheme("sum", k=k, r=1, backend=backend)
        encode, decode_one = scheme.encode, scheme.decode_one

        def decode(o):
            return decode_one(o[0], o, 1)
        us = _time(encode, q, iters=iters)
        out[f"kernel_parity_encode_{backend}_us"] = round(us, 1)
        print(f"kernel_parity_encode_{backend}_us,{us:.0f},interpret_mode")
        us = _time(decode, outs, iters=iters)
        out[f"kernel_parity_decode_{backend}_us"] = round(us, 1)
        print(f"kernel_parity_decode_{backend}_us,{us:.0f},interpret_mode")
    return out


def bench_kernel_attention():
    from repro.kernels import ops

    def flash(a, b, c):
        return ops.flash_attention_op(a, b, c)

    def decode(a, b, c):
        return ops.decode_attention_op(a, b, c, 200)
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    us = _time(flash, q, k, v, iters=3, warmup=1)
    print(f"kernel_flash_attention_us,{us:.0f},interpret_mode")
    qd = jax.random.normal(ks[0], (B, H, hd))
    us = _time(decode, qd, k, v, iters=3, warmup=1)
    print(f"kernel_decode_attention_us,{us:.0f},interpret_mode")


def _roofline_pred_us(k, r, B, F, V, dtype_bytes=4):
    """Roofline prediction for one fused encode→forward pass on the modelled
    TPU (launch/roofline.py constants): bytes moved (queries + weights read,
    output written) against HBM bandwidth vs flops (encode muladds + the
    [B,F]x[F,V] matmul per row) against peak — the kernel is memory-bound at
    serving shapes, so the memory term dominates."""
    from repro.launch.roofline import HBM_BW, PEAK_FLOPS
    bytes_moved = (k * B * F + r * F * V + r * B * V) * dtype_bytes
    flops = 2.0 * r * B * F * (k + V)
    return max(bytes_moved / HBM_BW, flops / PEAK_FLOPS) * 1e6


def autotune_fused_blocks(k=4, r=2, B=8, F=1024, V=256, iters=8,
                          candidates_b=(8, 16), candidates_f=(128, 256, 512),
                          verbose=True):
    """Sweep the fused kernel's ``block_b``/``block_f`` grid, timing each
    point against the roofline prediction, and return the fastest blocks as
    ``{"block_b": ..., "block_f": ...}``.  Interpret-mode timings order by
    grid-program count, which is the same knob that orders Mosaic timings on
    a real TPU, so the chosen blocks transfer; the roofline µs is printed
    alongside as the hardware-bound reference."""
    from repro.kernels import ops
    q, C, W = _fused_inputs(k, r, B, F, V)
    pred = _roofline_pred_us(k, r, B, F, V)
    best, best_us = None, float("inf")
    for bb in candidates_b:
        for bf in candidates_f:
            fn = functools.partial(ops.fused_encode_forward_op,
                                   block_b=bb, block_f=bf)
            us = _time(fn, q, C, W, iters=iters, warmup=1)
            if verbose:
                print(f"kernel_fused_autotune_bb{bb}_bf{bf}_us,{us:.0f},"
                      f"roofline_pred_us={pred:.2f}")
            if us < best_us:
                best, best_us = {"block_b": bb, "block_f": bf}, us
    if verbose:
        print(f"kernel_fused_autotune_chosen,block_b={best['block_b']},"
              f"block_f={best['block_f']},us={best_us:.0f}")
    return best


def bench_ci_smoke():
    """The deterministic-shape kernel smoke set the CI bench lane gates.
    Returns the ``kernel_*`` metrics dict (timings are wall-clock — the
    baseline's ``gate`` map gives them a wide band and pins the
    machine-robust fused/unfused ratios instead)."""
    out = {}
    blocks = autotune_fused_blocks(iters=4, verbose=False)
    out["kernel_fused_autotune_block_b"] = blocks["block_b"]
    out["kernel_fused_autotune_block_f"] = blocks["block_f"]
    bench_kernel_fused_encode_forward(out=out, blocks=blocks)
    bench_kernel_multigroup_decode(out=out)
    bench_kernel_parity_ops(out=out)
    return out


ALL = [bench_kernel_parity_ops, bench_kernel_fused_encode_forward,
       bench_kernel_multigroup_decode, bench_kernel_attention]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the deterministic CI kernel smoke set only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write (or merge into) a metrics JSON document "
                         "(with --smoke); merging preserves an existing "
                         "BENCH_ci.json written by benchmarks.latency")
    ap.add_argument("--autotune", action="store_true",
                    help="run the fused-kernel block sweep against the "
                         "roofline prediction and exit")
    args = ap.parse_args()
    if args.json and not args.smoke:
        ap.error("--json records the smoke metric set; pass --smoke too")
    if args.autotune:
        autotune_fused_blocks()
        return
    if args.smoke:
        metrics = bench_ci_smoke()
        if args.json:
            doc = {"metrics": {}}
            try:
                with open(args.json) as f:
                    doc = json.load(f)
            except (OSError, json.JSONDecodeError):
                pass
            if not isinstance(doc.get("metrics"), dict):
                doc["metrics"] = {}
            doc["metrics"].update(metrics)
            with open(args.json, "w") as f:
                json.dump(doc, f, indent=2, sort_keys=True)
            print(f"# merged {len(metrics)} kernel metrics into {args.json}")
        return
    for fn in ALL:
        fn()


if __name__ == "__main__":
    main()
