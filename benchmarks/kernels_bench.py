"""Microbenchmarks for the Pallas kernel wrappers (interpret mode on CPU —
numbers are correctness-path timings, not TPU performance; TPU perf is
modelled in the roofline table instead)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernel_parity_ops():
    """The parity hot paths through the scheme API, both backends — jnp vs
    the Pallas kernel wrappers (interpret mode here)."""
    from repro.core.scheme import get_scheme
    k = 4
    q = jnp.ones((k, 8, 4096))
    outs = jnp.ones((k, 8, 1000))
    for backend in ("jnp", "pallas"):
        scheme = get_scheme("sum", k=k, r=1, backend=backend)
        us = _time(lambda x: scheme.encode(x), q)
        print(f"kernel_parity_encode_{backend}_us,{us:.0f},interpret_mode")
        us = _time(lambda o: scheme.decode_one(o[0], o, 1), outs)
        print(f"kernel_parity_decode_{backend}_us,{us:.0f},interpret_mode")


def bench_kernel_attention():
    from repro.kernels import ops
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    us = _time(lambda a, b, c: ops.flash_attention_op(a, b, c), q, k, v,
               iters=3)
    print(f"kernel_flash_attention_us,{us:.0f},interpret_mode")
    qd = jax.random.normal(ks[0], (B, H, hd))
    us = _time(lambda a, b, c: ops.decode_attention_op(a, b, c, 200),
               qd, k, v, iters=3)
    print(f"kernel_decode_attention_us,{us:.0f},interpret_mode")


ALL = [bench_kernel_parity_ops, bench_kernel_attention]
