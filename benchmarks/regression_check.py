"""CI bench-regression gate.

Compares a fresh ``BENCH_ci.json`` (emitted by
``python -m benchmarks.latency --smoke --json BENCH_ci.json``) against the
checked-in ``benchmarks/BENCH_baseline.json`` and exits non-zero when any
gated metric regressed by more than ``--threshold`` (default 25%).

Gating rules (suffix defaults):

* ``*_ms`` metrics are gated as upper bounds (latencies: higher is worse);
* ``*_eps`` metrics (events per second — simulator throughput) are gated
  as LOWER bounds: the run fails when current throughput drops more than
  ``--eps-threshold`` (default 45%) below baseline.  The wide margin
  absorbs CI-runner speed variance while still catching a hot-loop
  regression that halves event throughput;
* everything else (counters like ``*_reconstructions``, ``*_wall_s``) is
  informational;
* a gated metric present in the baseline but missing from the current run
  fails (a silently dropped bench is a regression of the gate itself);
* metrics new in the current run are reported but do not fail — they start
  gating once the baseline is refreshed.

Per-metric overrides: the baseline document may carry a top-level
``"gate"`` map, ``{metric: {...}}``, consulted before the suffix rules —
this is how the kernel bench lane gates without loosening the DES gates:

* ``{"informational": true}``  — never gate this metric (e.g. the
  machine-dependent ``kernel_*_us`` wall-clocks and autotune block picks);
* ``{"max": M}``               — absolute upper bound: fail when the
  current value exceeds ``M`` regardless of the baseline value (e.g. the
  fused/unfused ``kernel_*_ratio`` metrics pin fused <= unfused with
  ``max: 1.0`` — machine-robust, unlike wall-clock deltas);
* ``{"threshold": t}``         — gate as a relative upper bound at ``t``
  instead of the global ``--threshold`` (forces gating even for metrics
  the suffix rules would treat as informational).

Exit codes: 0 = gate passed; 1 = at least one metric regressed (or went
missing); 2 = the gate itself could not run (unreadable or malformed
input) — distinct, so CI can tell "bench regressed" from "bench broke".

Besides the CSV on stdout, the comparison is rendered as a GitHub-flavored
markdown table (per-metric baseline vs current vs delta %) to
``--markdown PATH``; when the flag is omitted and ``$GITHUB_STEP_SUMMARY``
is set (any GitHub Actions job), the table is appended there, so a
regression is readable in the run's Summary tab without downloading the
BENCH_ci.json artifact.

The DES smoke set is a seeded discrete-event simulation (numpy RNG), so
those values are bit-stable across machines: the gate trips on code
changes that shift simulated latency semantics, not on CI-runner noise.
The ``kernel_*`` set is wall-clock and machine-dependent — which is why
it gates through the ``"gate"`` map (wide bands + absolute ratio bounds)
instead of the tight DES thresholds.  Refresh the baseline deliberately
after an intended change (both writers preserve the existing ``gate``
map)::

    PYTHONPATH=src python -m benchmarks.latency --smoke \
        --json benchmarks/BENCH_baseline.json
    PYTHONPATH=src python -m benchmarks.kernels_bench --smoke \
        --json benchmarks/BENCH_baseline.json
    PYTHONPATH=src python -m benchmarks.accuracy --smoke \
        --json benchmarks/BENCH_baseline.json

The ``acc_unavail_*`` set from the accuracy lane is informational (gated
via the ``gate`` map) and additionally rendered as a cross-scheme A_d
ranking table in the step summary (``accuracy_ranking_table``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def compare(current: dict, baseline: dict, threshold: float,
            eps_threshold: float = 0.45, gates: dict | None = None):
    """Returns (rows, failures); each row is a printable CSV line.

    ``*_ms`` gates are upper bounds (ratio may rise to 1 + threshold);
    ``*_eps`` gates are lower bounds (ratio may fall to 1 - eps_threshold).
    ``gates`` is the baseline document's per-metric override map (see the
    module docstring) — consulted before the suffix rules.
    """
    rows, failures = [], []
    gates = gates or {}
    for name in sorted(baseline):
        base = baseline[name]
        gate = gates.get(name, {})
        higher_worse = name.endswith("_ms")
        lower_worse = name.endswith("_eps")
        abs_max = gate.get("max")
        metric_threshold = gate.get("threshold", threshold)
        if gate.get("informational"):
            continue
        if abs_max is not None or "threshold" in gate:
            higher_worse, lower_worse = True, False
        if not higher_worse and not lower_worse:
            continue
        if name not in current:
            failures.append(f"{name}: missing from current run")
            rows.append(f"{name},{base},MISSING,,FAIL")
            continue
        cur = current[name]
        ratio = cur / base if base > 0 else 1.0
        if abs_max is not None:
            ok = cur <= abs_max
            detail = f"absolute bound max={abs_max}"
        elif higher_worse:
            ok = ratio <= 1.0 + metric_threshold
            detail = (f"+{(ratio - 1):.1%}, threshold "
                      f"{metric_threshold:.0%}")
        else:
            ok = ratio >= 1.0 - eps_threshold
            detail = (f"{(ratio - 1):.1%}, throughput floor "
                      f"-{eps_threshold:.0%}")
        rows.append(f"{name},{base},{cur},{ratio:.3f},"
                    f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(f"{name}: {base} -> {cur} ({detail})")
    for name in sorted(set(current) - set(baseline)):
        rows.append(f"{name},NEW,{current[name]},,info")
    return rows, failures


def markdown_table(rows, failures, threshold: float) -> str:
    """GitHub-flavored markdown rendering of ``compare``'s rows for
    ``$GITHUB_STEP_SUMMARY``: per-metric baseline vs current vs delta %,
    regressions called out up top."""
    lines = ["## Bench gate"]
    if failures:
        lines.append(f"**:x: {len(failures)} metric(s) regressed beyond "
                     f"{threshold:.0%}**")
        lines.extend(f"- `{f}`" for f in failures)
    else:
        n = sum(1 for r in rows if r.endswith(",ok"))
        lines.append(f":white_check_mark: {n} gated metrics within "
                     f"{threshold:.0%} of baseline")
    lines.append("")
    lines.append("| metric | baseline | current | delta % | status |")
    lines.append("|---|---:|---:|---:|---|")
    for row in rows:
        name, base, cur, ratio, status = row.split(",")
        if status == "info":
            delta = "new"
        elif not ratio:
            delta = "-"
        else:
            delta = f"{(float(ratio) - 1):+.1%}"
        mark = {"ok": "ok", "REGRESSED": ":x: REGRESSED",
                "FAIL": ":x: MISSING", "info": "info"}[status]
        lines.append(f"| `{name}` | {base} | {cur} | {delta} | {mark} |")
    return "\n".join(lines) + "\n"


def accuracy_ranking_table(current: dict) -> str:
    """GitHub-flavored markdown ranking of the coding schemes by degraded
    accuracy, from the ``acc_unavail_<scheme>_Ad`` metrics the accuracy
    smoke lane (``benchmarks.accuracy --smoke``) merges into BENCH_ci.json.

    These metrics are informational in the gate (accuracy at smoke scale
    moves with training noise), so they never appear in ``compare``'s rows
    — this renders them as their own section of the step summary instead.
    Returns the empty string when the accuracy lane contributed nothing."""
    prefix, suffix = "acc_unavail_", "_Ad"
    ad = {name[len(prefix):-len(suffix)]: val
          for name, val in current.items()
          if name.startswith(prefix) and name.endswith(suffix)}
    if not ad:
        return ""
    lines = ["## Accuracy under unavailability — A_d scheme ranking"]
    a_a = current.get("acc_unavail_Aa")
    if a_a is not None:
        lines.append(f"Available accuracy A_a = {a_a:.3f}; A_d scores the "
                     "reconstructed predictions with one unavailable "
                     "member per coding group (informational — not gated).")
    lines.append("")
    lines.append("| rank | scheme | A_d | vs best |")
    lines.append("|---:|---|---:|---:|")
    best = max(ad.values())
    ranked = sorted(ad.items(), key=lambda kv: (-kv[1], kv[0]))
    for i, (name, val) in enumerate(ranked, 1):
        lines.append(f"| {i} | `{name}` | {val:.3f} | {val - best:+.3f} |")
    return "\n".join(lines) + "\n"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_ci.json")
    ap.add_argument("baseline", help="checked-in BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed relative regression (default 0.25)")
    ap.add_argument("--eps-threshold", type=float, default=0.45,
                    help="max allowed relative throughput DROP for *_eps "
                         "metrics (default 0.45 — wide, to absorb runner "
                         "speed variance)")
    ap.add_argument("--markdown", default=None, metavar="PATH",
                    help="append a GitHub-flavored summary table here "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args()
    metrics, docs = {}, {}
    for label, path in (("current", args.current),
                        ("baseline", args.baseline)):
        try:
            with open(path) as f:
                docs[label] = json.load(f)
            metrics[label] = docs[label]["metrics"]
        except (OSError, json.JSONDecodeError, KeyError, TypeError) as e:
            # exit 2, not a traceback: "the gate could not run" must be
            # distinguishable from "the gate tripped" (exit 1)
            print(f"# bench gate cannot run: {label} file {path!r} is "
                  f"unreadable or malformed ({e})", file=sys.stderr)
            sys.exit(2)
    gates = docs["baseline"].get("gate") or {}
    if not isinstance(gates, dict):
        print(f"# bench gate cannot run: baseline 'gate' map is "
              f"malformed ({gates!r})", file=sys.stderr)
        sys.exit(2)
    rows, failures = compare(metrics["current"], metrics["baseline"],
                             args.threshold, args.eps_threshold, gates)
    print("metric,baseline,current,ratio,status")
    for row in rows:
        print(row)
    md_path = args.markdown or os.environ.get("GITHUB_STEP_SUMMARY")
    if md_path:
        with open(md_path, "a") as f:
            f.write(markdown_table(rows, failures, args.threshold))
            ranking = accuracy_ranking_table(metrics["current"])
            if ranking:
                f.write("\n" + ranking)
    if failures:
        print(f"\n# BENCH REGRESSION ({len(failures)} metric(s) beyond "
              f"{args.threshold:.0%}):", file=sys.stderr)
        for f_ in failures:
            print(f"#   {f_}", file=sys.stderr)
        sys.exit(1)
    n = sum(1 for r in rows if r.endswith(",ok"))
    print(f"# bench gate ok: {n} gated metrics within "
          f"{args.threshold:.0%} of baseline")


if __name__ == "__main__":
    main()
