"""CI bench-regression gate.

Compares a fresh ``BENCH_ci.json`` (emitted by
``python -m benchmarks.latency --smoke --json BENCH_ci.json``) against the
checked-in ``benchmarks/BENCH_baseline.json`` and exits non-zero when any
gated metric regressed by more than ``--threshold`` (default 25%).

Gating rules:

* only ``*_ms`` metrics are gated (latencies: higher is worse) — counters
  like ``*_reconstructions`` are informational;
* a gated metric present in the baseline but missing from the current run
  fails (a silently dropped bench is a regression of the gate itself);
* metrics new in the current run are reported but do not fail — they start
  gating once the baseline is refreshed.

The smoke set is a seeded discrete-event simulation (numpy RNG), so values
are bit-stable across machines: the gate trips on code changes that shift
simulated latency semantics, not on CI-runner noise.  Refresh the baseline
deliberately after an intended change::

    PYTHONPATH=src python -m benchmarks.latency --smoke \
        --json benchmarks/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys


def compare(current: dict, baseline: dict, threshold: float):
    """Returns (rows, failures); each row is a printable CSV line."""
    rows, failures = [], []
    for name in sorted(baseline):
        base = baseline[name]
        if not name.endswith("_ms"):
            continue
        if name not in current:
            failures.append(f"{name}: missing from current run")
            rows.append(f"{name},{base},MISSING,,FAIL")
            continue
        cur = current[name]
        ratio = cur / base if base > 0 else 1.0
        ok = ratio <= 1.0 + threshold
        rows.append(f"{name},{base},{cur},{ratio:.3f},"
                    f"{'ok' if ok else 'REGRESSED'}")
        if not ok:
            failures.append(
                f"{name}: {base} -> {cur} (+{(ratio - 1):.1%}, "
                f"threshold {threshold:.0%})")
    for name in sorted(set(current) - set(baseline)):
        rows.append(f"{name},NEW,{current[name]},,info")
    return rows, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_ci.json")
    ap.add_argument("baseline", help="checked-in BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed relative regression (default 0.25)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)["metrics"]
    with open(args.baseline) as f:
        baseline = json.load(f)["metrics"]
    rows, failures = compare(current, baseline, args.threshold)
    print("metric,baseline,current,ratio,status")
    for row in rows:
        print(row)
    if failures:
        print(f"\n# BENCH REGRESSION ({len(failures)} metric(s) beyond "
              f"{args.threshold:.0%}):", file=sys.stderr)
        for f_ in failures:
            print(f"#   {f_}", file=sys.stderr)
        sys.exit(1)
    n = sum(1 for r in rows if r.endswith(",ok"))
    print(f"# bench gate ok: {n} gated metrics within "
          f"{args.threshold:.0%} of baseline")


if __name__ == "__main__":
    main()
