"""Benchmark harness — one function per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only latency
Prints ``name,value,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "accuracy", "latency", "roofline",
                             "kernels"])
    args = ap.parse_args()

    from benchmarks import accuracy, kernels_bench, latency, roofline_table
    suites = {"accuracy": accuracy.ALL, "latency": latency.ALL,
              "roofline": roofline_table.ALL,
              "kernels": kernels_bench.ALL}
    if args.only:
        suites = {args.only: suites[args.only]}

    failures = []
    t0 = time.time()
    for suite, fns in suites.items():
        for fn in fns:
            print(f"# --- {suite}:{fn.__name__} ---", flush=True)
            t1 = time.time()
            try:
                fn()
            except Exception as e:
                failures.append((fn.__name__, repr(e)))
                traceback.print_exc()
            print(f"# {fn.__name__} took {time.time()-t1:.1f}s", flush=True)
    print(f"# total {time.time()-t0:.1f}s")
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
