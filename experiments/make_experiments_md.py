"""Assemble EXPERIMENTS.md from dry-run JSON artifacts + bench outputs.

    PYTHONPATH=src python experiments/make_experiments_md.py
"""
import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")


def load(d, mesh=None):
    out = {}
    for p in sorted(glob.glob(os.path.join(ROOT, d, "*.json"))):
        r = json.load(open(p))
        if mesh and r["mesh"] != mesh:
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def table(rows, base=None):
    hdr = ("| arch | shape | kind | compute ms | memory ms | coll ms | "
           "dominant | useful | temp GiB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for (arch, shape), r in sorted(rows.items()):
        c = r["roofline_scan_corrected"]
        u = r["useful_flops_ratio"]
        t = r["memory"]["temp_bytes"] / 2 ** 30
        extra = ""
        if base and (arch, shape) in base:
            t0 = base[(arch, shape)]["memory"]["temp_bytes"] / 2 ** 30
            extra = f" ({t0:.1f}→)"
        lines.append(
            f"| {arch} | {shape} | {r['kind']} | {c['compute_s']*1e3:.1f} | "
            f"{c['memory_s']*1e3:.1f} | {c['collective_s']*1e3:.1f} | "
            f"{r['roofline']['dominant']} | {u:.2f} |{extra} {t:.2f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    base = load("experiments/dryrun", "pod")
    multi = load("experiments/dryrun", "multipod")
    opt = load("experiments/dryrun_opt", "pod")
    opt_mp = load("experiments/dryrun_opt", "multipod")

    parts = []
    parts.append(open(os.path.join(ROOT, "experiments",
                                   "EXPERIMENTS_header.md")).read())

    parts.append("\n## §Dry-run\n")
    parts.append(f"""
Every (architecture × input-shape) pair was lowered **and compiled** with
`jax.jit(...).lower(...).compile()` on two production meshes, with
`memory_analysis()` and `cost_analysis()` captured per pair
(`experiments/dryrun/*.json`):

- single pod `(data=16, model=16)` = 256 chips: **{len(base)}/40 pairs compile**
- multi-pod `(pod=2, data=16, model=16)` = 512 chips: **{len(multi)}/40 pairs
  compile** (batch shards over `pod×data`; the pod axis carries only
  data-parallel reductions)

Methodology notes (verified empirically, see DESIGN.md):
- `cost_analysis()` of the SPMD executable is **per device**, and counts
  `while`-loop bodies **once**. Tables below scale flops/bytes/collectives by
  the layer-stack scan trip count (`scan_trips` in the JSON). These corrected
  terms are approximations in both directions: inner scans (flash KV blocks,
  SSD chunks) are still single-counted (under-count), while loop-invariant
  carried buffers (e.g. the whole decode cache threaded through the layer
  scan) get multiplied (over-count). Raw per-body terms are kept in the JSON;
  **peak-memory numbers are exact** and anchor all §Perf claims.
- collective bytes = sum of result-buffer sizes of
  all-gather/all-reduce/reduce-scatter/all-to-all/collective-permute in the
  compiled per-device HLO.
- `long_500k` runs natively for ssm/hybrid archs and with the
  sliding-window(8192) variant for full-attention archs (noted per JSON).
""")

    parts.append("\n## §Roofline — paper-faithful baseline "
                 "(single pod, 256 chips)\n\n")
    parts.append(
        "Terms in ms per step (scan-corrected); constants: 197 TFLOP/s bf16,"
        " 819 GB/s HBM, 50 GB/s/link. `useful` = MODEL_FLOPS (6·N_active·D,"
        " ×3 for training) / corrected HLO flops.\n\n")
    parts.append(table(base))

    parts.append("\n### Baseline observations (what would move each "
                 "dominant term)\n")
    parts.append("""
- **train_4k** pairs are memory-dominated: remat recompute traffic + fp32
  loss/optimizer temporaries; lever = microbatching (§Perf B) and bf16 grad
  accumulation.
- **decode** pairs were collective-dominated *entirely* due to the GQA KV
  cache replication over the 16-way tensor axis (kv_heads < 16); lever =
  sequence-sharded caches + grouped-GQA einsums (§Perf A).
- **prefill** pairs split between memory (activation streaming) and
  collective (FSDP weight gathers — pointless for inference; §Perf C).
- MoE archs keep small collective terms after the explicit expert-parallel
  shard_map schedule (the global-scatter lowering was catastrophically
  replicated — §Perf iteration 1 under *history*).
- `useful` ≫1 or ≪1 flags where inner-scan undercounting (flash/SSD) or
  non-matmul overheads (dispatch gathers, optimizer elementwise) dominate —
  per-pair notes in the JSONs.
""")

    if opt:
        parts.append("\n## §Roofline — beyond-paper optimized layout "
                     "(same mesh)\n\n")
        parts.append(
            "After §Perf changes (grouped-GQA, seq-sharded caches, inference"
            " weight layout; microbatching is opt-in per run so train rows"
            " here are un-microbatched). temp column shows (baseline→)"
            " optimized GiB/chip. The same optimized code also compiles for"
            f" all {len(opt_mp)}/40 pairs on the 512-chip multi-pod mesh"
            " (`experiments/dryrun_opt/*multipod*`).\n\n")
        parts.append(table(opt, base))

    parts.append(open(os.path.join(ROOT, "experiments",
                                   "EXPERIMENTS_perf.md")).read())

    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(p.rstrip("\n") + "\n" for p in parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
