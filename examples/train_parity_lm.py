"""Train a parity model for an assigned LM architecture (embedding-space
ParM, DESIGN.md §2) and measure degraded-mode next-token agreement.

    PYTHONPATH=src python examples/train_parity_lm.py [--arch smollm-135m]

1. "Deploy" a reduced LM trained briefly on a Markov stream.
2. Train a parity LM: F_P(sum embeddings) ~= sum logits  (MSE, §4.1).
3. Evaluate: for coding groups of k sequences, reconstruct one missing
   logit sequence via subtraction and report top-1 agreement with the
   deployed model's own prediction (the paper's A_d metric, LM flavour).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import lm_batches
from repro.models import transformer as T
from repro.training.optim import AdamConfig, adam_init
from repro.training.train_lib import (make_parity_train_step,
                                      make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--parity-steps", type=int, default=60)
    ap.add_argument("--k", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    B, S, k = 8, 32, args.k

    # 1. train the deployed LM ----------------------------------------------
    deployed = T.init_params(cfg, key)
    opt = AdamConfig(lr=3e-3)
    tstep = jax.jit(make_train_step(cfg, opt, remat=False))
    ostate = adam_init(deployed, opt)
    data = lm_batches(cfg.vocab, B, S, args.steps + 20, seed=0)
    for i in range(args.steps):
        deployed, ostate, m = tstep(deployed, ostate,
                                    {"tokens": jnp.asarray(data[i])[:, :S]})
    print(f"deployed {args.arch} (reduced) loss after {args.steps} steps: "
          f"{float(m['loss']):.3f}")

    # 2. train the parity LM -------------------------------------------------
    parity = T.init_params(cfg, jax.random.PRNGKey(1))
    pstep = jax.jit(make_parity_train_step(cfg, opt))
    pstate = adam_init(parity, opt)

    @jax.jit
    def make_batch(toks):                      # toks [k, B, S]
        embeds = jax.vmap(lambda t: T.embed_tokens(cfg, deployed, t))(toks)
        teacher = jax.vmap(
            lambda t: T.forward(cfg, deployed, tokens=t)[0])(toks)
        return {"embeds": embeds, "teacher": teacher}

    for i in range(args.parity_steps):
        rows = data[(i % 20) + args.steps]
        toks = jnp.asarray(rows[:k * (B // k) * 1, :S]).reshape(
            k, B // k, S) if False else jnp.stack(
            [jnp.asarray(data[(i + j) % (args.steps + 20)][:B // k, :S])
             for j in range(k)])
        parity, pstate, pm = pstep(parity, pstate, make_batch(toks))
        if i % 20 == 0:
            print(f"  parity step {i}: mse={float(pm['loss']):.4f}")

    # 3. degraded-mode agreement --------------------------------------------
    toks = jnp.stack(
        [jnp.asarray(data[args.steps + j][:B // k, :S]) for j in range(k)])
    batch = make_batch(toks)
    parity_q = batch["embeds"].sum(0)
    f_p, _ = T.forward(cfg, parity, embeds=parity_q)
    teacher = batch["teacher"]
    agree = []
    for miss in range(k):
        avail = sum(teacher[j] for j in range(k) if j != miss)
        recon = f_p - avail
        agree.append(float(
            (recon.argmax(-1) == teacher[miss].argmax(-1)).mean()))
    rand = 1.0 / cfg.vocab
    print(f"degraded-mode top-1 agreement with deployed predictions "
          f"(k={k}): {np.mean(agree):.3f}  (random={rand:.4f})")


if __name__ == "__main__":
    main()
