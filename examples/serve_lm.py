"""Coded autoregressive LM serving, end to end (DESIGN.md §13).

    PYTHONPATH=src python examples/serve_lm.py [--requests 4] [--k 2] \
        [--slots 2] [--max-new 4] [--straggle-ms 120]

Deploys a tiny transformer behind ``deploy_lm(spec, engine="threads")``:
k member instances serve multi-token requests out of per-slot KV-cache
pools (continuous batching — requests join and leave at token boundaries),
while a parity instance decodes the embedding-encoded sum of the member
streams.  Member 0 is artificially straggled: every decode step it misses,
the scheduler reconstructs its logits from the parity stream and the stream
keeps emitting tokens without waiting.

The SAME deployment shape then replays through the token-level DES at a
qwen3-moe-235b roofline-calibrated service time — the big-config tail study
(coded vs uncoded equal-resources) that runs where no TPU pod is attached.
"""
import argparse

import jax

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serving.api import BatchingPolicy, deploy_lm
from repro.serving.generation import GenerationSpec, token_service_ms
from repro.serving.scenarios import instance_id


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--straggle-ms", type=float, default=120.0)
    ap.add_argument("--sim-tokens", type=int, default=8000)
    args = ap.parse_args()

    # threads engine: real model, one deliberately slow member ------------
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    slow = instance_id("main", 0)
    spec = GenerationSpec(
        cfg=cfg, params=params, k=args.k, r=1, scheme="sum",
        batching=BatchingPolicy(max_size=args.slots), max_seq_len=32,
        max_new_tokens=args.max_new, straggle_ms=args.straggle_ms,
        delay_fn=lambda iid: 0.4 if iid == slow else 0.0)
    prompts = [[(7 * i + j) % cfg.vocab for j in range(3 + i % 3)]
               for i in range(args.requests)]
    with deploy_lm(spec, engine="threads") as sess:
        futs = [sess.submit(p) for p in prompts]
        if not sess.wait_all(300.0):
            raise SystemExit("generation did not drain")
        for f in futs:
            print(f"request {f.rid}: tokens={f.result()} "
                  f"reconstructed_steps={f.reconstructed_steps}")
        report = sess.stats()
    print(report.summary())
    print(f"threads: tokens/s={report.tokens_per_s:.1f} "
          f"inter-token p50={report.inter_token_p50_ms:.1f}ms "
          f"p999={report.inter_token_p999_ms:.1f}ms "
          f"reconstructed={report.reconstructed_steps}")
    assert report.reconstructed_steps > 0, "straggled member never coded over"

    # sim engine: big-config tail study at roofline service time ----------
    big = get_config("qwen3-moe-235b-a22b")
    lm = GenerationSpec(cfg=big, k=4, r=1, m=12, utilization=0.3,
                        kv_len=4096, tp=8, scenario="bursty")
    print(f"\nsim: qwen3-moe-235b decode step = {token_service_ms(lm):.2f}ms"
          f" (roofline, kv_len=4096, tp=8)")
    coded = deploy_lm(lm, engine="sim").replay(n_tokens=args.sim_tokens,
                                               seed=1)
    uncoded = deploy_lm(lm.replace(strategy="equal_resources"),
                        engine="sim").replay(n_tokens=args.sim_tokens,
                                             seed=1)
    print(f"sim coded:   {coded.summary()}")
    print(f"sim uncoded: {uncoded.summary()}")
    print(f"inter-token p999: coded {coded.inter_token_p999_ms:.1f}ms vs "
          f"uncoded {uncoded.inter_token_p999_ms:.1f}ms "
          f"({coded.inter_token_p999_ms / uncoded.inter_token_p999_ms:.2f}x"
          f" at {coded.inter_token_p50_ms / uncoded.inter_token_p50_ms:.2f}x"
          f" the median)")


if __name__ == "__main__":
    main()
