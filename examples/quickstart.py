"""Quickstart: the ParM pipeline in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Train a small deployed classifier.
2. Learn a parity model for k=2 (paper §3.3).
3. Simulate an unavailable prediction and reconstruct it with the
   subtraction decoder (paper §3.2).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import topk_accuracy
from repro.core.parity import train_parity_models
from repro.data.pipeline import batched, cluster_images
from repro.models.cnn import build
from repro.training.loss import softmax_xent
from repro.training.optim import AdamConfig, adam_init, adam_update

IMG = (16, 16, 1)


def main():
    # 1. deployed model ----------------------------------------------------
    x, y, tmpl = cluster_images(3000, noise=2.0, seed=0, image_shape=IMG)
    xt, yt, _ = cluster_images(500, noise=2.0, seed=1, templates=tmpl,
                               image_shape=IMG)
    params, fwd = build("mlp", jax.random.PRNGKey(0), image_shape=IMG)
    opt = AdamConfig(lr=1e-3)
    state = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        loss, g = jax.value_and_grad(
            lambda p: softmax_xent(fwd(p, xb), yb))(p)
        p, s = adam_update(g, s, p, opt)
        return p, s, loss

    for xb, yb in batched(x, y, 64, epochs=3):
        params, state, loss = step(params, state, xb, yb)
    acc = topk_accuracy(np.asarray(fwd(params, jnp.asarray(xt))), yt)
    print(f"deployed model accuracy A_a = {acc:.3f}")

    # 2. parity model (k=2, the "sum" scheme from the registry) ------------
    k = 2
    parity_params, scheme = train_parity_models(
        params, fwd, lambda kk: build("mlp", kk, image_shape=IMG)[0],
        x, k=k, scheme="sum", epochs=5)

    # 3. one coding group: X1, X2 -> P; X2's prediction is "unavailable" ---
    x1, x2 = xt[0:1], xt[1:2]
    parity_query = scheme.encode(jnp.stack([x1, x2]))[0]
    f_x1 = fwd(params, jnp.asarray(x1))
    f_p = fwd(parity_params[0], parity_query)
    recon = scheme.decode_one(f_p[0], jnp.stack([f_x1[0], f_x1[0] * 0]), 1)
    truth = fwd(params, jnp.asarray(x2))[0]
    print(f"true class of X2:           {int(jnp.argmax(truth))} "
          f"(label {yt[1]})")
    print(f"reconstructed prediction:   {int(jnp.argmax(recon))}")
    print("reconstruction L2 gap:      "
          f"{float(jnp.linalg.norm(recon - truth)):.3f}")


if __name__ == "__main__":
    main()
