"""End-to-end ParM serving driver (the paper-kind end-to-end example:
serve a small model with batched requests through the coded frontend).

    PYTHONPATH=src python examples/serve_parm.py [--n 120] [--k 2] [--m 4]

Trains a deployed classifier + parity model, then serves a request stream
through the threaded frontend with an injected straggler instance, and
reports latency percentiles + how each prediction was completed
(model / parity-reconstruction), plus accuracy of each path.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.parity import train_parity_models
from repro.data.pipeline import batched, cluster_images
from repro.models.cnn import build
from repro.serving.runtime import ParMFrontend
from repro.training.loss import softmax_xent
from repro.training.optim import AdamConfig, adam_init, adam_update

IMG = (16, 16, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=120)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--straggle-ms", type=float, default=150.0)
    args = ap.parse_args()

    # train deployed + parity models ---------------------------------------
    x, y, tmpl = cluster_images(3000, noise=2.0, seed=0, image_shape=IMG)
    xt, yt, _ = cluster_images(args.n, noise=2.0, seed=1, templates=tmpl,
                               image_shape=IMG)
    params, fwd = build("mlp", jax.random.PRNGKey(0), image_shape=IMG)
    opt = AdamConfig(lr=1e-3)
    state = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        loss, g = jax.value_and_grad(
            lambda p: softmax_xent(fwd(p, xb), yb))(p)
        return (*adam_update(g, s, p, opt), loss)

    for xb, yb in batched(x, y, 64, epochs=3):
        params, state, _ = step(params, state, xb, yb)
    pp, scheme = train_parity_models(
        params, fwd, lambda kk: build("mlp", kk, image_shape=IMG)[0],
        x, k=args.k, epochs=5)
    jfwd = jax.jit(fwd)

    # serve with an injected straggler --------------------------------------
    slow = {0}

    def delay(iid):
        return args.straggle_ms / 1e3 if iid in slow else 0.0

    fe = ParMFrontend(jfwd, params, parity_params=pp[0], k=args.k, m=args.m,
                      strategy="parm", scheme=scheme, delay_fn=delay)
    try:
        t0 = time.perf_counter()
        qs = []
        for i in range(args.n):
            qs.append(fe.submit(i, xt[i:i + 1]))
            time.sleep(0.008)                  # ~125 qps arrival stream
        ok = fe.wait_all(timeout=120)
        wall = time.perf_counter() - t0
        assert ok, "unanswered queries!"
        stats = fe.stats()
        lat = np.array([q.latency_ms for q in qs])
        print(f"\nserved {args.n} queries in {wall:.2f}s "
              f"(m={args.m} deployed + {max(1, args.m // args.k)} parity, "
              f"instance 0 straggles {args.straggle_ms:.0f} ms)")
        print(f"latency  p50={np.percentile(lat, 50):.1f}ms "
              f"p90={np.percentile(lat, 90):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms max={lat.max():.1f}ms")
        print(f"completed_by: {stats['completed_by']}")
        for how in ("model", "parity"):
            sel = [q for q in qs if q.completed_by == how]
            if sel:
                acc = np.mean([np.argmax(q.result) == yt[q.qid]
                               for q in sel])
                print(f"accuracy of '{how}' predictions: {acc:.3f} "
                      f"(n={len(sel)})")
    finally:
        fe.shutdown()


if __name__ == "__main__":
    main()
