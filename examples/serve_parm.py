"""End-to-end ParM serving driver (the paper-kind end-to-end example:
serve a small model with batched requests through the coded frontend).

    PYTHONPATH=src python examples/serve_parm.py [--n 120] [--k 2] [--m 4] \
        [--batch-size 4]

Trains a deployed classifier + parity model, declares the deployment once as
a ``DeploymentSpec`` and serves a request stream through
``deploy(spec, engine="threads")`` with an injected straggler instance,
reporting latency percentiles + how each prediction was completed
(model / parity-reconstruction), plus accuracy of each path.  The SAME spec
replays through the simulator: ``deploy(spec, engine="sim").replay(trace)``.
"""
import argparse
import time

import jax
import numpy as np

from repro.core.parity import train_parity_models
from repro.data.pipeline import batched, cluster_images
from repro.models.cnn import build
from repro.serving.api import BatchingPolicy, DeploymentSpec, Trace, deploy
from repro.training.loss import softmax_xent
from repro.training.optim import AdamConfig, adam_init, adam_update

IMG = (16, 16, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=120)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--straggle-ms", type=float, default=150.0)
    ap.add_argument("--batch-size", type=int, default=1,
                    help="adaptive-batching max batch size (main pool)")
    args = ap.parse_args()

    # train deployed + parity models ---------------------------------------
    x, y, tmpl = cluster_images(3000, noise=2.0, seed=0, image_shape=IMG)
    xt, yt, _ = cluster_images(args.n, noise=2.0, seed=1, templates=tmpl,
                               image_shape=IMG)
    params, fwd = build("mlp", jax.random.PRNGKey(0), image_shape=IMG)
    opt = AdamConfig(lr=1e-3)
    state = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        loss, g = jax.value_and_grad(
            lambda p: softmax_xent(fwd(p, xb), yb))(p)
        return (*adam_update(g, s, p, opt), loss)

    for xb, yb in batched(x, y, 64, epochs=3):
        params, state, _ = step(params, state, xb, yb)
    pp, scheme = train_parity_models(
        params, fwd, lambda kk: build("mlp", kk, image_shape=IMG)[0],
        x, k=args.k, epochs=5)
    jfwd = jax.jit(fwd)

    # serve with an injected straggler --------------------------------------
    slow = {0}

    def delay(iid):
        return args.straggle_ms / 1e3 if iid in slow else 0.0

    spec = DeploymentSpec(
        fwd=jfwd, params=params, parity_params=pp[0], strategy="parm",
        scheme=scheme, k=args.k, m=args.m, delay_fn=delay,
        batching=BatchingPolicy(max_size=args.batch_size, max_delay_ms=2.0))
    with deploy(spec, engine="threads") as sess:
        t0 = time.perf_counter()
        futs = []
        for i in range(args.n):
            futs.append(sess.submit(xt[i:i + 1]))
            time.sleep(0.008)                  # ~125 qps arrival stream
        ok = sess.wait_all(timeout=120)
        wall = time.perf_counter() - t0
        assert ok, "unanswered queries!"
        stats = sess.stats()
        lat = np.array([f.latency_ms for f in futs])
        print(f"\nserved {args.n} queries in {wall:.2f}s "
              f"(m={args.m} deployed + {max(1, args.m // args.k)} parity, "
              f"instance 0 straggles {args.straggle_ms:.0f} ms)")
        print(f"latency  p50={np.percentile(lat, 50):.1f}ms "
              f"p90={np.percentile(lat, 90):.1f}ms "
              f"p99={np.percentile(lat, 99):.1f}ms max={lat.max():.1f}ms")
        print(f"completed_by: {stats['completed_by']}")
        if stats["mean_batch_size"] > 1:
            print(f"adaptive batching: mean batch "
                  f"{stats['mean_batch_size']:.2f} over {stats['batches']} "
                  "inference calls")
        if stats["cancellations"]:
            print(f"redundant work cancelled: {stats['cancellations']} "
                  "queued items tombstoned")
        for how in ("model", "parity"):
            sel = [f for f in futs if f.completed_by == how]
            if sel:
                acc = np.mean([np.argmax(f.result()) == yt[f.qid]
                               for f in sel])
                print(f"accuracy of '{how}' predictions: {acc:.3f} "
                      f"(n={len(sel)})")

    # the SAME spec replays through the simulator: the DES charges its
    # calibrated service-time model (not this tiny MLP's real latency), so
    # this is the 100k-query-scale view of the deployment just served
    sim = deploy(spec, engine="sim").replay(Trace(n_queries=20_000,
                                                  qps=125.0))
    print(f"\nsim replay of the same spec: {sim.summary()}")


if __name__ == "__main__":
    main()
