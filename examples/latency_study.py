"""Tail-latency study (paper Fig 11) via the discrete-event simulator.

    PYTHONPATH=src python examples/latency_study.py [--qps 270] [--m 12]
"""
import argparse

from repro.serving.simulator import SimConfig, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=270)
    ap.add_argument("--m", type=int, default=12)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--n", type=int, default=100_000)
    args = ap.parse_args()

    cfg = SimConfig(n_queries=args.n, qps=args.qps, m=args.m, k=args.k)
    print(f"m={args.m} deployed instances, k={args.k} "
          f"({1/args.k:.0%} redundancy), {args.qps} qps, "
          f"{args.n} queries, background network shuffles on\n")
    print(f"{'strategy':18s} {'median':>8s} {'p99':>8s} {'p99.9':>8s} "
          f"{'gap':>8s} {'recon':>7s}")
    for strat in ("none", "equal_resources", "parm", "approx_backup",
                  "replication"):
        r = simulate(cfg, strat)
        gap = r["p999_ms"] - r["median_ms"]
        print(f"{strat:18s} {r['median_ms']:7.1f}ms {r['p99_ms']:7.1f}ms "
              f"{r['p999_ms']:7.1f}ms {gap:7.1f}ms "
              f"{r['reconstructions']:7d}")


if __name__ == "__main__":
    main()
