"""Tail-latency study (paper Fig 11) via the discrete-event simulator.

    PYTHONPATH=src python examples/latency_study.py [--qps 270] [--m 12] \
        [--r 2] [--scheme learned] [--scenario crash]

``--scenario`` picks a registered fault scenario (``crash``, ``bursty``,
``storm``, ...); omitted, the paper's background network-shuffle load runs.
``--scheme`` / ``--r`` select the code served by the coded strategies — any
registered name, including ``learned`` and ``approx_backup`` (§3.5,
DESIGN.md §7).
"""
import argparse

from repro.core.scheme import available_schemes
from repro.serving.scenarios import available_scenarios
from repro.serving.simulator import SimConfig, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=270)
    ap.add_argument("--m", type=int, default=12)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--r", type=int, default=1,
                    help="parity models per coding group (paper §3.5)")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--scheme", default=None, choices=available_schemes(),
                    help="coding scheme for coded strategies (e.g. sum | "
                         "learned | replication; default: strategy's own)")
    ap.add_argument("--scenario", default=None,
                    choices=available_scenarios(),
                    help="fault scenario (default: legacy shuffle load)")
    args = ap.parse_args()

    cfg = SimConfig(n_queries=args.n, qps=args.qps, m=args.m, k=args.k,
                    r=args.r)
    load = args.scenario or "background network shuffles"
    print(f"m={args.m} deployed instances, k={args.k} "
          f"({1/args.k:.0%} redundancy), r={args.r}, {args.qps} qps, "
          f"{args.n} queries, load: {load}\n")
    print(f"{'strategy':18s} {'scheme':12s} {'median':>8s} {'p99':>8s} "
          f"{'p99.9':>8s} {'gap':>8s} {'recon':>7s}")
    for strat in ("none", "equal_resources", "parm", "approx_backup",
                  "replication"):
        r = simulate(cfg, strat, scheme=args.scheme,
                     scenario=args.scenario)
        gap = r["p999_ms"] - r["median_ms"]
        print(f"{strat:18s} {str(r['scheme']):12s} "
              f"{r['median_ms']:7.1f}ms {r['p99_ms']:7.1f}ms "
              f"{r['p999_ms']:7.1f}ms {gap:7.1f}ms "
              f"{r['reconstructions']:7d}")


if __name__ == "__main__":
    main()
