"""Tail-latency study (paper Fig 11) via the sim engine of the declarative
serving API.

    PYTHONPATH=src python examples/latency_study.py [--qps 270] [--m 12] \
        [--r 2] [--scheme learned] [--scenario crash] [--batch-size 4]

One ``DeploymentSpec`` per strategy, one shared workload ``Trace``:
``deploy(spec, engine="sim").replay(trace)`` — the exact spec a threaded
deployment would consume (DESIGN.md §8).  ``--scenario`` picks a registered
fault scenario (``crash``, ``bursty``, ``storm``, ...); omitted, the paper's
background network-shuffle load runs.  ``--scheme`` / ``--r`` select the code
served by the coded strategies — any registered name, including ``learned``
and ``approx_backup`` (§3.5, DESIGN.md §7).  ``--batch-size`` sweeps the
adaptive ``BatchingPolicy`` through the DES's per-batch service-time curve.
``--controller`` closes the loop: a registered adaptive-redundancy
controller (DESIGN.md §10) retunes scheme, r, and batching from live
``ReportWindow`` signals — pair it with an episodic ``--scenario`` such as
``bursty`` to watch the escalation/settle cycle in the adjustment log.
"""
import argparse

from repro.core.scheme import available_schemes
from repro.serving.api import BatchingPolicy, DeploymentSpec, Trace, deploy
from repro.serving.controller import available_controllers
from repro.serving.scenarios import available_scenarios


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=270)
    ap.add_argument("--m", type=int, default=12)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--r", type=int, default=1,
                    help="parity models per coding group (paper §3.5)")
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--scheme", default=None, choices=available_schemes(),
                    help="coding scheme for coded strategies (e.g. sum | "
                         "learned | replication; default: strategy's own)")
    ap.add_argument("--scenario", default=None,
                    choices=available_scenarios(),
                    help="fault scenario (default: legacy shuffle load)")
    ap.add_argument("--batch-size", type=int, default=1,
                    help="adaptive-batching max batch size (main pool)")
    ap.add_argument("--controller", default=None,
                    choices=available_controllers(),
                    help="closed-loop adaptive-redundancy controller "
                         "(coded strategies retune scheme/r/batching from "
                         "live ReportWindow signals)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI subprocess dryruns: exercise "
                         "the full strategy sweep in seconds")
    args = ap.parse_args()
    if args.smoke:
        args.n = min(args.n, 4000)

    trace = Trace(n_queries=args.n, qps=args.qps)
    load = args.scenario or "background network shuffles"
    ctl = f", controller: {args.controller}" if args.controller else ""
    print(f"m={args.m} deployed instances, k={args.k} "
          f"({1/args.k:.0%} redundancy), r={args.r}, {args.qps} qps, "
          f"{args.n} queries, load: {load}, "
          f"batching max_size={args.batch_size}{ctl}\n")
    print(f"{'strategy':18s} {'scheme':12s} {'median':>8s} {'p99':>8s} "
          f"{'p99.9':>8s} {'gap':>8s} {'recon':>7s} {'cancel':>7s}")
    for strat in ("none", "equal_resources", "parm", "approx_backup",
                  "replication"):
        spec = DeploymentSpec(
            strategy=strat, scheme=args.scheme, k=args.k, r=args.r,
            m=args.m, scenario=args.scenario,
            batching=BatchingPolicy(max_size=args.batch_size),
            controller=args.controller)
        r = deploy(spec, engine="sim").replay(trace)
        gap = r["p999_ms"] - r["median_ms"]
        print(f"{strat:18s} {str(r['scheme']):12s} "
              f"{r['median_ms']:7.1f}ms {r['p99_ms']:7.1f}ms "
              f"{r['p999_ms']:7.1f}ms {gap:7.1f}ms "
              f"{r['reconstructions']:7d} {r.cancellations:7d}")
        if args.controller and r.adjustments:
            log = " ".join(
                f"w{w}->({s},r={rr},b={b})" for w, s, rr, b in r.adjustments)
            print(f"{'':18s} adjustments: {log} "
                  f"(parity_served={r.parity_served})")


if __name__ == "__main__":
    main()
