"""Property tests for the ParM coding layer (hypothesis).

Invariants from the paper:
  * For a linear deployed model F and the identity parity model F_P = F, the
    addition/subtraction code is EXACT for any missing index (Table 1).
  * For r > 1, with ideal parity outputs (the decoder's expected linear
    combinations), any <= r missing outputs are reconstructed exactly from
    any k available outputs (§3.5, MDS property of the Vandermonde code).
  * Encoders preserve query shape; ConcatEncoder output equals one query's
    footprint (1/k bandwidth overhead, §3.1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.codes import (ConcatEncoder, LinearDecoder, SumEncoder,
                              make_code, vandermonde)
from repro.models.linear import init_linear, linear_fwd

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@given(k=st.integers(2, 6), missing=st.data(), seed=st.integers(0, 2**16))
def test_linear_model_exact_reconstruction(k, missing, seed):
    j = missing.draw(st.integers(0, k - 1))
    key = jax.random.PRNGKey(seed)
    p = init_linear(key, 12, 7)
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, 3, 12))
    enc, dec = make_code(k, 1, "sum")
    parity = enc(xs)[0]
    outs = jnp.stack([linear_fwd(p, x) for x in xs])         # [k, 3, 7]
    parity_out = linear_fwd(p, parity)                        # ideal F_P = F
    recon = dec.decode_one(parity_out, outs, j)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(outs[j]),
                               rtol=0, atol=1e-3)


@given(k=st.integers(2, 5), r=st.integers(1, 3), seed=st.integers(0, 2**16),
       data=st.data())
def test_vandermonde_multi_failure_exact(k, r, seed, data):
    n_missing = data.draw(st.integers(1, r))
    missing = data.draw(st.permutations(list(range(k))))[:n_missing]
    rng = np.random.default_rng(seed)
    outs_true = rng.normal(size=(k, 5)).astype(np.float32)
    C = vandermonde(k, r)
    parity_outs = (C @ outs_true).astype(np.float32)          # ideal F_P_j
    dec = LinearDecoder(k, r)
    mask = np.zeros(k, bool)
    mask[list(missing)] = True
    outs_in = outs_true.copy()
    outs_in[mask] = 999.0                                     # garbage
    recon = np.asarray(dec.decode(jnp.asarray(parity_outs),
                                  jnp.asarray(outs_in), jnp.asarray(mask)))
    np.testing.assert_allclose(recon[mask], outs_true[mask], atol=5e-3)
    np.testing.assert_allclose(recon[~mask], outs_true[~mask], atol=1e-6)


@given(k=st.integers(2, 5), r=st.integers(1, 3))
def test_vandermonde_is_mds(k, r):
    """Every square system the decoder can face must be solvable: any
    m <= min(r, k) columns of the r x k coefficient matrix have rank m."""
    from itertools import combinations
    C = vandermonde(k, r)
    m = min(r, k)
    for cols in combinations(range(k), m):
        sub = C[:, cols]
        assert np.linalg.matrix_rank(sub) == m


@given(k=st.sampled_from([2, 4]), seed=st.integers(0, 100))
def test_concat_encoder_footprint(k, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(k, 3, 32, 32, 3)).astype(np.float32))
    enc = ConcatEncoder(k)
    out = enc(q)
    assert out.shape == (1, 3, 32, 32, 3)     # same footprint as one query


def test_sum_encoder_r1_is_plain_sum():
    q = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    enc = SumEncoder(2, 1)
    np.testing.assert_allclose(np.asarray(enc(q)[0]), np.asarray(q.sum(0)))


def test_decode_one_matches_general_decode():
    k, r = 3, 1
    rng = np.random.default_rng(0)
    outs = jnp.asarray(rng.normal(size=(k, 4)).astype(np.float32))
    parity = outs.sum(0)
    dec = LinearDecoder(k, r)
    for j in range(k):
        a = dec.decode_one(parity, outs, j)
        mask = np.zeros(k, bool)
        mask[j] = True
        b = dec.decode(parity[None], outs, jnp.asarray(mask))[j]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
