"""Property tests for the ParM coding layer (deterministic parameter sweeps).

Invariants from the paper:
  * For a linear deployed model F and the identity parity model F_P = F, the
    addition/subtraction code is EXACT for any missing index (Table 1).
  * For r > 1, with ideal parity outputs (the decoder's expected linear
    combinations), any <= r missing outputs are reconstructed exactly from
    any k available outputs (§3.5, MDS property of the Vandermonde code).
  * Encoders preserve query shape; ConcatEncoder output equals one query's
    footprint (1/k bandwidth overhead, §3.1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codes import (ConcatEncoder, LinearDecoder, SumEncoder,
                              make_code, vandermonde)
from repro.core.scheme import get_scheme
from repro.models.linear import init_linear, linear_fwd


@pytest.mark.parametrize("k,j,seed", [
    (2, 0, 0), (2, 1, 101), (3, 1, 202), (4, 3, 303), (5, 2, 404),
    (6, 0, 505), (6, 5, 606),
])
def test_linear_model_exact_reconstruction(k, j, seed):
    key = jax.random.PRNGKey(seed)
    p = init_linear(key, 12, 7)
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (k, 3, 12))
    scheme = get_scheme("sum", k=k, r=1)
    parity = scheme.encode(xs)[0]
    outs = jnp.stack([linear_fwd(p, x) for x in xs])         # [k, 3, 7]
    parity_out = linear_fwd(p, parity)                        # ideal F_P = F
    recon = scheme.decode_one(parity_out, outs, j)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(outs[j]),
                               rtol=0, atol=1e-3)


@pytest.mark.parametrize("k,r,missing,seed", [
    (2, 1, (0,), 0), (2, 2, (0, 1), 1), (3, 2, (2,), 2), (3, 2, (0, 2), 3),
    (4, 3, (1, 3), 4), (4, 3, (0, 1, 2), 5), (5, 3, (4,), 6),
    (5, 2, (1, 2), 7),
])
def test_vandermonde_multi_failure_exact(k, r, missing, seed):
    rng = np.random.default_rng(seed)
    outs_true = rng.normal(size=(k, 5)).astype(np.float32)
    scheme = get_scheme("sum", k=k, r=r)
    C = np.asarray(scheme.coeffs)
    parity_outs = (C @ outs_true).astype(np.float32)          # ideal F_P_j
    mask = np.zeros(k, bool)
    mask[list(missing)] = True
    outs_in = outs_true.copy()
    outs_in[mask] = 999.0                                     # garbage
    recon = np.asarray(scheme.decode(jnp.asarray(parity_outs),
                                     jnp.asarray(outs_in),
                                     jnp.asarray(mask)))
    np.testing.assert_allclose(recon[mask], outs_true[mask], atol=5e-3)
    np.testing.assert_allclose(recon[~mask], outs_true[~mask], atol=1e-6)


@pytest.mark.parametrize("k", [2, 3, 4, 5])
@pytest.mark.parametrize("r", [1, 2, 3])
def test_vandermonde_is_mds(k, r):
    """Every square system the decoder can face must be solvable: any
    m <= min(r, k) columns of the r x k coefficient matrix have rank m."""
    from itertools import combinations
    C = vandermonde(k, r)
    m = min(r, k)
    for cols in combinations(range(k), m):
        sub = C[:, cols]
        assert np.linalg.matrix_rank(sub) == m


@pytest.mark.parametrize("k,seed", [(2, 0), (4, 17), (2, 86), (4, 100)])
def test_concat_encoder_footprint(k, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(k, 3, 32, 32, 3)).astype(np.float32))
    enc = ConcatEncoder(k)
    out = enc(q)
    assert out.shape == (1, 3, 32, 32, 3)     # same footprint as one query


def test_concat_encoder_k3_footprint():
    """k=3 tiles a 2x2 grid with one empty cell; footprint is unchanged."""
    q = jnp.asarray(np.random.default_rng(0).normal(
        size=(3, 2, 16, 16, 1)).astype(np.float32))
    out = get_scheme("concat", k=3).encode(q)
    assert out.shape == (1, 2, 16, 16, 1)


@pytest.mark.parametrize("k,H,W", [(3, 15, 16), (3, 16, 15), (2, 9, 9),
                                   (5, 16, 14)])
def test_concat_encoder_rejects_indivisible_images(k, H, W):
    """H or W not divisible by g = ceil(sqrt(k)) must raise, not silently
    corrupt the grid."""
    q = jnp.ones((k, 1, H, W, 1))
    with pytest.raises(ValueError, match="divisible"):
        ConcatEncoder(k)(q)
    with pytest.raises(ValueError, match="divisible"):
        get_scheme("concat", k=k).encode(q)


def test_sum_encoder_r1_is_plain_sum():
    q = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    enc = SumEncoder(2, 1)
    np.testing.assert_allclose(np.asarray(enc(q)[0]), np.asarray(q.sum(0)))


def test_decode_one_matches_general_decode():
    k, r = 3, 1
    rng = np.random.default_rng(0)
    outs = jnp.asarray(rng.normal(size=(k, 4)).astype(np.float32))
    parity = outs.sum(0)
    dec = LinearDecoder(k, r)
    for j in range(k):
        a = dec.decode_one(parity, outs, j)
        mask = np.zeros(k, bool)
        mask[j] = True
        b = dec.decode(parity[None], outs, jnp.asarray(mask))[j]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_every_registered_scheme_roundtrips_under_all_masks():
    """decode(encode-consistent parity outputs) must round-trip for EVERY
    registered scheme, under EVERY missing mask with n_missing <= r.

    The parity outputs are the ideal output-code combinations
    ``coeffs @ outs`` — what a perfect parity model returns.  For schemes
    whose input code IS the output code (sum, fisher, replication,
    approx_backup, and the learned scheme's zero-initialised residual) that
    equals ``encode(outs)``, which is asserted too; concat's input code is
    the image grid (§4.2.3) and invnet's is conducted in the coupling
    network's latent space, so only the output-code invariant applies to
    them.  The learned scheme is checked at loose tolerance (its decode is
    the shared masked least-squares solve)."""
    from itertools import combinations

    from repro.core.scheme import available_schemes

    for name in available_schemes():
        for r_req in (1, 2):
            try:
                scheme = get_scheme(name, k=4, r=r_req)
            except ValueError:
                continue              # scheme rejects this r (concat: r=1)
            k, r = scheme.k, scheme.r
            rng = np.random.default_rng(11 * k + r)
            outs = jnp.asarray(rng.normal(size=(k, 5)).astype(np.float32))
            parity = jnp.einsum("rk,k...->r...",
                                jnp.asarray(scheme.coeffs, jnp.float32),
                                outs)
            if name not in ("concat", "invnet"):
                np.testing.assert_allclose(
                    np.asarray(scheme.encode(outs)), np.asarray(parity),
                    atol=1e-4, err_msg=name)
            atol = 1e-2 if name == "learned" else 1e-3
            for n_missing in range(1, min(r, k) + 1):
                for rows in combinations(range(k), n_missing):
                    mask = np.zeros(k, bool)
                    mask[list(rows)] = True
                    corrupted = jnp.where(jnp.asarray(mask)[:, None],
                                          999.0, outs)
                    recon = np.asarray(scheme.decode(
                        parity, corrupted, jnp.asarray(mask)))
                    np.testing.assert_allclose(
                        recon, np.asarray(outs), atol=atol,
                        err_msg=f"{name} r={r} mask={rows}")
                    if n_missing == 1 and r == 1:
                        one = np.asarray(scheme.decode_one(
                            parity[0], corrupted, rows[0]))
                        np.testing.assert_allclose(
                            one, np.asarray(outs[rows[0]]), atol=atol,
                            err_msg=f"{name} decode_one j={rows[0]}")


def test_dynamic_arity_schemes_roundtrip_under_combined_loss_masks():
    """Schemes whose recoverability is a response COUNT (``dynamic_arity``
    — approxifer) must round-trip under EVERY split of e <= r losses
    across members and parities together, not only member masks: one
    deployment, any arrival pattern, same decoder.  Includes the e = r
    all-extras-lost split, where decode degenerates to a passthrough of
    the (complete) member outputs."""
    from itertools import combinations

    from repro.core.scheme import (available_schemes, recoverable_rows,
                                   scheme_capabilities)

    swept = 0
    for name in available_schemes():
        try:
            scheme = get_scheme(name, k=3, r=2)
        except ValueError:
            continue
        if not scheme_capabilities(scheme).dynamic_arity:
            continue
        swept += 1
        k, r = scheme.k, scheme.r
        rng = np.random.default_rng(5)
        outs = jnp.asarray(rng.normal(size=(k, 4)).astype(np.float32))
        parity = jnp.einsum("rk,k...->r...",
                            jnp.asarray(scheme.coeffs, jnp.float32), outs)
        for e in range(0, r + 1):
            for lost in combinations(range(k + r), e):
                mask = np.zeros(k, bool)
                pa = np.ones(r, bool)
                for t in lost:
                    if t < k:
                        mask[t] = True
                    else:
                        pa[t - k] = False
                rec_rows = recoverable_rows(scheme, mask, pa)
                assert rec_rows.sum() == mask.sum(), (name, lost)
                corrupted = jnp.where(jnp.asarray(mask)[:, None], 999.0,
                                      outs)
                recon = np.asarray(scheme.decode(
                    parity * jnp.asarray(pa)[:, None], corrupted,
                    jnp.asarray(mask), jnp.asarray(pa)))
                np.testing.assert_allclose(
                    recon, np.asarray(outs), atol=5e-3,
                    err_msg=f"{name} lost={lost}")
    assert swept >= 1            # approxifer is registered


def test_make_code_shim_raises_with_migration_message():
    """The PR-1-era make_code() shim is removed: TypeError pointing at
    get_scheme()."""
    with pytest.raises(TypeError, match="get_scheme"):
        make_code(3, 1, "sum")
