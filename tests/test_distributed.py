"""Distribution-layer tests: sharding rules, logical constraints, roofline
parsing, and a small-mesh dry-run in a subprocess (XLA device-count flags
must be set before jax initialises, so it cannot run in-process)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_divisibility():
    """Every generated PartitionSpec evenly divides its dim (by construction
    of the divisibility guard)."""
    from repro.configs.base import ARCH_IDS, get_config
    from repro.launch.steps import param_shapes

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    from repro.distributed.sharding import ShardingRules
    rules = ShardingRules.__new__(ShardingRules)
    rules.mesh = FakeMesh()
    rules.axis_sizes = {"data": 16, "model": 16}
    rules.tp, rules.fsdp = "model", "data"
    rules.batch_axes = ("data",)

    sizes = {"data": 16, "model": 16}
    from jax.tree_util import tree_flatten_with_path
    for arch in ARCH_IDS:
        shapes = param_shapes(get_config(arch))
        leaves, _ = tree_flatten_with_path(shapes)
        for path, leaf in leaves:
            spec = rules.param_spec(path, leaf)
            for dim, entry in zip(leaf.shape, spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                prod = 1
                for a in axes:
                    prod *= sizes[a]
                assert dim % prod == 0, (arch, path, leaf.shape, spec)


def test_logical_constrain_noop_without_rules():
    from repro.distributed.logical import clear_rules, constrain
    clear_rules()
    x = jnp.ones((4, 8))
    y = constrain(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_logical_axis_reuse_guard():
    """The same mesh axis must never appear twice in one spec."""
    from repro.distributed import logical

    captured = {}
    orig = jax.lax.with_sharding_constraint

    def fake_wsc(x, spec):
        captured["spec"] = spec
        return x

    jax.lax.with_sharding_constraint, wsc = fake_wsc, orig
    try:
        with logical.logical_rules(
                {"batch": ("data",), "seq": ("data",)}, {"data": 16}):
            logical.constrain(jnp.ones((16, 32)), ("batch", "seq"))
        spec = captured["spec"]
        flat = [a for e in spec if e for a in
                (e if isinstance(e, tuple) else (e,))]
        assert len(flat) == len(set(flat))
        assert spec[0] == "data" and spec[1] is None
    finally:
        jax.lax.with_sharding_constraint = wsc


def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
      %ag = bf16[16,128]{1,0} all-gather(bf16[2,128]{1,0} %x), dims={0}
      %ar.1 = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%sum
      %a2a = (bf16[4,8]{1,0}, bf16[4,8]{1,0}) all-to-all(%p, %q)
      %cp = u32[2]{0} collective-permute(u32[2]{0} %z)
      %not_a_collective = f32[999999]{0} add(f32[1]{0} %a, f32[1]{0} %b)
    """
    detail, counts = collective_bytes(hlo)
    assert detail["all-gather"] == 16 * 128 * 2
    assert detail["all-reduce"] == 1024 * 4
    assert detail["all-to-all"] == 2 * 4 * 8 * 2
    assert detail["collective-permute"] == 2 * 4
    assert counts["all-gather"] == 1


def test_roofline_terms():
    from repro.launch.roofline import Roofline, PEAK_FLOPS, HBM_BW
    r = Roofline(PEAK_FLOPS, HBM_BW * 2, 0.0, {}, {}, 256)
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 2.0) < 1e-9
    assert r.dominant == "memory"


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("smollm-135m", "train_4k"),
    ("mamba2-780m", "decode_32k"),
])
def test_dryrun_subprocess_small_mesh(arch, shape):
    """Lower+compile on the 2x2 test mesh in a fresh process."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "test", "--out",
         "/tmp/dryrun_test_out"],
        env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    path = f"/tmp/dryrun_test_out/{arch}__{shape}__test.json"
    with open(path) as f:
        res = json.load(f)
    assert res["roofline"]["flops_per_device"] > 0
    assert res["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_input_specs_all_pairs_shape_only():
    """input_specs/cache_shapes build for all 40 pairs without allocation."""
    from repro.configs.base import ARCH_IDS, SHAPES, get_config
    from repro.launch.dryrun import adapt_config
    from repro.launch import steps as ST
    for arch in ARCH_IDS:
        for shape in SHAPES:
            cfg, _ = adapt_config(arch, shape)
            batch = ST.input_specs(cfg, shape)
            assert all(isinstance(v, jax.ShapeDtypeStruct)
                       for v in batch.values())
            if SHAPES[shape].kind == "decode":
                cs = ST.cache_shapes(cfg, shape)
                n_bytes = sum(np.prod(l.shape) * l.dtype.itemsize
                              for l in jax.tree.leaves(cs))
                assert n_bytes > 0
