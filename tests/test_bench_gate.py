"""The CI bench-regression gate (benchmarks/regression_check.py): gating
rules — only *_ms metrics gate, missing gated metrics fail, new metrics are
informational — and the checked-in baseline staying in sync with the smoke
set the bench job emits."""
import importlib.util
import json
import pathlib

REPO = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "regression_check", REPO / "benchmarks" / "regression_check.py")
regression_check = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(regression_check)
compare = regression_check.compare


def test_gate_passes_identical_runs():
    base = {"a_p999_ms": 40.0, "a_median_ms": 25.0, "a_reconstructions": 17}
    rows, failures = compare(dict(base), base, threshold=0.25)
    assert not failures
    # counters are informational: not among gated rows
    assert not any(r.startswith("a_reconstructions") for r in rows)


def test_gate_trips_on_regression_but_tolerates_threshold():
    base = {"x_p999_ms": 100.0}
    _, failures = compare({"x_p999_ms": 124.9}, base, threshold=0.25)
    assert not failures                         # +24.9% is within budget
    _, failures = compare({"x_p999_ms": 126.0}, base, threshold=0.25)
    assert failures and "x_p999_ms" in failures[0]   # +26% trips
    _, failures = compare({"x_p999_ms": 10.0}, base, threshold=0.25)
    assert not failures                         # improvements never trip


def test_gate_fails_on_missing_metric_and_reports_new_ones():
    base = {"x_p999_ms": 100.0, "y_median_ms": 10.0}
    cur = {"y_median_ms": 10.0, "z_p999_ms": 5.0}
    rows, failures = compare(cur, base, threshold=0.25)
    assert any("missing" in f for f in failures)
    assert any(r.startswith("z_p999_ms,NEW") for r in rows)


def test_checked_in_baseline_matches_smoke_metric_set():
    """The baseline must cover exactly the metrics the smoke bench emits —
    a drifted baseline would silently un-gate part of the sweep.  (Values
    are compared in CI by the bench job itself; here we pin the *schema*,
    which also proves the gate is exercised with the current registry —
    learned and approx_backup metrics included.)"""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        metrics = json.load(f)["metrics"]
    from repro.core.scheme import available_schemes
    for scheme in available_schemes():
        assert f"smoke_scheme_{scheme}_p999_ms" in metrics, scheme
    for strat in ("parm", "equal_resources", "replication", "none"):
        assert f"smoke_{strat}_p999_ms" in metrics, strat
    assert "smoke_r2_correlated_p999_ms" in metrics
    for b in (1, 2, 4):
        assert f"smoke_batch{b}_p999_ms" in metrics, b
    assert all(isinstance(v, (int, float)) for v in metrics.values())


def test_baseline_shows_adaptive_batching_improves_overloaded_tail():
    """The batching sweep exists to document that max_size > 1 stabilizes
    the overloaded deployment: the checked-in baseline itself must show the
    batched smoke runs beating the unbatched one by a wide margin."""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        metrics = json.load(f)["metrics"]
    assert metrics["smoke_batch4_p999_ms"] < metrics["smoke_batch1_p999_ms"] / 2
    assert metrics["smoke_batch2_p999_ms"] < metrics["smoke_batch1_p999_ms"] / 2
