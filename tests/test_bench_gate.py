"""The CI bench-regression gate (benchmarks/regression_check.py): gating
rules — *_ms metrics gate as upper bounds, *_eps throughput metrics as
lower bounds, missing gated metrics fail, new metrics are informational —
exit codes and the $GITHUB_STEP_SUMMARY markdown rendering, and the
checked-in baseline staying in sync with the smoke set the bench job
emits."""
import importlib.util
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

_spec = importlib.util.spec_from_file_location(
    "regression_check", REPO / "benchmarks" / "regression_check.py")
regression_check = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(regression_check)
compare = regression_check.compare


def test_gate_passes_identical_runs():
    base = {"a_p999_ms": 40.0, "a_median_ms": 25.0, "a_reconstructions": 17}
    rows, failures = compare(dict(base), base, threshold=0.25)
    assert not failures
    # counters are informational: not among gated rows
    assert not any(r.startswith("a_reconstructions") for r in rows)


def test_gate_trips_on_regression_but_tolerates_threshold():
    base = {"x_p999_ms": 100.0}
    _, failures = compare({"x_p999_ms": 124.9}, base, threshold=0.25)
    assert not failures                         # +24.9% is within budget
    _, failures = compare({"x_p999_ms": 126.0}, base, threshold=0.25)
    assert failures and "x_p999_ms" in failures[0]   # +26% trips
    _, failures = compare({"x_p999_ms": 10.0}, base, threshold=0.25)
    assert not failures                         # improvements never trip


def test_gate_fails_on_missing_metric_and_reports_new_ones():
    base = {"x_p999_ms": 100.0, "y_median_ms": 10.0}
    cur = {"y_median_ms": 10.0, "z_p999_ms": 5.0}
    rows, failures = compare(cur, base, threshold=0.25)
    assert any("missing" in f for f in failures)
    assert any(r.startswith("z_p999_ms,NEW") for r in rows)


def test_eps_metrics_gate_as_lower_bounds():
    """*_eps (events/sec — simulator throughput) fails only when current
    throughput DROPS below baseline by more than --eps-threshold; gains
    and wall-clock noise within the floor never trip."""
    base = {"tenmillion_sum_r1_eps": 1_000_000.0}
    _, failures = compare({"tenmillion_sum_r1_eps": 560_000.0}, base,
                          threshold=0.25, eps_threshold=0.45)
    assert not failures                         # -44%: inside the floor
    _, failures = compare({"tenmillion_sum_r1_eps": 540_000.0}, base,
                          threshold=0.25, eps_threshold=0.45)
    assert failures and "tenmillion_sum_r1_eps" in failures[0]
    _, failures = compare({"tenmillion_sum_r1_eps": 3_000_000.0}, base,
                          threshold=0.25, eps_threshold=0.45)
    assert not failures                         # speedups never trip
    # missing from the current run fails, like any gated metric
    _, failures = compare({}, base, threshold=0.25, eps_threshold=0.45)
    assert failures and "missing" in failures[0]
    # informational metrics (e.g. *_wall_s) still never gate
    base2 = {"tenmillion_sum_r1_wall_s": 20.0}
    rows, failures = compare({"tenmillion_sum_r1_wall_s": 500.0}, base2,
                             threshold=0.25, eps_threshold=0.45)
    assert not failures
    assert not any(r.startswith("tenmillion_sum_r1_wall_s,20") for r in rows)


def test_gate_exact_threshold_boundary_is_inclusive():
    """ratio == 1 + threshold passes (<=); the first representable step
    beyond it trips — the boundary must not drift with a refactor."""
    base = {"x_p999_ms": 100.0}
    _, failures = compare({"x_p999_ms": 125.0}, base, threshold=0.25)
    assert not failures                         # exactly +25%: ok
    _, failures = compare({"x_p999_ms": 125.00001}, base, threshold=0.25)
    assert failures                             # one step past: trips


def test_gate_map_informational_never_gates():
    """A per-metric {"informational": true} override silences even suffix-
    gated metrics — autotune block picks and other reported-only values."""
    base = {"x_p999_ms": 100.0, "kernel_fused_autotune_block_b": 8}
    gates = {"x_p999_ms": {"informational": True},
             "kernel_fused_autotune_block_b": {"informational": True}}
    rows, failures = compare({"x_p999_ms": 900.0,
                              "kernel_fused_autotune_block_b": 16},
                             base, threshold=0.25, gates=gates)
    assert not failures
    assert not any(r.startswith("x_p999_ms") for r in rows)


def test_gate_map_per_metric_threshold():
    """{"threshold": t} gates a metric at its own band — wide for wall-clock
    kernel timings — and forces gating for metrics the suffix rules would
    skip.  A gated metric missing from the current run still fails."""
    base = {"kernel_fused_encode_forward_r1_us": 100.0}
    gates = {"kernel_fused_encode_forward_r1_us": {"threshold": 3.0}}
    _, failures = compare({"kernel_fused_encode_forward_r1_us": 390.0},
                          base, threshold=0.25, gates=gates)
    assert not failures                      # +290% inside the 300% band
    _, failures = compare({"kernel_fused_encode_forward_r1_us": 410.0},
                          base, threshold=0.25, gates=gates)
    assert failures and "kernel_fused_encode_forward_r1_us" in failures[0]
    _, failures = compare({}, base, threshold=0.25, gates=gates)
    assert failures and "missing" in failures[0]


def test_gate_map_absolute_max_bound():
    """{"max": M} is an absolute bound on the current value — how the
    fused/unfused time ratios pin fused <= unfused regardless of baseline
    drift (a ratio metric's baseline value is itself noisy)."""
    base = {"kernel_fused_encode_forward_r1_ratio": 0.2}
    gates = {"kernel_fused_encode_forward_r1_ratio": {"max": 1.0}}
    _, failures = compare({"kernel_fused_encode_forward_r1_ratio": 0.97},
                          base, threshold=0.25, gates=gates)
    assert not failures            # 4.8x the baseline ratio, still <= max
    _, failures = compare({"kernel_fused_encode_forward_r1_ratio": 1.02},
                          base, threshold=0.25, gates=gates)
    assert failures and "absolute bound" in failures[0]
    _, failures = compare({}, base, threshold=0.25, gates=gates)
    assert failures and "missing" in failures[0]


def _run_gate(tmp_path, current, baseline, *args, env_extra=None):
    import os
    cur, base = tmp_path / "cur.json", tmp_path / "base.json"
    for path, content in ((cur, current), (base, baseline)):
        path.write_text(content if isinstance(content, str)
                        else json.dumps(content if "metrics" in content
                                        else {"metrics": content}))
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    # never inherit a real Actions summary file: the gate auto-appends to
    # $GITHUB_STEP_SUMMARY, and these deliberate pass/regress runs must
    # not write tables into the CI test job's own Summary tab
    env.pop("GITHUB_STEP_SUMMARY", None)
    if env_extra:
        env.update(env_extra)
    return subprocess.run(
        [sys.executable, str(REPO / "benchmarks" / "regression_check.py"),
         str(cur), str(base), *args],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_gate_exit_codes(tmp_path):
    """0 = pass, 1 = regression, 2 = gate could not run (malformed or
    missing input) — CI distinguishes 'bench regressed' from 'bench
    broke'."""
    ok = _run_gate(tmp_path, {"a_p999_ms": 10.0}, {"a_p999_ms": 10.0})
    assert ok.returncode == 0, ok.stderr
    trip = _run_gate(tmp_path, {"a_p999_ms": 20.0}, {"a_p999_ms": 10.0})
    assert trip.returncode == 1
    assert "BENCH REGRESSION" in trip.stderr
    broken = _run_gate(tmp_path, "{not json", {"a_p999_ms": 10.0})
    assert broken.returncode == 2
    assert "malformed" in broken.stderr
    # a metrics-less but valid JSON document is also "cannot run"
    nokey = _run_gate(tmp_path, '{"foo": 1}', {"a_p999_ms": 10.0})
    assert nokey.returncode == 2


def test_gate_writes_markdown_step_summary(tmp_path):
    """--markdown (and $GITHUB_STEP_SUMMARY) render the per-metric
    baseline/current/delta table — regressions readable in the Actions
    Summary tab without downloading artifacts."""
    md = tmp_path / "summary.md"
    res = _run_gate(tmp_path,
                    {"a_p999_ms": 20.0, "b_median_ms": 5.0, "c_p99_ms": 1.0},
                    {"a_p999_ms": 10.0, "b_median_ms": 5.0},
                    "--markdown", str(md))
    assert res.returncode == 1
    text = md.read_text()
    assert "| metric | baseline | current | delta % | status |" in text
    assert "REGRESSED" in text and "+100.0%" in text
    assert "`c_p99_ms`" in text and "new" in text
    # the env-var path appends to the same file format
    md2 = tmp_path / "gha.md"
    res2 = _run_gate(tmp_path, {"a_p999_ms": 10.0}, {"a_p999_ms": 10.0},
                     env_extra={"GITHUB_STEP_SUMMARY": str(md2)})
    assert res2.returncode == 0
    assert "Bench gate" in md2.read_text()


def test_gate_honors_baseline_gate_map_end_to_end(tmp_path):
    """The CLI reads the per-metric override map from the BASELINE
    document's top-level "gate" key: a ratio past its absolute max trips
    (exit 1) while a 2x wall-clock move inside its wide band passes."""
    baseline = {"metrics": {"kernel_multigroup_decode_ratio": 0.3,
                            "kernel_multigroup_decode_us": 1000.0},
                "gate": {"kernel_multigroup_decode_ratio": {"max": 1.0},
                         "kernel_multigroup_decode_us": {"threshold": 3.0}}}
    ok = _run_gate(tmp_path, {"kernel_multigroup_decode_ratio": 0.9,
                              "kernel_multigroup_decode_us": 2000.0},
                   baseline)
    assert ok.returncode == 0, ok.stderr
    trip = _run_gate(tmp_path, {"kernel_multigroup_decode_ratio": 1.4,
                                "kernel_multigroup_decode_us": 2000.0},
                     baseline)
    assert trip.returncode == 1
    assert "absolute bound" in trip.stderr
    # a malformed gate map is "cannot run", not a silent un-gating
    bad = dict(baseline, gate="not-a-map")
    broken = _run_gate(tmp_path, {"kernel_multigroup_decode_ratio": 0.9,
                                  "kernel_multigroup_decode_us": 2000.0},
                      bad)
    assert broken.returncode == 2


def test_accuracy_ranking_table_renders_sorted():
    """The acc_unavail_* metrics the accuracy smoke lane merges into
    BENCH_ci.json render as their own A_d ranking section (they are
    informational in the gate, so compare() never rows them)."""
    cur = {"acc_unavail_Aa": 0.99, "acc_unavail_sum_Ad": 0.24,
           "acc_unavail_fisher_Ad": 0.94, "acc_unavail_invnet_Ad": 0.94,
           "smoke_parm_p999_ms": 10.0}
    md = regression_check.accuracy_ranking_table(cur)
    assert "A_d scheme ranking" in md and "A_a = 0.990" in md
    assert md.index("`fisher`") < md.index("`sum`")     # ranked descending
    assert "+0.000" in md and "-0.700" in md
    # ties break alphabetically so the table is deterministic
    assert md.index("`fisher`") < md.index("`invnet`")
    # no accuracy metrics -> no section at all
    assert regression_check.accuracy_ranking_table(
        {"smoke_parm_p999_ms": 10.0}) == ""


def test_gate_appends_accuracy_ranking_to_step_summary(tmp_path):
    md = tmp_path / "summary.md"
    res = _run_gate(tmp_path,
                    {"a_p999_ms": 10.0, "acc_unavail_Aa": 0.99,
                     "acc_unavail_fisher_Ad": 0.94,
                     "acc_unavail_sum_Ad": 0.24},
                    {"a_p999_ms": 10.0}, "--markdown", str(md))
    assert res.returncode == 0, res.stderr
    text = md.read_text()
    assert "Bench gate" in text
    assert "A_d scheme ranking" in text and "`fisher`" in text


def test_checked_in_baseline_covers_accuracy_lane():
    """Every registered scheme must have a baseline A_d entry (the
    accuracy lane sweeps the registry), gated informational — accuracy at
    smoke scale moves with training noise.  The recorded baseline itself
    must show the training-free fisher scheme at or above the distilled
    sum baseline (the PR-10 acceptance bar)."""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        doc = json.load(f)
    metrics, gate = doc["metrics"], doc["gate"]
    from repro.eval.unavailability import DEFAULT_SCHEMES
    assert "acc_unavail_Aa" in metrics
    for scheme in DEFAULT_SCHEMES:
        name = f"acc_unavail_{scheme}_Ad"
        assert name in metrics, scheme
        assert gate[name] == {"informational": True}, name
    assert gate["acc_unavail_Aa"] == {"informational": True}
    assert metrics["acc_unavail_fisher_Ad"] >= metrics["acc_unavail_sum_Ad"]


def test_checked_in_baseline_gates_kernel_lane():
    """The kernel bench lane (DESIGN.md §12): the checked-in baseline must
    carry the kernel_* smoke metrics AND the gate map that pins the fused
    paths — fused <= unfused locked by max-1.0 ratio bounds, wall-clocks
    on a wide band, autotune picks informational."""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        doc = json.load(f)
    metrics, gate = doc["metrics"], doc["gate"]
    for r in (1, 2):
        assert f"kernel_fused_encode_forward_r{r}_us" in metrics
        assert f"kernel_unfused_encode_forward_r{r}_us" in metrics
        ratio = f"kernel_fused_encode_forward_r{r}_ratio"
        assert gate[ratio] == {"max": 1.0}
        # the recorded baseline itself shows fused beating unfused
        assert metrics[ratio] <= 1.0, (ratio, metrics[ratio])
    assert gate["kernel_multigroup_decode_ratio"] == {"max": 1.0}
    assert metrics["kernel_multigroup_decode_ratio"] <= 1.0
    assert "kernel_pergroup_decode_us" in metrics
    for backend in ("jnp", "pallas"):
        assert f"kernel_parity_encode_{backend}_us" in metrics
        assert f"kernel_parity_decode_{backend}_us" in metrics
    for name, spec in gate.items():
        assert name in metrics, f"gate entry {name} has no baseline metric"
        if name.endswith("_us"):
            assert spec.get("threshold", 0) >= 1.0, (name, spec)
    for blk in ("block_b", "block_f"):
        assert gate[f"kernel_fused_autotune_{blk}"] == \
            {"informational": True}


def test_checked_in_baseline_matches_smoke_metric_set():
    """The baseline must cover exactly the metrics the smoke bench emits —
    a drifted baseline would silently un-gate part of the sweep.  (Values
    are compared in CI by the bench job itself; here we pin the *schema*,
    which also proves the gate is exercised with the current registry —
    learned and approx_backup metrics included.)"""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        metrics = json.load(f)["metrics"]
    from repro.core.scheme import available_schemes
    for scheme in available_schemes():
        assert f"smoke_scheme_{scheme}_p999_ms" in metrics, scheme
    for strat in ("parm", "equal_resources", "replication", "none"):
        assert f"smoke_{strat}_p999_ms" in metrics, strat
    assert "smoke_r2_correlated_p999_ms" in metrics
    for b in (1, 2, 4):
        assert f"smoke_batch{b}_p999_ms" in metrics, b
    # the Byzantine trend: latency metrics gate, the detection/correction
    # counters ride as informational accuracy signals
    for scheme in ("approxifer", "sum"):
        assert f"smoke_byzantine_{scheme}_p999_ms" in metrics, scheme
        assert f"smoke_byzantine_{scheme}_corrupted_detected" in metrics
        assert f"smoke_byzantine_{scheme}_corrected" in metrics
    assert metrics["smoke_byzantine_approxifer_corrupted_detected"] > 0
    assert metrics["smoke_byzantine_sum_corrupted_detected"] == 0
    # the adaptive-controller pair: gated latency on both sides, with the
    # parity_served/adjustments counters riding as informational resource
    # signals
    for scen in ("bursty", "storm"):
        for tag in ("adaptive", "static_r1"):
            assert f"smoke_{tag}_{scen}_p999_ms" in metrics, (tag, scen)
            assert f"smoke_{tag}_{scen}_parity_served" in metrics, (tag, scen)
        assert f"smoke_adaptive_{scen}_adjustments" in metrics, scen
    # trace-driven / multi-tenant workloads (DESIGN.md §11)
    for scen in ("diurnal", "flash_crowd"):
        assert f"smoke_{scen}_p999_ms" in metrics, scen
    for tenant in ("gold", "free"):
        assert f"smoke_tenants_{tenant}_p999_ms" in metrics, tenant
        assert f"smoke_tenants_{tenant}_slo_violations" in metrics, tenant
    # the utilization frontier grid and the 10M-query hot-loop speed lock
    for scheme in ("sum", "replication", "approxifer"):
        for util in (55, 70, 85):
            assert f"smoke_frontier_{scheme}_u{util}_p999_ms" in metrics, \
                (scheme, util)
    assert "tenmillion_sum_r1_p999_ms" in metrics
    assert "tenmillion_sum_r1_eps" in metrics
    assert "tenmillion_sum_r1_wall_s" in metrics
    assert all(isinstance(v, (int, float)) for v in metrics.values())


def test_baseline_shows_frontier_ordering_and_hot_loop_speed():
    """The frontier grid exists to document how each code's tail grows
    with utilization (monotone per scheme), and the 10M point locks the
    vectorized hot loop: under 30 s wall and above 0.5M events/sec in the
    recorded baseline."""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        metrics = json.load(f)["metrics"]
    for scheme in ("sum", "replication", "approxifer"):
        p = [metrics[f"smoke_frontier_{scheme}_u{u}_p999_ms"]
             for u in (55, 70, 85)]
        # at smoke scale the p999 of 8k queries is an order statistic over
        # ~8 samples — the middle point is noisy, but the hot end of the
        # frontier must sit above the cool end
        assert p[2] > p[0], (scheme, p)
    assert metrics["tenmillion_sum_r1_wall_s"] < 30.0
    assert metrics["tenmillion_sum_r1_eps"] > 500_000.0


def test_baseline_shows_adaptive_controller_beats_static_tail():
    """The controller smoke pair exists to document frontier dominance on
    episodic fault scenarios: the checked-in baseline itself must show the
    closed-loop run beating the static r=1 deployment's tail while having
    actually adjusted (a baseline where the controller never fired would
    gate nothing)."""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        metrics = json.load(f)["metrics"]
    for scen in ("bursty", "storm"):
        assert (metrics[f"smoke_adaptive_{scen}_p999_ms"]
                < metrics[f"smoke_static_r1_{scen}_p999_ms"]), scen
        assert metrics[f"smoke_adaptive_{scen}_adjustments"] >= 1, scen


def test_baseline_shows_adaptive_batching_improves_overloaded_tail():
    """The batching sweep exists to document that max_size > 1 stabilizes
    the overloaded deployment: the checked-in baseline itself must show the
    batched smoke runs beating the unbatched one by a wide margin."""
    with open(REPO / "benchmarks" / "BENCH_baseline.json") as f:
        metrics = json.load(f)["metrics"]
    assert metrics["smoke_batch4_p999_ms"] < metrics["smoke_batch1_p999_ms"] / 2
    assert metrics["smoke_batch2_p999_ms"] < metrics["smoke_batch1_p999_ms"] / 2
