"""ApproxIFER-style rational-interpolation scheme ("approxifer"): node
geometry, exactness of the dynamic-arity decoder on polynomial data, the
Byzantine vote, the Pallas encode kernel, and the no-training pipeline.

The differential battery (tests/test_differential.py) covers the serving
layers; this file pins the scheme object itself.
"""
from itertools import combinations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.approxifer import (ApproxIFERScheme, chebyshev_nodes,
                                   lagrange_eval_matrix, split_nodes)
from repro.core.scheme import get_scheme, recoverable_rows


def _ideal(scheme, outs):
    """Ideal parity outputs: the output trajectory is the degree-(k-1)
    interpolant of the member outputs, sampled at the parity nodes — for a
    linear deployed model that is exactly what the parity pool returns."""
    return jnp.einsum("rk,k...->r...",
                      jnp.asarray(scheme.coeffs, jnp.float32), outs)


@pytest.mark.parametrize("k,r", [(2, 1), (2, 2), (3, 1), (3, 2), (4, 2),
                                 (4, 3), (5, 1), (6, 2)])
def test_nodes_distinct_and_coeffs_partition_unity(k, r):
    """Member and parity nodes come off one combined Chebyshev grid (all
    distinct, interleaved), and every encode row is a Lagrange-basis
    evaluation — rows sum to 1 (partition of unity), so encoding a
    constant group yields that constant."""
    z, w = split_nodes(k, r)
    nodes = np.concatenate([z, w])
    assert len(np.unique(nodes)) == k + r
    assert len(z) == k and len(w) == r
    scheme = get_scheme("approxifer", k=k, r=r)
    c = np.asarray(scheme.coeffs)
    np.testing.assert_allclose(c.sum(axis=1), np.ones(r), atol=1e-5)
    const = jnp.ones((k, 3))
    np.testing.assert_allclose(np.asarray(scheme.encode(const)),
                               np.ones((r, 3)), atol=1e-5)


def test_lagrange_eval_matrix_interpolates():
    nodes = chebyshev_nodes(5)
    at = np.array([0.3, nodes[2], -0.9])
    L = lagrange_eval_matrix(nodes, at)
    # a degree-4 polynomial is reproduced exactly at every evaluation point
    coef = np.array([0.5, -1.0, 2.0, 0.3, -0.7])
    p = np.polynomial.polynomial.polyval(nodes, coef)
    want = np.polynomial.polynomial.polyval(at, coef)
    np.testing.assert_allclose(L @ p, want, atol=1e-10)
    # hitting a node exactly returns that node's value (indicator row)
    np.testing.assert_allclose(L[1], np.eye(5)[2], atol=1e-12)


@pytest.mark.parametrize("k,r", [(2, 2), (3, 2), (4, 2), (4, 3)])
def test_decode_adapts_to_any_arrival_pattern(k, r):
    """Dynamic arity: for EVERY split of e <= r losses across members and
    parities, the decoder reconstructs the missing members exactly from
    whichever >= k responses arrived — one deployment, every pattern, no
    retraining."""
    scheme = get_scheme("approxifer", k=k, r=r)
    rng = np.random.default_rng(7 * k + r)
    outs = jnp.asarray(rng.normal(size=(k, 6)).astype(np.float32))
    parity = _ideal(scheme, outs)
    n = k + r
    for e in range(1, r + 1):
        for lost in combinations(range(n), e):
            miss = np.zeros(k, bool)
            pa = np.ones(r, bool)
            for t in lost:
                if t < k:
                    miss[t] = True
                else:
                    pa[t - k] = False
            assert recoverable_rows(scheme, miss, pa).sum() == miss.sum()
            corrupted = jnp.where(jnp.asarray(miss)[:, None], 999.0, outs)
            recon = np.asarray(scheme.decode(
                parity * jnp.asarray(pa)[:, None], corrupted,
                jnp.asarray(miss), jnp.asarray(pa)))
            np.testing.assert_allclose(recon, np.asarray(outs), atol=5e-3,
                                       err_msg=f"k={k} r={r} lost={lost}")


def test_all_extra_responses_lost_still_decodes():
    """e = 2 of r = 2 extra responses missing: with every member present
    the decode is a no-op passthrough, and recoverable_rows correctly
    reports nothing recoverable once a member is also missing (arrived <
    k) — the deployment survives losing ALL its redundancy, with zero
    retraining, because the originals are served uncoded."""
    scheme = get_scheme("approxifer", k=2, r=2)
    rng = np.random.default_rng(0)
    outs = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    none = np.zeros(2, bool)
    lost = np.zeros(2, bool)
    recon = np.asarray(scheme.decode(jnp.zeros((2, 4)), outs,
                                     jnp.asarray(none), jnp.asarray(lost)))
    np.testing.assert_allclose(recon, np.asarray(outs), atol=1e-6)
    miss = np.array([True, False])
    assert not recoverable_rows(scheme, miss, lost).any()


def test_decode_one_matches_decode_and_pallas():
    for k in (2, 3, 4):
        rng = np.random.default_rng(k)
        outs = jnp.asarray(rng.normal(size=(k, 2, 6)).astype(np.float32))
        jnp_s = get_scheme("approxifer", k=k, r=1)
        pls_s = get_scheme("approxifer", k=k, r=1, backend="pallas")
        parity = _ideal(jnp_s, outs)
        for j in range(k):
            want = np.asarray(outs[j])
            a = np.asarray(jnp_s.decode_one(parity[0], outs, j))
            b = np.asarray(pls_s.decode_one(parity[0], outs, j))
            np.testing.assert_allclose(a, want, atol=5e-3)
            np.testing.assert_allclose(b, want, atol=5e-3)


@pytest.mark.parametrize("k,r,shape", [(2, 1, (3, 8)), (3, 2, (1, 4, 4, 1)),
                                       (4, 2, (2, 130)), (2, 2, (9, 5))])
def test_pallas_encode_matches_jnp(k, r, shape):
    """The berrut_encoder kernel (one launch for all r rows) must agree
    with the jnp reference over lane/sublane-unaligned shapes too."""
    rng = np.random.default_rng(3 * k + r)
    q = jnp.asarray(rng.normal(size=(k,) + shape).astype(np.float32))
    a = np.asarray(get_scheme("approxifer", k=k, r=r).encode(q))
    b = np.asarray(
        get_scheme("approxifer", k=k, r=r, backend="pallas").encode(q))
    assert b.shape == (r,) + shape
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_berrut_encode_op_unbatched_vector():
    q = jnp.asarray(np.random.default_rng(0).normal(size=(3, 7))
                    .astype(np.float32))
    a = np.asarray(get_scheme("approxifer", k=3).encode(q))
    b = np.asarray(get_scheme("approxifer", k=3, backend="pallas").encode(q))
    np.testing.assert_allclose(a, b, atol=1e-4)


# ------------------------------------------------------- Byzantine voting --
def test_flag_errors_votes_out_gross_member_corruption():
    scheme = get_scheme("approxifer", k=2, r=2)
    rng = np.random.default_rng(1)
    outs = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    parity = np.asarray(_ideal(scheme, outs))
    bad = np.asarray(outs).copy()
    bad[1] += 1e3
    mf, pf = scheme.flag_errors(bad, np.ones(2, bool), parity,
                                np.ones(2, bool))
    assert mf.tolist() == [False, True] and not pf.any()


def test_flag_errors_votes_out_corrupt_parity():
    scheme = get_scheme("approxifer", k=2, r=2)
    rng = np.random.default_rng(2)
    outs = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    parity = np.asarray(_ideal(scheme, outs)).copy()
    parity[0] -= 1e3
    mf, pf = scheme.flag_errors(np.asarray(outs), np.ones(2, bool), parity,
                                np.ones(2, bool))
    assert pf.tolist() == [True, False] and not mf.any()


def test_flag_errors_abstains_without_surplus():
    """k + 1 responses cannot localize an error (the 2e-surplus margin):
    the vote must abstain rather than guess."""
    scheme = get_scheme("approxifer", k=2, r=2)
    rng = np.random.default_rng(3)
    outs = jnp.asarray(rng.normal(size=(2, 4)).astype(np.float32))
    parity = np.asarray(_ideal(scheme, outs))
    bad = np.asarray(outs).copy()
    bad[0] += 1e3
    mf, pf = scheme.flag_errors(bad, np.ones(2, bool), parity,
                                np.array([True, False]))
    assert not mf.any() and not pf.any()


def test_flag_errors_clean_group_untouched():
    scheme = get_scheme("approxifer", k=3, r=2)
    rng = np.random.default_rng(4)
    outs = jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32))
    parity = np.asarray(_ideal(scheme, outs))
    mf, pf = scheme.flag_errors(np.asarray(outs), np.ones(3, bool), parity,
                                np.ones(2, bool))
    assert not mf.any() and not pf.any()


def test_max_correctable_margin():
    scheme = get_scheme("approxifer", k=4, r=3)
    assert scheme.max_correctable(4) == 0      # no surplus
    assert scheme.max_correctable(5) == 0      # 1 surplus: detect-only
    assert scheme.max_correctable(6) == 1      # 2e = 2
    assert scheme.max_correctable(7) == 1


# ---------------------------------------------------- no-training pipeline --
def test_train_parity_models_is_a_noop_for_model_agnostic_schemes():
    """approxifer works with the *deployed* model: train_parity_models
    returns r references to the deployed params and never trains."""
    from repro.core.parity import train_parity_models
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(6, 3)).astype(np.float32))
    x = rng.normal(size=(32, 6)).astype(np.float32)
    pp, scheme = train_parity_models(
        W, lambda p, xb: xb @ p, init_fn=None, x_train=x, k=2, r=2,
        scheme="approxifer")
    assert scheme.name == "approxifer" and len(pp) == 2
    for p in pp:
        assert p is W


def test_registry_validation_and_bounds():
    with pytest.raises(ValueError, match="k >= 2"):
        ApproxIFERScheme(k=1)
    with pytest.raises(ValueError, match="r must be"):
        ApproxIFERScheme(k=2, r=0)
    s = get_scheme("approxifer", k=3, r=2)
    assert (s.k, s.r, s.name) == (3, 2, "approxifer")
    with pytest.raises(ValueError, match="backend"):
        ApproxIFERScheme(k=2, backend="cuda")


def test_decode_cost_is_flat_and_encode_cost_linear():
    """Scheme-owned DES hints: one refit serves all missing rows (flat in
    n_missing), encode is one linear pass."""
    from repro.core.scheme import decode_cost, encode_cost
    s = get_scheme("approxifer", k=4, r=2)
    assert decode_cost(s, 1) == decode_cost(s, 2) == 2.0
    assert encode_cost(s) == 1.0
