"""Prefill <-> decode consistency: the cache contract the coded LM serving
engine depends on.

For each layer family (attn, ssm, moe, hybrid), ``decode_step`` run
token-by-token over a sequence must reproduce the logits of a full
teacher-forced ``forward`` — and a scalar-``pos`` decode must be bit-equal
to the vector-``pos`` (slot-batched) decode at the same uniform position,
since the continuous-batching engine always drives the vector path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)

ARCHS = [
    "qwen2-0.5b",              # dense attention + bias + GQA
    "mamba2-780m",             # pure ssm
    "qwen3-moe-235b-a22b",     # moe ffn
    "jamba-1.5-large-398b",    # hybrid attn/mamba + moe
]


def _cfg(arch):
    # capacity_factor bumped so the tiny reduced MoE never drops tokens —
    # same stance as test_archs_smoke
    return get_config(arch, reduced=True).replace(capacity_factor=8.0)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_token_by_token_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = T.init_params(cfg, KEY)
    B, P, N = 2, 8, 6                  # prompt length, decoded tokens
    S = P + N
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, tokens=toks)
    last, cache = T.prefill(cfg, params, tokens=toks[:, :P], cache_len=S)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, P - 1]), atol=2e-3)
    for t in range(P, S):
        logits, cache = T.decode_step(cfg, params, cache, t,
                                      token=toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-3,
                                   err_msg=f"{arch} diverged at pos {t}")


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-780m"])
def test_vector_pos_decode_bit_equal_to_scalar(arch):
    """decode_step(pos scalar) == decode_step(pos [B] uniform), bit-equal."""
    cfg = _cfg(arch)
    params = T.init_params(cfg, KEY)
    B, P = 2, 8
    toks = jax.random.randint(KEY, (B, P + 1), 0, cfg.vocab)
    _, cache = T.prefill(cfg, params, tokens=toks[:, :P], cache_len=P + 4)
    tok = toks[:, P:P + 1]
    log_s, cache_s = T.decode_step(cfg, params, cache, P, token=tok)
    log_v, cache_v = T.decode_step(cfg, params, cache,
                                   jnp.full((B,), P, jnp.int32), token=tok)
    np.testing.assert_array_equal(np.asarray(log_s), np.asarray(log_v))
    for a, b in zip(jax.tree.leaves(cache_s), jax.tree.leaves(cache_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_vector_pos_decode_rows_independent():
    """Each row of a vector-pos decode equals its own solo decode."""
    cfg = _cfg("qwen2-0.5b")
    params = T.init_params(cfg, KEY)
    B, S = 3, 12
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    pos = jnp.array([3, 7, 10], jnp.int32)
    # build a batched cache where row b holds a prefill of toks[b, :pos[b]]
    cache = T.init_cache(cfg, B, S)
    for b in range(B):
        _, cb = T.prefill(cfg, params, tokens=toks[b:b + 1, :int(pos[b])],
                          cache_len=S)
        cache = jax.tree.map(
            lambda pool, one, b=b: pool.at[:, b:b + 1].set(one), cache, cb)
    tok = jnp.take_along_axis(toks, pos[:, None], axis=1)
    log_v, _ = T.decode_step(cfg, params, cache, pos, token=tok)
    for b in range(B):
        _, cb = T.prefill(cfg, params, tokens=toks[b:b + 1, :int(pos[b])],
                          cache_len=S)
        log_b, _ = T.decode_step(cfg, params, cb, int(pos[b]),
                                 token=tok[b:b + 1])
        np.testing.assert_allclose(np.asarray(log_v[b]), np.asarray(log_b[0]),
                                   atol=2e-4, rtol=2e-4)
