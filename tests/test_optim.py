"""Optimizer + checkpoint unit tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import load, save
from repro.training.optim import (AdamConfig, adam_init, adam_update,
                                  global_norm)


def _numpy_adam(params, grads, m, v, step, cfg):
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        g = grads[k]
        m_new = cfg.b1 * m[k] + (1 - cfg.b1) * g
        v_new = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        mh = m_new / (1 - cfg.b1 ** step)
        vh = v_new / (1 - cfg.b2 ** step)
        delta = mh / (np.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * params[k]
        out_p[k] = params[k] - cfg.lr * delta
        out_m[k], out_v[k] = m_new, v_new
    return out_p, out_m, out_v


def test_adam_matches_numpy_reference():
    cfg = AdamConfig(lr=1e-2, weight_decay=0.01)
    rng = np.random.default_rng(0)
    params = {"a": rng.normal(size=(4, 3)).astype(np.float32),
              "b": rng.normal(size=(5,)).astype(np.float32)}
    jparams = jax.tree.map(jnp.asarray, params)
    state = adam_init(jparams, cfg)
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(vv) for k, vv in params.items()}
    for step in range(1, 4):
        grads = {k: rng.normal(size=vv.shape).astype(np.float32)
                 for k, vv in params.items()}
        jparams, state = adam_update(jax.tree.map(jnp.asarray, grads),
                                     state, jparams, cfg)
        params, m, v = _numpy_adam(params, grads, m, v, step, cfg)
        for k in params:
            np.testing.assert_allclose(np.asarray(jparams[k]), params[k],
                                       atol=1e-5)


def test_grad_clip():
    cfg = AdamConfig(lr=0.0, grad_clip=1.0)   # lr 0: only clip matters
    params = {"a": jnp.zeros((3,))}
    state = adam_init(params, cfg)
    g = {"a": jnp.full((3,), 100.0)}
    # after clip the global norm of applied grads is 1: verify moments
    _, state = adam_update(g, state, params, cfg)
    mu = state["mu"]["a"]
    np.testing.assert_allclose(float(jnp.linalg.norm(mu / 0.1)), 1.0,
                               rtol=1e-4)


def test_structural_tuples_survive_update():
    """The param tree contains structural tuples (layer stacks) — the
    flatten-based update must not confuse them with leaves."""
    cfg = AdamConfig(lr=1e-2)
    params = {"blocks": ({"w": jnp.ones((2, 2))}, {"w": jnp.ones((3,))})}
    state = adam_init(params, cfg)
    grads = jax.tree.map(jnp.ones_like, params)
    new_p, state = adam_update(grads, state, params, cfg)
    assert isinstance(new_p["blocks"], tuple)
    assert new_p["blocks"][0]["w"].shape == (2, 2)
    assert float(jnp.abs(new_p["blocks"][0]["w"] - 1.0).max()) > 0


def test_bf16_moments():
    cfg = AdamConfig(moment_dtype="bfloat16")
    params = {"a": jnp.ones((4,), jnp.bfloat16)}
    state = adam_init(params, cfg)
    assert state["mu"]["a"].dtype == jnp.bfloat16
    grads = {"a": jnp.ones((4,), jnp.bfloat16)}
    new_p, state = adam_update(grads, state, params, cfg)
    assert new_p["a"].dtype == jnp.bfloat16
    assert state["mu"]["a"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    params = {"blocks": ({"w": jnp.arange(6.0).reshape(2, 3)},),
              "embed": jnp.ones((4, 2), jnp.bfloat16)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save(path, params, step=7)
    restored, meta = load(path, params)
    assert meta["step"] == 7
    np.testing.assert_allclose(np.asarray(restored["blocks"][0]["w"]),
                               np.asarray(params["blocks"][0]["w"]))
    assert restored["embed"].dtype == np.dtype("bfloat16") or \
        restored["embed"].dtype == params["embed"].dtype


def test_global_norm():
    t = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((16,)) * 1.0}
    np.testing.assert_allclose(float(global_norm(t)),
                               np.sqrt(9 * 4 + 16), rtol=1e-6)
