"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward and one train step on CPU; output
shapes and finiteness asserted. Decode-path consistency is covered for one
arch per family (cheaper; full 10-arch decode consistency was validated
during bring-up and is exercised again by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.training.optim import AdamConfig, adam_init
from repro.training.train_lib import make_train_step

# ~40s of per-arch compile+step work: full-suite lane only
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["cross_embeds"] = 0.1 * jax.random.normal(
            KEY, (B, cfg.n_modality_tokens, cfg.d_model))
    if cfg.enc_dec:
        batch["frames"] = 0.1 * jax.random.normal(KEY, (B, S, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    kw = {}
    if cfg.family == "vlm":
        kw["cross_embeds"] = batch["cross_embeds"]
    if cfg.enc_dec:
        kw["cross_embeds"] = batch["frames"]
    logits, aux = T.forward(cfg, params, tokens=batch["tokens"], **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(aux)), arch

    opt_cfg = AdamConfig(lr=1e-3)
    step = make_train_step(cfg, opt_cfg, remat=False)
    opt_state = adam_init(params, opt_cfg)
    new_params, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0, arch


@pytest.mark.parametrize("arch", [
    "qwen2-0.5b",              # dense + bias + GQA
    "mamba2-780m",             # ssm
    "jamba-1.5-large-398b",    # hybrid + moe
    "seamless-m4t-medium",     # enc-dec
    "llama-3.2-vision-11b",    # vlm cross-attn
])
def test_decode_consistency(arch):
    cfg = get_config(arch, reduced=True).replace(capacity_factor=8.0)
    params = T.init_params(cfg, KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S + 1)
    kw = {}
    if cfg.family == "vlm":
        kw["cross_embeds"] = batch["cross_embeds"]
    if cfg.enc_dec:
        kw["cross_embeds"] = batch["frames"]
    full, _ = T.forward(cfg, params, tokens=batch["tokens"], **kw)
    last, cache = T.prefill(cfg, params, tokens=batch["tokens"][:, :S],
                            cache_len=S + 4, **kw)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, S - 1]), atol=2e-3)
    logits, cache = T.decode_step(cfg, params, cache, S,
                                  token=batch["tokens"][:, S:S + 1])
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, S]), atol=2e-3)


def test_sliding_window_decode():
    """Ring-buffer sliding-window decode agrees with teacher forcing."""
    cfg = get_config("smollm-135m", reduced=True).replace(sliding_window=8)
    params = T.init_params(cfg, KEY)
    B, S = 1, 16
    toks = jax.random.randint(KEY, (B, S + 3), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, tokens=toks)
    last, cache = T.prefill(cfg, params, tokens=toks[:, :S])
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, S - 1]), atol=2e-3)
    for t in range(3):
        logits, cache = T.decode_step(cfg, params, cache, S + t,
                                      token=toks[:, S + t:S + t + 1])
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, S + t]), atol=2e-3)


def test_config_exactness():
    """Full configs match the assignment table."""
    spec = {
        "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 128256),
        "seamless-m4t-medium": (12, 1024, 16, 16, 256206),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536),
        "smollm-135m": (30, 576, 9, 3, 49152),
        "olmo-1b": (16, 2048, 16, 16, 50304),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 151936),
        "qwen2-0.5b": (24, 896, 14, 2, 151936),
        "mamba2-780m": (48, 1536, 0, 0, 50280),
    }
    for arch, (L, D, H, KV, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.vocab) == (L, D, H, KV, V), arch
    moe = get_config("deepseek-moe-16b")
    assert (moe.n_experts, moe.moe_top_k, moe.n_shared_experts) == (64, 6, 2)
    q3 = get_config("qwen3-moe-235b-a22b")
    assert (q3.n_experts, q3.moe_top_k) == (128, 8)
    jb = get_config("jamba-1.5-large-398b")
    assert (jb.n_experts, jb.moe_top_k, jb.attn_every) == (16, 2, 8)
    mb = get_config("mamba2-780m")
    assert mb.ssm_state == 128


def test_param_counts_in_range():
    """Full-config param counts are in the ballpark of the model names."""
    from repro.launch.steps import n_params_of, param_shapes
    expect = {"smollm-135m": (0.10e9, 0.20e9),
              "qwen2-0.5b": (0.4e9, 0.7e9),
              "olmo-1b": (0.9e9, 1.5e9),
              "mamba2-780m": (0.6e9, 1.0e9),
              "qwen3-4b": (3.5e9, 5.0e9),
              "deepseek-moe-16b": (14e9, 20e9),
              "jamba-1.5-large-398b": (330e9, 430e9),
              "qwen3-moe-235b-a22b": (200e9, 260e9)}
    for arch, (lo, hi) in expect.items():
        n = n_params_of(param_shapes(get_config(arch)))
        assert lo <= n <= hi, (arch, n)
