"""Closed-loop adaptive-redundancy controller tests (serving/controller.py).

Covers the controller protocol + registry, the empty-window-safe rate
guards shared by ``ServingReport`` and ``ReportWindow``, the threshold /
hysteresis decision policies as pure functions of window sequences, the
no-op equivalence of the ``static`` controller through the DES, and the
PR's deliverable: on the ``bursty`` and ``storm`` regimes the adaptive
deployment strictly dominates every static (scheme, r) configuration on
the p999-latency-vs-parity-resource frontier (seeded DES, deterministic).
"""
import math

import pytest

from repro.serving.controller import (Adjustment, HysteresisController,
                                      StaticController, ThresholdController,
                                      available_controllers, get_controller,
                                      list_controllers, register_controller)
from repro.serving.report import ReportWindow, ServingReport, build_window
from repro.serving.simulator import SimConfig, simulate


# ----------------------------------------------------- registry round-trips --
def test_every_registry_lists_resolvable_names():
    """The introspection helpers' contract: every listed name resolves
    through the matching getter — controllers enumerate their candidate
    actions this way, so a listed-but-unresolvable name would break the
    control loop at runtime, not at import."""
    from repro.core.scheme import get_scheme, list_schemes
    from repro.serving.scenarios import get_scenario, list_scenarios
    from repro.serving.strategy import get_strategy, list_strategies

    assert list_schemes() == sorted(list_schemes())
    for name in list_schemes():
        assert get_scheme(name, k=2).name == name
    for name in list_strategies():
        assert get_strategy(name).name == name
    for name in list_scenarios():
        assert get_scenario(name).name == name
    for name in list_controllers():
        assert get_controller(name).name == name
    # the legacy available_* spellings stay aliases of the same lists
    from repro.core.scheme import available_schemes
    from repro.serving.scenarios import available_scenarios
    from repro.serving.strategy import available_strategies
    assert available_schemes() == list_schemes()
    assert available_strategies() == list_strategies()
    assert available_scenarios() == list_scenarios()
    assert available_controllers() == list_controllers()


def test_builtin_controllers_registered():
    assert {"static", "threshold", "hysteresis"} <= set(list_controllers())


def test_register_controller_rejects_silent_replacement():
    with pytest.raises(ValueError, match="already registered"):
        register_controller("threshold", StaticController)
    # same-factory re-registration is a no-op (module re-import safety)
    register_controller("threshold", ThresholdController)
    # and override=True replaces deliberately — restore immediately
    register_controller("threshold", StaticController, override=True)
    register_controller("threshold", ThresholdController, override=True)


def test_get_controller_resolution_and_errors():
    with pytest.raises(KeyError, match="unknown controller"):
        get_controller("nope")
    with pytest.raises(TypeError, match="not a Controller"):
        get_controller(object())
    # instances pass through untouched; kwargs reach the factory
    ctl = ThresholdController(window_ms=250.0)
    assert get_controller(ctl) is ctl
    assert get_controller("threshold", window_ms=250.0).window_ms == 250.0


# ------------------------------------------------- empty-window-safe rates --
def test_empty_report_and_window_rates_are_zero_not_errors():
    """The shared ``_safe_rate`` guard: zero completions means "no
    evidence", reported as 0.0 — never a ZeroDivisionError out of a quiet
    window or an empty run."""
    rep = ServingReport(n=0, reconstructions=0)
    assert rep.straggler_rate == 0.0
    assert rep.corruption_rate == 0.0
    assert rep.cancellation_rate == 0.0
    win = ReportWindow(n=0)
    assert win.straggler_rate == 0.0
    assert win.corruption_rate == 0.0
    assert win.cancellation_rate == 0.0
    built = build_window(3, 0.0, 100.0, [])
    assert built.n == 0
    assert math.isnan(built.p50_ms) and math.isnan(built.p999_ms)
    assert built.straggler_rate == 0.0


def test_build_window_computes_percentiles_and_rates():
    recs = [(10.0, False), (20.0, True), (30.0, False), (40.0, True)]
    win = build_window(7, 500.0, 1000.0, recs, corrupted_detected=1,
                      cancellations=2)
    assert (win.index, win.t0_ms, win.t1_ms, win.n) == (7, 500.0, 1000.0, 4)
    assert win.reconstructions == 2
    assert win.straggler_rate == 0.5
    assert win.corruption_rate == 0.25
    assert win.cancellation_rate == 0.5
    assert win.p50_ms == 25.0
    assert win.p999_ms == pytest.approx(40.0, rel=1e-3)


def test_report_rates_follow_counts():
    rep = ServingReport(n=10, reconstructions=3, corrupted_detected=1,
                        cancelled_queries=1, cancelled_parities=1)
    assert rep.straggler_rate == 0.3
    assert rep.corruption_rate == 0.1
    assert rep.cancellation_rate == 0.2
    # Mapping view exposes the derived rates too
    assert rep["straggler_rate"] == 0.3


# ------------------------------------------------------- decision policies --
def _win(n=100, recon=0, corrupted=0, p50=25.0, p999=30.0, index=0):
    return ReportWindow(index=index, t0_ms=0.0, t1_ms=1000.0, n=n,
                        p50_ms=p50, p999_ms=p999, reconstructions=recon,
                        corrupted_detected=corrupted)


BASE = Adjustment(scheme="sum", r=1, batch_max_size=1)


def test_threshold_escalates_on_hot_window_and_returns_to_base():
    ctl = ThresholdController(down_windows=1)
    state = ctl.init(BASE)
    # calm window in base mode: hold
    adj, state = ctl.observe(state, _win())
    assert adj is None
    # hot via tail ratio (p999/p50 >= 3): escalate in one window
    adj, state = ctl.observe(state, _win(p999=100.0))
    assert adj == Adjustment(scheme="approxifer", r=2, batch_max_size=4)
    # still turbulent (in-between window): hold the escalation
    adj, state = ctl.observe(state, _win(p999=50.0))
    assert adj is None
    # genuinely calm window: de-escalate back to the captured base
    adj, state = ctl.observe(state, _win())
    assert adj == BASE


def test_threshold_escalates_on_straggler_and_corruption_signals():
    ctl = ThresholdController()
    # straggler threshold sits ABOVE the benign parity race rate (~0.3 at
    # k=2): 30% reconstructions must NOT escalate, 50% must
    adj, _ = ctl.observe(ctl.init(BASE), _win(recon=30))
    assert adj is None
    adj, _ = ctl.observe(ctl.init(BASE), _win(recon=50))
    assert adj is not None
    adj, _ = ctl.observe(ctl.init(BASE), _win(corrupted=5))
    assert adj is not None


def test_threshold_holds_on_empty_windows_and_resets_streaks():
    """An empty window carries no evidence: it neither escalates nor
    counts toward a calm streak (it resets both streaks)."""
    ctl = ThresholdController(down_windows=2)
    state = ctl.init(BASE)
    adj, state = ctl.observe(state, _win(p999=100.0))     # escalate
    assert adj is not None
    adj, state = ctl.observe(state, _win(index=1))        # calm 1/2
    assert adj is None
    adj, state = ctl.observe(state, _win(n=0, index=2))   # empty: reset
    assert adj is None
    adj, state = ctl.observe(state, _win(index=3))        # calm 1/2 again
    assert adj is None
    adj, state = ctl.observe(state, _win(index=4))        # calm 2/2
    assert adj == BASE


def test_controller_is_functional_and_reusable():
    """One frozen instance drives two interleaved state threads without
    cross-talk — the property that lets a single controller run both
    engines of a differential test."""
    ctl = ThresholdController()
    s1, s2 = ctl.init(BASE), ctl.init(BASE)
    adj1, s1 = ctl.observe(s1, _win(p999=100.0))
    adj2, s2 = ctl.observe(s2, _win())
    assert adj1 is not None and adj2 is None
    assert s1.mode == "escalated" and s2.mode == "base"


def test_hysteresis_debounces_both_directions():
    ctl = HysteresisController()
    assert ctl.up_windows == 2 and ctl.down_windows > ctl.up_windows
    state = ctl.init(BASE)
    adj, state = ctl.observe(state, _win(p999=100.0))     # hot 1/2
    assert adj is None
    adj, state = ctl.observe(state, _win(p999=100.0))     # hot 2/2
    assert adj is not None
    for i in range(ctl.down_windows - 1):
        adj, state = ctl.observe(state, _win(index=i))
        assert adj is None
    adj, state = ctl.observe(state, _win(index=9))
    assert adj == BASE


def test_static_controller_never_adjusts():
    ctl = StaticController()
    state = ctl.init(BASE)
    for w in (_win(), _win(p999=1000.0), _win(n=0), _win(recon=100)):
        adj, state = ctl.observe(state, w)
        assert adj is None
    assert ctl.max_r(3) == 3


def test_threshold_validates_at_construction():
    with pytest.raises(ValueError, match="not a registered coding scheme"):
        ThresholdController(escalate_scheme="nope")
    with pytest.raises(ValueError, match="escalate_r"):
        ThresholdController(escalate_r=0)
    with pytest.raises(ValueError, match="up_windows"):
        ThresholdController(up_windows=0)
    with pytest.raises(ValueError, match="r must be"):
        Adjustment(r=0)
    with pytest.raises(ValueError, match="batch_max_size"):
        Adjustment(batch_max_size=0)
    assert ThresholdController().max_r(1) == 2
    assert ThresholdController().max_r(3) == 3


# --------------------------------------------------------- engine coupling --
def test_static_controller_is_a_noop_through_the_des():
    """The ``static`` controller observes every window but never adjusts —
    the report must carry the controller bookkeeping yet match the
    controller-less run on every serving metric (ctl events draw no RNG,
    so the event sequence is otherwise identical)."""
    cfg = SimConfig(n_queries=2000)
    plain = simulate(cfg, "parm", scenario="bursty")
    static = simulate(cfg, "parm", scenario="bursty", controller="static")
    assert static.controller == "static"
    assert static.windows > 0
    assert static.adjustments == ()
    for key in ("n", "median_ms", "p99_ms", "p999_ms", "mean_ms", "max_ms",
                "reconstructions", "cancelled_queries", "cancelled_parities",
                "completed_by"):
        assert static[key] == plain[key], key
    assert plain.controller is None and plain.windows == 0


def _static_grid(cfg_kw, scenario):
    """The static (scheme, r) grid the adaptive run must beat: the r=1
    deployment and both r=2 escalation end-states."""
    grid = {}
    for tag, scheme, r in (("sum_r1", None, 1), ("sum_r2", "sum", 2),
                           ("apx_r2", "approxifer", 2)):
        rep = simulate(SimConfig(r=r, **cfg_kw), "parm", scheme=scheme,
                       scenario=scenario)
        grid[tag] = rep
    return grid


def _assert_dominates(adaptive, grid, scenario):
    """Strict frontier dominance: lower p999 than EVERY static point, and
    less parity work than every static point that matches the escalated
    redundancy (r=2) — i.e. the adaptive run achieves better tails than
    always-on redundancy while paying for it only during turbulence."""
    assert adaptive.adjustments, (scenario, "controller never escalated")
    for tag, rep in grid.items():
        assert adaptive.p999_ms < rep.p999_ms, (
            scenario, tag, adaptive.p999_ms, rep.p999_ms)
    for tag in ("sum_r2", "apx_r2"):
        assert adaptive.parity_served < grid[tag].parity_served, (
            scenario, tag, adaptive.parity_served, grid[tag].parity_served)


def test_adaptive_beats_static_frontier_on_bursty_smoke():
    """Deterministic (seeded DES) frontier check at smoke scale — the fast
    lane's lock on the PR deliverable; the full-scale sweep runs in the
    slow lane below and in benchmarks/latency.py."""
    cfg_kw = dict(n_queries=2000)
    adaptive = simulate(SimConfig(**cfg_kw), "parm", scenario="bursty",
                        controller="threshold")
    _assert_dominates(adaptive, _static_grid(cfg_kw, "bursty"), "bursty")


def test_adaptive_controller_stays_quiet_on_calm_workload():
    """No turbulence, no adjustments: the benign parity completion race
    (~30% at k=2) must not read as straggling."""
    rep = simulate(SimConfig(n_queries=2000), "parm", scenario="calm",
                   controller="threshold")
    assert rep.adjustments == ()
    assert rep.windows > 0


@pytest.mark.slow
def test_adaptive_beats_static_frontier_at_scale():
    """Full-scale frontier dominance on BOTH turbulent regimes (the PR
    acceptance criterion): adaptive p999 strictly below every static
    (scheme, r) point AND parity work strictly below every static r=2
    point, on bursty and storm."""
    cfg_kw = dict(n_queries=8000)
    for scenario in ("bursty", "storm"):
        adaptive = simulate(SimConfig(**cfg_kw), "parm", scenario=scenario,
                            controller="threshold")
        _assert_dominates(adaptive, _static_grid(cfg_kw, scenario), scenario)


def test_controller_flows_through_deployment_spec():
    """DeploymentSpec(controller=...) reaches the DES engine and surfaces
    in the report — names and instances both."""
    import numpy as np

    from repro.serving.api import DeploymentSpec, Trace, deploy

    def fwd(p, x):
        return x @ p

    W = np.eye(4, dtype=np.float32)
    spec = DeploymentSpec(fwd=fwd, params=W, parity_params=[W],
                          strategy="parm", scheme="sum", k=2, m=2,
                          controller="threshold", scenario="bursty")
    rep = deploy(spec, engine="sim").replay(
        Trace(n_queries=1000, qps=270.0, seed=0, n_shuffles=0))
    assert rep["controller"] == "threshold"
    assert rep["windows"] > 0


# ------------------------------------------- escalation pools and routing --
def test_escalation_r_protocol_on_builtins():
    """``escalation_r`` sizes the deployed-params pool family: 0 for a
    controller that never leaves the base (pool layout — and thus any
    seeded hazard realization — identical to a controller-less run),
    ``escalate_r`` for the threshold family."""
    assert StaticController().escalation_r(1) == 0
    assert StaticController().escalation_r(3) == 0
    assert ThresholdController().escalation_r(1) == 2
    assert HysteresisController().escalation_r(2) == 2
    # a policy that cannot leave the base at all provisions nothing
    assert ThresholdController(escalate_scheme=None,
                               escalate_r=1).escalation_r(1) == 0


def _escalation_spec(parity_params, *, encode_fn=None, scenario=None,
                     window_ms=1e9):
    """Threads-engine spec with a threshold controller whose windows never
    fire on their own (window_ms is huge) — escalation in these tests is
    driven explicitly through ``_apply_adjustment``, so the timing is
    deterministic."""
    import numpy as np

    from repro.serving.api import DeploymentSpec

    def fwd(p, x):
        return x @ p

    rng = np.random.default_rng(7)
    W = np.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    spec = DeploymentSpec(
        fwd=fwd, params=W, parity_params=parity_params(W),
        strategy="parm", scheme="sum", k=2, r=1, m=2,
        scenario=scenario, encode_fn=encode_fn,
        controller=ThresholdController(window_ms=window_ms,
                                       escalate_batch_max=1))
    return spec, W


def test_escalated_groups_route_to_deployed_params_pools():
    """REGRESSION (reviewer, high): escalation to the model_agnostic
    approxifer must dispatch parity work to the deployed-params escalation
    pools, never to the deployment's trained parity pools.  The trained
    parity model here is -W — numerically WRONG for any other code — so a
    misrouted escalated group would decode garbage, while correct routing
    serves the exact linear prediction."""
    import numpy as np

    from repro.serving.api import deploy
    from repro.serving.scenarios import (DeterministicSlowdown, Scenario,
                                         pool_of_iid)

    scen = Scenario(
        "esc-route",
        # stall EVERY main instance effectively forever: the mains share one
        # queue, so stalling just one would let the other serve all queries
        # and no group would ever need decoding.  With both dead, an
        # approxifer (k=2, r=2) reconstruction off the escalation pools is
        # the ONLY way any query completes — no timing race for the asserts
        # to lose.  shutdown() joins workers with a 5 s timeout and they are
        # daemon threads, so the sleeping mains are abandoned, not waited
        # out.
        (DeterministicSlowdown(targets=(("main", 0), ("main", 1)),
                               add_ms=60_000.0),))
    spec, W = _escalation_spec(lambda W: [np.asarray(-W)], scenario=scen)
    sess = deploy(spec, engine="threads")
    try:
        fe = sess.frontend
        # two-family provisioning: 1 trained pool + 2 escalation pools
        assert fe._agn_base == 1 and fe._agn_r == 2
        assert len(fe.parity_qs) == 3
        for w in fe.workers:
            pool, _ = pool_of_iid(w.iid)
            if pool == "parity0":
                assert np.allclose(np.asarray(w.params), -W)
            elif pool.startswith("parity"):
                assert w.params is spec.params      # the DEPLOYED model
                assert w.fwd is spec.fwd            # ... and architecture
        with fe.lock:
            fe._apply_adjustment(
                Adjustment(scheme="approxifer", r=2, batch_max_size=1), 0)
        rng = np.random.default_rng(1)
        # warm-up group: compiles the whole escalated encode/decode path end
        # to end and pins the recon-count baseline for the measured group
        for _ in range(2):
            sess.submit(rng.normal(size=(1, 8)).astype(np.float32))
        assert sess.wait_all(timeout=60)
        warm_recon = sess.stats()["reconstructions"]
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(2)]
        futs = [sess.submit(x) for x in xs]
        assert sess.wait_all(timeout=60)
        # main0 never answers, so its query is served by an approxifer
        # decode off the escalation pools — exact for a linear deployment
        # iff the parities were computed with W, not the trained -W model
        for f, x in zip(futs, xs):
            np.testing.assert_allclose(np.asarray(f.result(timeout=1.0)),
                                       x @ W, rtol=1e-4, atol=1e-4)
        assert sess.stats()["reconstructions"] >= warm_recon + 1
    finally:
        sess.shutdown()


def test_user_encode_fn_is_bypassed_for_escalated_groups():
    """REGRESSION (reviewer): a user encode_fn encodes the DEPLOYMENT's
    code; groups captured under a controller-escalated scheme must encode
    through that scheme's own encoder, or decode would consume parities of
    the wrong code.  After de-escalation the user encoder is back."""
    import numpy as np

    from repro.core.scheme import get_scheme
    from repro.serving.api import deploy

    calls = []
    sum_code = get_scheme("sum", k=2, r=1)

    def counting_encode(stacked):
        calls.append(1)
        return np.asarray(sum_code.encode(stacked))

    spec, W = _escalation_spec(lambda W: [W], encode_fn=counting_encode)
    sess = deploy(spec, engine="threads")
    try:
        fe = sess.frontend
        x = np.ones((1, 8), np.float32)
        for _ in range(2):
            sess.submit(x)
        assert len(calls) == 1                  # base group: user encoder
        with fe.lock:
            fe._apply_adjustment(
                Adjustment(scheme="approxifer", r=2, batch_max_size=1), 0)
        for _ in range(2):
            sess.submit(x)
        assert len(calls) == 1                  # escalated group: bypassed
        with fe.lock:
            fe._apply_adjustment(Adjustment(scheme="sum", r=1), 1)
        for _ in range(2):
            sess.submit(x)
        assert len(calls) == 2                  # back on the base code
        assert sess.wait_all(timeout=20)
    finally:
        sess.shutdown()


def test_adjustment_restores_base_scheme_instance_and_validates_target():
    """REGRESSION (reviewer): de-escalation restores the deployment's own
    resolved scheme INSTANCE (never a fresh registry default under the
    same name), and any adjustment that is not an exact return to the base
    must name a model_agnostic scheme that fits the provisioned escalation
    pools."""
    from repro.serving.api import deploy

    spec, W = _escalation_spec(lambda W: [W])
    sess = deploy(spec, engine="threads")
    try:
        fe = sess.frontend
        base = fe.scheme
        assert fe._base_scheme is base
        with fe.lock:
            fe._apply_adjustment(Adjustment(scheme="approxifer", r=2), 0)
        assert fe.scheme is not base
        assert fe.scheme.name == "approxifer" and fe.r == 2
        with fe.lock:
            fe._apply_adjustment(Adjustment(scheme="sum", r=1), 1)
        assert fe.scheme is base                # identity, not a lookalike
        # a trained-parity scheme cannot be an escalation target: the
        # escalation pools run the deployed parameters
        with pytest.raises(ValueError, match="model_agnostic"):
            with fe.lock:
                fe._apply_adjustment(Adjustment(scheme="sum", r=2), 2)
        # an agnostic target beyond the provisioned escalation pools fails
        # with the provisioning contract in the message
        with pytest.raises(ValueError, match="escalation pools"):
            with fe.lock:
                fe._apply_adjustment(Adjustment(scheme="approxifer", r=3), 2)
    finally:
        sess.shutdown()


def test_close_window_rechecks_elapsed_under_lock():
    """REGRESSION (reviewer): two concurrent submits can both observe an
    expired window outside the lock and race into ``_close_window`` — the
    loser must re-check under the lock and NOT close the next window
    early.  The direct calls pin the in-lock early-return; the hammer
    asserts an exact window count under contention."""
    import threading as th

    from repro.serving.api import deploy

    spec, W = _escalation_spec(lambda W: [W], window_ms=10.0)
    sess = deploy(spec, engine="threads")
    try:
        fe = sess.frontend
        assert fe._close_window(5.0) is False
        assert fe._window_idx == 0
        assert fe._close_window(10.0) is True
        assert fe._window_idx == 1
        # 8 threads tick the same 95 ms clock edge concurrently: exactly
        # windows 1..8 close (9 total boundaries at 10 ms), never more
        now = fe._origin + 0.095
        threads = [th.Thread(target=fe._ctl_tick, args=(now,))
                   for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert fe._window_idx == 9
    finally:
        sess.shutdown()


def test_des_trailing_window_adjustments_are_log_only():
    """REGRESSION (reviewer): ctl events past the last arrival are
    trailing — the threads engine closes them at shutdown when workers
    have joined, so the DES must record the decision but leave the pools
    alone.  Here the only window closes after every arrival; its
    escalation (batch_max 4) must not batch the drain: every serving
    metric matches the controller-less run exactly."""
    from repro.serving.scenarios import (DeterministicArrivals,
                                         DeterministicSlowdown, Scenario)

    scen = Scenario(
        "trailing-ctl",
        (DeterministicArrivals(times_ms=(0.0, 5.0, 10.0, 15.0, 20.0, 25.0)),
         DeterministicSlowdown(targets=(("main", 0),), add_ms=200.0),
         DeterministicSlowdown(targets=(("parity0", 0), ("parity1", 0),
                                        ("parity2", 0)), add_ms=50.0)))
    cfg = SimConfig(n_queries=6, m=1, k=2, r=1, slo_ms=None, n_shuffles=0)
    plain = simulate(cfg, "parm", scenario=scen)
    rep = simulate(cfg, "parm", scenario=scen,
                   controller=ThresholdController(window_ms=300.0))
    # the single (trailing) window saw a 50% straggler rate: HOT, escalate
    assert rep.windows == 1
    assert tuple(rep.adjustments) == ((0, "approxifer", 2, 4),)
    assert rep.scheme == "approxifer"      # final knobs ARE recorded
    for key in ("n", "median_ms", "p99_ms", "p999_ms", "mean_ms", "max_ms",
                "reconstructions", "cancelled_queries", "cancelled_parities",
                "completed_by", "batches", "mean_batch_size",
                "parity_served"):
        assert rep[key] == plain[key], key
