"""Serving-layer tests: DES invariants + the threaded ParM runtime."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.runtime import ParMFrontend
from repro.serving.simulator import SimConfig, simulate
from repro.serving.strategy import available_strategies, get_strategy


# ----------------------------------------------------------------- DES ----
@pytest.mark.parametrize("strategy", ["parm", "equal_resources",
                                      "approx_backup", "replication",
                                      "default_slo", "none"])
@pytest.mark.parametrize("seed,k", [(0, 2), (7, 3), (20, 4)])
def test_des_all_queries_answered(strategy, seed, k):
    cfg = SimConfig(n_queries=2000, qps=200, m=12, k=k, seed=seed)
    r = simulate(cfg, strategy)          # internal assert: none unanswered
    assert r["median_ms"] > 0
    assert r["p999_ms"] >= r["p99_ms"] >= r["median_ms"]


def test_des_accepts_strategy_object():
    """simulate() takes the same ResilienceStrategy objects the threaded
    frontend consumes — the string is just registry sugar."""
    cfg = SimConfig(n_queries=2000, qps=200, m=12, k=2, seed=0)
    by_name = simulate(cfg, "parm")
    by_obj = simulate(cfg, get_strategy("parm"))
    assert by_name == by_obj
    assert by_obj["strategy"] == "parm"


def test_des_every_registered_strategy_runs():
    cfg = SimConfig(n_queries=1000, qps=150, m=8, k=2, seed=1)
    for name in available_strategies():
        r = simulate(cfg, name)
        assert r["strategy"] == name
        assert np.isfinite(r["p999_ms"])


@pytest.mark.slow
def test_des_parm_beats_equal_resources_tail():
    cfg = SimConfig(n_queries=50_000, qps=270, m=12, k=2, seed=3)
    parm = simulate(cfg, "parm")
    er = simulate(cfg, "equal_resources")
    assert parm["p99_ms"] < er["p99_ms"]
    gap_parm = parm["p999_ms"] - parm["median_ms"]
    gap_er = er["p999_ms"] - er["median_ms"]
    assert gap_parm < gap_er                      # paper Fig 11 qualitative
    # median stays flat (paper: < 0.5 ms increase)
    assert abs(parm["median_ms"] - er["median_ms"]) < 2.0


def test_des_parm_reconstructs():
    cfg = SimConfig(n_queries=20_000, qps=270, m=12, k=2, seed=0)
    r = simulate(cfg, "parm")
    assert r["reconstructions"] > 0


def test_des_no_background_load_no_tail():
    cfg = SimConfig(n_queries=20_000, qps=100, m=12, k=2, seed=0,
                    n_shuffles=0)
    r = simulate(cfg, "none")
    assert r["p999_ms"] < 2.5 * r["median_ms"]


def test_des_same_seed_is_deterministic():
    """Same SimConfig (same seed) ⇒ bit-identical percentile dict, on both
    the legacy shuffle path and the scenario path."""
    cfg = SimConfig(n_queries=5000, qps=270, m=12, k=2, seed=42)
    assert simulate(cfg, "parm") == simulate(cfg, "parm")
    assert simulate(cfg, "parm", scenario="storm") == \
        simulate(cfg, "parm", scenario="storm")
    assert simulate(cfg, "parm") != simulate(
        SimConfig(n_queries=5000, qps=270, m=12, k=2, seed=43), "parm")


def test_des_parm_tail_beats_none_under_shuffle_load():
    """Fast-lane sanity (small n): under background shuffles ParM closes the
    tail of the unprotected baseline and actually reconstructs."""
    cfg = SimConfig(n_queries=5000, qps=270, m=12, k=2, seed=0)
    parm = simulate(cfg, "parm")
    none = simulate(cfg, "none")
    assert parm["p999_ms"] < none["p999_ms"]
    assert parm["reconstructions"] > 0


def test_des_r2_runs_two_parity_pools_and_reconstructs():
    """r=2 (§3.5) through the DES: the strategy's layout is sized for two
    parity pools and reconstruction still fires."""
    cfg = SimConfig(n_queries=5000, qps=270, m=12, k=2, r=2, seed=0)
    r2 = simulate(cfg, "parm")
    assert r2["scheme"] == "sum"
    assert r2["reconstructions"] > 0
    # the apples-to-apples budget grows with r in the layout the DES uses
    lay = get_strategy("parm").layout(12, 2, r=2)
    assert lay.parity == 6


def test_des_scenarios_all_run_and_report_name():
    from repro.serving.scenarios import available_scenarios
    cfg = SimConfig(n_queries=1000, qps=200, m=8, k=2, seed=1)
    for name in available_scenarios():
        r = simulate(cfg, "parm", scenario=name)
        assert r["scenario"] == name
        assert np.isfinite(r["p999_ms"]) and r["median_ms"] > 0


def test_des_bursty_arrivals_inflate_tail():
    """The MMPP hazard must actually modulate arrivals: bursts at 3x the
    base rate overload the pool and show up in the tail."""
    cfg = SimConfig(n_queries=5000, qps=270, m=12, k=2, seed=0)
    calm = simulate(cfg, "parm", scenario="calm")
    bursty = simulate(cfg, "parm", scenario="bursty")
    assert bursty["p999_ms"] > calm["p999_ms"]


# ------------------------------------------------------------ threaded ----
def _linear_fwd(p, x):
    return x @ p


def test_threaded_parm_reconstruction_correct():
    """Inject a permanent straggler; ParM must return the exact linear
    reconstruction for queries stuck on it."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    slow = {0}                                     # instance 0 is stuck

    def delay(iid):
        return 0.5 if iid in slow else 0.0

    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=2,
                      strategy="parm", delay_fn=delay)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(6)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        n_parity = 0
        for q, x in zip(qs, xs):
            want = np.asarray(_linear_fwd(W, x))
            np.testing.assert_allclose(q.result, want, atol=1e-3)
            n_parity += (q.completed_by == "parity")
        # the straggler's queries should (mostly) be parity-reconstructed
        assert n_parity >= 1
    finally:
        fe.shutdown()


def test_threaded_equal_resources_completes():
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, k=2, m=2, strategy="equal_resources")
    try:
        qs = [fe.submit(i, np.ones((1, 4), np.float32)) for i in range(4)]
        assert fe.wait_all(timeout=10)
        for q in qs:
            assert q.completed_by == "model"
    finally:
        fe.shutdown()


def test_threaded_member_output_before_group_assembly():
    """A member whose inference finishes before its coding group is even
    assembled (slow submitter, fast worker) must still contribute its real
    output to the decode — not a zeros placeholder."""
    rng = np.random.default_rng(3)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=2,
                      strategy="parm",
                      delay_fn=lambda i: 0.5 if i < 2 else 0.0)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(2)]
        q0 = fe.submit(0, xs[0])
        assert q0.event.wait(10)           # q0 done before the group exists
        q1 = fe.submit(1, xs[1])           # group forms now; q1 straggles
        assert fe.wait_all(timeout=30)
        assert q1.completed_by == "parity"
        np.testing.assert_allclose(q1.result, np.asarray(_linear_fwd(W, xs[1])),
                                   atol=1e-3)
    finally:
        fe.shutdown()


def test_frontend_rejects_mismatched_scheme_k():
    """A scheme instance built for a different k must fail fast at
    construction, not as a mid-submit assertion that hangs wait_all."""
    from repro.core.scheme import get_scheme
    W = jnp.ones((4, 3), jnp.float32)
    with pytest.raises(ValueError, match="k=2"):
        ParMFrontend(_linear_fwd, W, parity_params=W, k=4,
                     scheme=get_scheme("sum", k=2))


def test_threaded_mode_kwarg_removed():
    """The PR-1-era mode= alias is removed: TypeError pointing at
    strategy=."""
    W = jnp.ones((4, 3), jnp.float32)
    with pytest.raises(TypeError, match="strategy="):
        ParMFrontend(_linear_fwd, W, k=2, m=2, mode="equal_resources")


def test_threaded_backup_params_kwarg_removed():
    """The removed dedicated-backup-pool spelling names its migration."""
    W = jnp.ones((4, 3), jnp.float32)
    with pytest.raises(TypeError, match="parity_params="):
        ParMFrontend(_linear_fwd, W, k=2, m=2, backup_params=W)


def test_threaded_replication_strategy_completes():
    """Registered replication strategy: each query dispatched twice to the
    main pool; first completion wins even with a permanent straggler."""
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, k=2, m=3, strategy="replication",
                      delay_fn=lambda i: 0.4 if i == 0 else 0.0)
    try:
        qs = [fe.submit(i, np.ones((1, 4), np.float32)) for i in range(6)]
        assert fe.wait_all(timeout=15)
        for q in qs:
            np.testing.assert_allclose(q.result, np.full((1, 3), 4.0))
    finally:
        fe.shutdown()


def test_threaded_default_slo_baseline():
    """Clipper-style baseline: late predictions replaced by the default."""
    W = jnp.ones((4, 3), jnp.float32)
    default = np.zeros((1, 3), np.float32)

    def delay(iid):
        return 0.3                                  # everything is late

    fe = ParMFrontend(_linear_fwd, W, k=2, m=1, strategy="default_slo",
                      delay_fn=delay, default_prediction=default, slo_ms=50)
    try:
        q = fe.submit(0, np.ones((1, 4), np.float32))
        q.event.wait(5)
        assert q.completed_by == "default"
        np.testing.assert_allclose(q.result, default)
    finally:
        fe.shutdown()


def test_stats_empty_and_singleton_safe():
    """stats() must not crash before any query completes, and must report
    the simulator's percentile keys on a single-query workload."""
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, k=2, m=1, strategy="none")
    try:
        s = fe.stats()
        assert s["n"] == 0 and np.isnan(s["median_ms"])
        q = fe.submit(0, np.ones((1, 4), np.float32))
        q.event.wait(10)
        s = fe.stats()
        for key in ("median_ms", "p99_ms", "p999_ms", "mean_ms", "max_ms"):
            assert np.isfinite(s[key]), (key, s)
        assert s["n"] == 1
    finally:
        fe.shutdown()


def test_shutdown_flushes_partial_group():
    """A workload that is not a multiple of k leaves a pending coding group;
    shutdown() fulfills those members so wait_all() cannot hang on them."""
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=4, m=1,
                      strategy="parm")
    qs = [fe.submit(i, np.ones((1, 4), np.float32)) for i in range(3)]
    fe.shutdown()      # partial group of 3 < k=4; no parity was dispatched
    assert fe.wait_all(timeout=5)
    assert all(q.event.is_set() for q in qs)


def test_encode_decode_latency_budget():
    """Paper §5.2.5: encode/decode are microsecond-scale next to inference.
    (CPU-container analogue: encode+decode of a [k,1,1000] group must be
    well under a ResNet-18-class inference time of ~25 ms.)"""
    from repro.core.scheme import get_scheme
    scheme = get_scheme("sum", k=2, r=1)
    q = jnp.ones((2, 1, 1000))
    encode = jax.jit(lambda x: scheme.encode(x))
    outs = jnp.ones((2, 1, 1000))
    decode = jax.jit(lambda p, o: scheme.decode_one(p, o, 0))
    encode(q).block_until_ready()
    decode(q[0], outs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        encode(q).block_until_ready()
    enc_us = (time.perf_counter() - t0) / 50 * 1e6
    t0 = time.perf_counter()
    for _ in range(50):
        decode(q[0], outs).block_until_ready()
    dec_us = (time.perf_counter() - t0) / 50 * 1e6
    assert enc_us < 5000 and dec_us < 5000, (enc_us, dec_us)


# ------------------------------------------- shutdown / flush / batching ----
def test_shutdown_wakes_blocked_workers_without_polling():
    """Workers block on the pool queue (no idle-wakeup poll loop); the
    shutdown sentinel must wake and retire every one of them promptly."""
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=12,
                      strategy="parm")
    assert all(w.is_alive() for w in fe.workers)
    t0 = time.perf_counter()
    fe.shutdown()
    assert time.perf_counter() - t0 < 0.2       # sub-ms per idle worker
    assert all(not w.is_alive() for w in fe.workers)
    fe.shutdown()                               # idempotent


def test_shutdown_cancels_armed_slo_timers():
    """default_slo arms one Timer per query; shutdown() must cancel them so
    none fires into the torn-down frontend (and flushed queries must stay
    'flushed', not be overwritten by a late 'default')."""
    W = jnp.ones((4, 3), jnp.float32)
    default = np.zeros((1, 3), np.float32)
    fe = ParMFrontend(_linear_fwd, W, k=2, m=1, strategy="default_slo",
                      delay_fn=lambda i: 0.5, default_prediction=default,
                      slo_ms=150.0)
    qs = [fe.submit(i, np.ones((1, 4), np.float32)) for i in range(3)]
    assert len(fe._timers) == 3
    fe.shutdown()                    # well before the 150 ms deadline
    assert not fe._timers            # armed timers cancelled and dropped
    time.sleep(0.25)                 # past the deadline: nothing may fire
    assert all(q.completed_by != "default" for q in qs if q.event.is_set())


def test_slo_timer_set_does_not_accumulate_fired_timers():
    """A fired timer removes itself from the armed set — a long-lived
    deployment must not leak one Timer object per served query."""
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, k=2, m=2, strategy="default_slo",
                      default_prediction=np.zeros((1, 3), np.float32),
                      slo_ms=30.0)
    try:
        qs = [fe.submit(i, np.ones((1, 4), np.float32)) for i in range(8)]
        assert fe.wait_all(timeout=10)
        deadline = time.time() + 5
        while fe._timers and time.time() < deadline:
            time.sleep(0.01)
        assert not fe._timers, len(fe._timers)
        del qs
    finally:
        fe.shutdown()


def test_wait_all_true_after_non_multiple_of_k_workload():
    """A workload that isn't a multiple of k: the full group completes (its
    straggler via parity decode, tombstoning the now-redundant original),
    the trailing partial-group query — stuck behind the lone busy worker —
    keeps wait_all() False until shutdown flushes it, after which wait_all()
    must return True with every query settled."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=1,
                      strategy="parm", delay_fn=lambda i: 0.4 if i == 0
                      else 0.0)
    qs = [fe.submit(i, rng.normal(size=(1, 8)).astype(np.float32))
          for i in range(3)]
    assert fe.wait_all(timeout=0.15) is False   # worker still holds q0
    # shutdown while q1/q2 are still queued: the worker finishes q0 (whose
    # output unlocks q1's decode), then retires without touching the backlog
    fe.shutdown()
    assert fe.wait_all(timeout=5) is True
    assert qs[0].completed_by == "model"        # served by the slow worker
    assert qs[1].completed_by == "parity"       # decoded around it
    assert all(q.event.is_set() for q in qs)
    assert qs[2].completed_by == "flushed"
    st = fe.stats()
    assert st["n"] == 2                          # flushed excluded from stats
    assert st["completed_by"]["flushed"] == 1
    # q1's original was dequeued (or drained at shutdown) after its parity
    # reconstruction arrived: redundant work, cancelled
    assert st["cancelled_queries"] == 1


def test_early_output_stash_is_consumed_at_group_assembly():
    """An output that beats its group's assembly parks in _early_outs and
    must be moved into the group (and removed from the stash) the moment the
    group forms, so the decode reads the real output, not a zero row."""
    rng = np.random.default_rng(3)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=2,
                      strategy="parm",
                      delay_fn=lambda i: 0.5 if i < 2 else 0.0)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(2)]
        q0 = fe.submit(0, xs[0])
        assert q0.event.wait(10)            # done before the group exists
        with fe.lock:
            assert 0 in fe._early_outs      # parked: group not assembled yet
        q1 = fe.submit(1, xs[1])            # group forms now; q1 straggles
        with fe.lock:
            assert not fe._early_outs       # stash consumed by assembly
            assert 0 in fe.groups[0]["outs"]
        assert fe.wait_all(timeout=30)
        assert q1.completed_by == "parity"
        np.testing.assert_allclose(
            q1.result, np.asarray(_linear_fwd(W, xs[1])), atol=1e-3)
    finally:
        fe.shutdown()


def test_threaded_adaptive_batching_batches_backlog_and_splits_results():
    """With one worker held busy, a burst of queries queues behind it; the
    worker must then serve them in one stacked inference call (up to
    max_size) and split the outputs back per query, bit-exactly."""
    from repro.serving.api import BatchingPolicy
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    fe = ParMFrontend(_linear_fwd, W, k=2, m=1, strategy="none",
                      delay_fn=lambda i: 0.15,
                      batching=BatchingPolicy(max_size=4, max_delay_ms=0.0))
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(5)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        for q, x in zip(qs, xs):
            np.testing.assert_allclose(
                q.result, np.asarray(_linear_fwd(W, x)), atol=1e-4)
        st = fe.stats()
        # 5 queries arrived while the worker slept on the first: at most 3
        # inference calls can have served them (1 + batch<=4 + remainder)
        assert st["batches"] <= 3
        assert st["mean_batch_size"] > 1.0
        assert st["completed_by"]["model"] == 5
    finally:
        fe.shutdown()


def test_des_adaptive_batching_stabilizes_overload():
    """Above the unbatched capacity knee, adaptive batching (the per-batch
    service curve at the ACTUAL dequeued batch size) must cut the tail and
    report mean_batch_size > 1; the legacy static batch_size model is
    untouched by the new knob."""
    base = dict(n_queries=4000, qps=520, m=12, k=2, seed=1)
    unbatched = simulate(SimConfig(**base), "parm")
    batched = simulate(SimConfig(**base, batch_max_size=4), "parm")
    assert batched["p999_ms"] < unbatched["p999_ms"] / 2, \
        (batched["p999_ms"], unbatched["p999_ms"])
    assert batched["mean_batch_size"] > 1.05
    assert unbatched["mean_batch_size"] == 1.0
    # both engines' reports carry the cancellation counters
    assert batched["cancelled_queries"] >= 0
    assert "cancelled_parities" in batched


def test_des_cancellation_fires_under_load():
    """Redundant-work cancellation under overload: default_slo tombstones
    queued originals once the deadline answered them (the Clipper frontend
    never re-serves an expired query), and parm drops undispatched parity
    queries whose whole group already finished on the mains."""
    cfg = SimConfig(n_queries=4000, qps=520, m=12, k=2, seed=1)
    slo = simulate(cfg, "default_slo")
    assert slo["cancelled_queries"] > 0
    assert slo["completed_by"]["default"] > 0
    parm = simulate(cfg, "parm")
    assert parm["cancelled_parities"] > 0
    assert parm["reconstructions"] > 0
