"""Serving-layer tests: DES invariants + the threaded ParM runtime."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.serving.runtime import ParMFrontend
from repro.serving.simulator import SimConfig, simulate


# ----------------------------------------------------------------- DES ----
@given(strategy=st.sampled_from(["parm", "equal_resources", "approx_backup",
                                 "replication", "none"]),
       seed=st.integers(0, 20), k=st.sampled_from([2, 3, 4]))
@settings(deadline=None, max_examples=12)
def test_des_all_queries_answered(strategy, seed, k):
    cfg = SimConfig(n_queries=2000, qps=200, m=12, k=k, seed=seed)
    r = simulate(cfg, strategy)          # internal assert: none unanswered
    assert r["median_ms"] > 0
    assert r["p999_ms"] >= r["p99_ms"] >= r["median_ms"]


def test_des_parm_beats_equal_resources_tail():
    cfg = SimConfig(n_queries=50_000, qps=270, m=12, k=2, seed=3)
    parm = simulate(cfg, "parm")
    er = simulate(cfg, "equal_resources")
    assert parm["p99_ms"] < er["p99_ms"]
    gap_parm = parm["p999_ms"] - parm["median_ms"]
    gap_er = er["p999_ms"] - er["median_ms"]
    assert gap_parm < gap_er                      # paper Fig 11 qualitative
    # median stays flat (paper: < 0.5 ms increase)
    assert abs(parm["median_ms"] - er["median_ms"]) < 2.0


def test_des_parm_reconstructs():
    cfg = SimConfig(n_queries=20_000, qps=270, m=12, k=2, seed=0)
    r = simulate(cfg, "parm")
    assert r["reconstructions"] > 0


def test_des_no_background_load_no_tail():
    cfg = SimConfig(n_queries=20_000, qps=100, m=12, k=2, seed=0,
                    n_shuffles=0)
    r = simulate(cfg, "none")
    assert r["p999_ms"] < 2.5 * r["median_ms"]


# ------------------------------------------------------------ threaded ----
def _linear_fwd(p, x):
    return x @ p


def test_threaded_parm_reconstruction_correct():
    """Inject a permanent straggler; ParM must return the exact linear
    reconstruction for queries stuck on it."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    slow = {0}                                     # instance 0 is stuck

    def delay(iid):
        return 0.5 if iid in slow else 0.0

    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=2,
                      mode="parm", delay_fn=delay)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(6)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        n_parity = 0
        for q, x in zip(qs, xs):
            want = np.asarray(_linear_fwd(W, x))
            np.testing.assert_allclose(q.result, want, atol=1e-3)
            n_parity += (q.completed_by == "parity")
        # the straggler's queries should (mostly) be parity-reconstructed
        assert n_parity >= 1
    finally:
        fe.shutdown()


def test_threaded_equal_resources_completes():
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, k=2, m=2, mode="equal_resources")
    try:
        qs = [fe.submit(i, np.ones((1, 4), np.float32)) for i in range(4)]
        assert fe.wait_all(timeout=10)
        for q in qs:
            assert q.completed_by == "model"
    finally:
        fe.shutdown()


def test_threaded_default_slo_baseline():
    """Clipper-style baseline: late predictions replaced by the default."""
    W = jnp.ones((4, 3), jnp.float32)
    default = np.zeros((1, 3), np.float32)

    def delay(iid):
        return 0.3                                  # everything is late

    fe = ParMFrontend(_linear_fwd, W, k=2, m=1, mode="default_slo",
                      delay_fn=delay, default_prediction=default, slo_ms=50)
    try:
        q = fe.submit(0, np.ones((1, 4), np.float32))
        q.event.wait(5)
        assert q.completed_by == "default"
        np.testing.assert_allclose(q.result, default)
    finally:
        fe.shutdown()


def test_encode_decode_latency_budget():
    """Paper §5.2.5: encode/decode are microsecond-scale next to inference.
    (CPU-container analogue: encode+decode of a [k,1,1000] group must be
    well under a ResNet-18-class inference time of ~25 ms.)"""
    from repro.core.codes import LinearDecoder, SumEncoder
    enc, dec = SumEncoder(2, 1), LinearDecoder(2, 1)
    q = jnp.ones((2, 1, 1000))
    encode = jax.jit(lambda x: enc(x))
    outs = jnp.ones((2, 1, 1000))
    decode = jax.jit(lambda p, o: dec.decode_one(p, o, 0))
    encode(q).block_until_ready()
    decode(q[0], outs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        encode(q).block_until_ready()
    enc_us = (time.perf_counter() - t0) / 50 * 1e6
    t0 = time.perf_counter()
    for _ in range(50):
        decode(q[0], outs).block_until_ready()
    dec_us = (time.perf_counter() - t0) / 50 * 1e6
    assert enc_us < 5000 and dec_us < 5000, (enc_us, dec_us)
