"""The fused coded hot path (DESIGN.md §12): Pallas-vs-jnp-vs-ref
equivalence for the fused encode→forward kernel and the batched multigroup
decode, the scheme-level batched surfaces against their per-group
equivalents on BOTH backends, the fusability routing in
``core.parity.fused_parity_outputs``, and a ``_FORCE_DECODE`` differential
case proving the batched decode drains serve bit-identical
``ServingReport``s to the per-group drains in BOTH serving engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheme import LinearScheme, get_scheme
from repro.kernels import ops, ref


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


def _close(got, want, dt=jnp.float32, mul=1.0):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dt) * mul, rtol=_tol(dt) * mul)


# ------------------------------------------------- fused encode→forward ----

@pytest.mark.parametrize("k,r,B,F,V,dt", [
    (2, 1, 4, 512, 128, jnp.float32),
    (3, 1, 5, 300, 130, jnp.float32),      # nothing 128-aligned
    (2, 3, 8, 1024, 257, jnp.float32),     # trailing partial V block
    (4, 2, 1, 129, 64, jnp.float32),       # trailing partial F block, B=1
    (4, 2, 8, 1000, 100, jnp.bfloat16),
])
def test_fused_encode_forward_op(k, r, B, F, V, dt):
    key = jax.random.PRNGKey(k * 97 + r * 13 + F)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (k, B, F), dt)
    C = jax.random.normal(ks[1], (r, k), jnp.float32)
    W = jax.random.normal(ks[2], (r, F, V), dt)
    got = ops.fused_encode_forward_op(q, C, W)
    want = ref.fused_encode_forward_ref(q, C, W)
    # relative to the magnitude of a length-F*k reduction
    _close(got, want, dt, mul=np.sqrt(F * k))
    assert got.shape == (r, B, V)


def test_fused_encode_forward_trailing_feature_shape():
    """Image-shaped queries flatten to F inside the op."""
    q = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 4, 6, 2))
    C = jnp.asarray([[1.0, 2.0, 3.0]])
    W = jax.random.normal(jax.random.PRNGKey(1), (1, 48, 10))
    got = ops.fused_encode_forward_op(q, C, W)
    want = ref.fused_encode_forward_ref(q.reshape(3, 2, -1), C, W)
    _close(got, want, mul=16)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("name,r", [("sum", 1), ("sum", 2), ("learned", 2)])
def test_scheme_encode_forward_matches_unfused(backend, name, r):
    """scheme.encode_forward == scheme.encode then per-row matmul, on both
    backends, for every LinearScheme-family member (learned overrides the
    coefficient matrix but inherits the fused surface)."""
    k, B, F, V = 3, 4, 50, 7
    scheme = get_scheme(name, k=k, r=r, backend=backend)
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (k, B, F))
    W = jax.random.normal(jax.random.PRNGKey(6), (r, F, V))
    got = scheme.encode_forward(q, W)
    enc = scheme.encode(q.reshape(k, B, F))
    want = jnp.einsum("rbf,rfv->rbv", jnp.asarray(enc, jnp.float32),
                      W.astype(jnp.float32))
    _close(got, want, mul=F)
    # a shared 2d first-layer matrix broadcasts across rows
    got2 = scheme.encode_forward(q, W[0])
    want2 = jnp.einsum("rbf,fv->rbv", jnp.asarray(enc, jnp.float32),
                       W[0].astype(jnp.float32))
    _close(got2, want2, mul=F)


# ---------------------------------------------------- multigroup decode ----

@pytest.mark.parametrize("G,k,B,V", [(1, 2, 1, 9), (5, 3, 4, 100),
                                     (4, 4, 2, 257)])
def test_multigroup_decode_op(G, k, B, V):
    """One launch over G groups == G per-group subtraction decodes, for
    every missing index and both shared and per-group coeffs."""
    rng = np.random.default_rng(G * 7 + k)
    po = jnp.asarray(rng.normal(size=(G, B, V)), jnp.float32)
    outs = jnp.asarray(rng.normal(size=(G, k, B, V)), jnp.float32)
    idxs = np.arange(G) % k                    # cycles every missing index
    shared = jnp.arange(1.0, k + 1.0)
    got = ops.multigroup_decode_op(po, outs, idxs, shared)
    for g in range(G):
        want = ops.parity_decode_op(po[g], outs[g], int(idxs[g]), shared)
        _close(got[g], want, mul=k)
    # per-group coefficient rows
    cg = jnp.asarray(rng.normal(size=(G, k)), jnp.float32) + 2.0
    got = ops.multigroup_decode_op(po, outs, idxs, cg)
    for g in range(G):
        want = ops.parity_decode_op(po[g], outs[g], int(idxs[g]), cg[g])
        _close(got[g], want, mul=k)


def test_multigroup_decode_op_matches_ref_and_unbatched():
    G, k, V = 3, 2, 40
    rng = np.random.default_rng(0)
    po = jnp.asarray(rng.normal(size=(G, V)), jnp.float32)   # no batch axis
    outs = jnp.asarray(rng.normal(size=(G, k, V)), jnp.float32)
    idxs = np.array([0, 1, 0])
    c = jnp.asarray([2.0, 3.0])
    got = ops.multigroup_decode_op(po, outs, idxs, c)
    assert got.shape == (G, V)
    cg = np.broadcast_to(np.asarray(c), (G, k)).copy()
    avail = cg * (np.arange(k)[None] != idxs[:, None])
    inv = 1.0 / np.take_along_axis(cg, idxs[:, None], 1)
    cmat = jnp.asarray(np.concatenate([avail, inv], 1))
    want = ref.multigroup_decode_ref(po[:, None], outs[:, :, None], cmat)
    _close(got, want[:, 0])


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_scheme_decode_one_many_matches_decode_one(backend):
    k, G, B, V = 4, 6, 3, 33
    scheme = get_scheme("sum", k=k, r=1, backend=backend)
    rng = np.random.default_rng(1)
    po = jnp.asarray(rng.normal(size=(G, B, V)), jnp.float32)
    outs = jnp.asarray(rng.normal(size=(G, k, B, V)), jnp.float32)
    idxs = np.arange(G) % k
    got = scheme.decode_one_many(po, outs, idxs)
    for g in range(G):
        _close(got[g], scheme.decode_one(po[g], outs[g], int(idxs[g])),
               mul=k)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_scheme_decode_many_matches_decode(backend):
    """Batched masked least-squares over G groups == per-group decode, for
    r=2 with varied missing masks and a straggling parity row."""
    k, r, B, V = 3, 2, 2, 11
    scheme = get_scheme("sum", k=k, r=r, backend=backend)
    rng = np.random.default_rng(2)
    masks = np.array([[1, 0, 0], [0, 1, 1], [1, 1, 0], [0, 0, 1]], bool)
    pa = np.array([[1, 1], [1, 1], [1, 1], [1, 0]], bool)
    G = len(masks)
    po = jnp.asarray(rng.normal(size=(G, r, B, V)), jnp.float32)
    outs = jnp.asarray(rng.normal(size=(G, k, B, V)), jnp.float32)
    got = scheme.decode_many(po, outs, masks, pa)
    for g in range(G):
        want = scheme.decode(po[g], outs[g], masks[g], pa[g])
        _close(got[g], want, mul=8 * k)
    # parity_avail defaults to all-arrived
    got = scheme.decode_many(po, outs, masks)
    for g in range(G):
        want = scheme.decode(po[g], outs[g], masks[g])
        _close(got[g], want, mul=8 * k)


def test_batched_surface_is_linear_family_only():
    """approxifer has its own decoder and replication is passthrough —
    neither may expose the batched LinearScheme surface (the engines
    feature-test with hasattr and fall back per-group)."""
    for name in ("approxifer", "replication"):
        scheme = get_scheme(name, k=2, r=2)
        assert not hasattr(type(scheme), "decode_one_many"), name
        assert not hasattr(type(scheme), "decode_many"), name
    assert hasattr(type(get_scheme("learned", k=2)), "decode_one_many")


# --------------------------------------------------- fusability routing ----

def test_fused_parity_outputs_linear_and_mlp():
    from repro.core import parity
    from repro.models.cnn import init_mlp, mlp_fwd
    from repro.models.linear import init_linear, linear_fwd
    k, r, B, F, V = 2, 2, 4, 24, 5
    scheme = get_scheme("sum", k=k, r=r)
    q = jax.random.normal(jax.random.PRNGKey(0), (k, B, F))
    for fwd, pp in (
            (linear_fwd, [init_linear(jax.random.PRNGKey(j), F, V)
                          for j in range(r)]),
            (mlp_fwd, [init_mlp(jax.random.PRNGKey(j), F, hidden=(16,),
                                n_out=V) for j in range(r)])):
        fused = parity.fused_parity_outputs(scheme, q, pp, fwd)
        enc = scheme.encode(q)
        want = jnp.stack([fwd(pp[j], enc[j]) for j in range(r)])
        _close(fused, want, mul=F)
        # ... and the fused path was actually taken
        parity._FORCE_FUSED = True
        try:
            _close(parity.fused_parity_outputs(scheme, q, pp, fwd), want,
                   mul=F)
        finally:
            parity._FORCE_FUSED = None


def test_fused_parity_outputs_fallback_and_force():
    """Custom forwards never silently fuse; _FORCE_FUSED=False disables
    fusion even for fusable pairs; =True raises on non-fusable ones."""
    from repro.core import parity
    from repro.models.linear import init_linear, linear_fwd
    k, F, V = 2, 6, 3
    scheme = get_scheme("sum", k=k, r=1)
    q = jax.random.normal(jax.random.PRNGKey(1), (k, 3, F))
    pp = [init_linear(jax.random.PRNGKey(0), F, V)]

    def custom_fwd(p, x):                     # linear-shaped but not the
        return x @ p["w"]                     # canonical chain

    want = jnp.stack([custom_fwd(pp[0], scheme.encode(q)[0])])
    _close(parity.fused_parity_outputs(scheme, q, pp, custom_fwd), want)
    parity._FORCE_FUSED = True
    try:
        with pytest.raises(ValueError, match="not fusable"):
            parity.fused_parity_outputs(scheme, q, pp, custom_fwd)
        # approxifer's custom encode is not the LinearScheme projection
        apx = get_scheme("approxifer", k=k, r=1)
        with pytest.raises(ValueError, match="not fusable"):
            parity.fused_parity_outputs(apx, q, pp, linear_fwd)
    finally:
        parity._FORCE_FUSED = None
    parity._FORCE_FUSED = False
    try:
        want = jnp.stack([linear_fwd(pp[0], scheme.encode(q)[0])])
        _close(parity.fused_parity_outputs(scheme, q, pp, linear_fwd), want)
    finally:
        parity._FORCE_FUSED = None


# ------------------------------------- batched-vs-pergroup differential ----

def _force_decode(mode):
    from repro.serving import runtime, simulator
    runtime._FORCE_DECODE = mode
    simulator._FORCE_DECODE = mode


@pytest.mark.parametrize("scheme,k,r,slow_main,expected", [
    ("sum", 2, 1, (0,), 1),
    ("sum", 2, 2, (0, 1), 2),      # r=2: the decode_many lstsq surface
])
def test_batched_decode_differential_both_engines(scheme, k, r, slow_main,
                                                  expected):
    """Forcing every drain through the batched decode surface
    (``_FORCE_DECODE="batched"`` lowers the drain's batch threshold to 1)
    vs forcing per-group decodes must produce identical ServingReports in
    BOTH engines — the serving-layer analogue of the kernel equivalence
    sweeps above (reconstruction counts AND completion attribution)."""
    from tests.test_differential import (_make_spec, _pattern_scenario,
                                         _run_runtime, _run_sim)
    scen = _pattern_scenario(k, slow_main, ())
    spec, W = _make_spec(scheme, k, r, scen)
    reports = {}
    for mode in ("batched", "pergroup"):
        _force_decode(mode)
        try:
            reports[mode] = {"sim": _run_sim(spec, n=k),
                             "rt": _run_runtime(spec, W, n=k)}
        finally:
            _force_decode(None)
    for eng in ("sim", "rt"):
        b, p = reports["batched"][eng], reports["pergroup"][eng]
        assert b["reconstructions"] == p["reconstructions"] == expected, \
            (eng, b, p)
        assert b["completed_by"] == p["completed_by"], (eng, b, p)
        assert b.get("cancelled_queries") == p.get("cancelled_queries")
    # and the engines agree with each other, per DESIGN.md §1
    assert (reports["batched"]["sim"]["reconstructions"] ==
            reports["batched"]["rt"]["reconstructions"])
