"""Tests for the §Perf features: microbatched accumulation equivalence,
coded-serve-step variants, and the inference sharding layout."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.launch import steps as ST
from repro.models import transformer as T
from repro.training.optim import AdamConfig, adam_init

KEY = jax.random.PRNGKey(0)


def test_microbatch_matches_full_batch():
    """m-way gradient accumulation == single-shot step (same data)."""
    cfg = get_config("smollm-135m", reduced=True)
    params = T.init_params(cfg, KEY)
    opt = AdamConfig(lr=1e-2)
    batch = {"tokens": jax.random.randint(KEY, (4, 16), 0, cfg.vocab)}

    s1 = ST.make_train_step(cfg, opt, shard_logits=False)
    s2 = ST.make_train_step(cfg, opt, shard_logits=False, microbatch=2)
    p1, _, l1 = jax.jit(s1)(params, adam_init(params, opt), batch)
    p2, _, l2 = jax.jit(s2)(params, adam_init(params, opt), batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_coded_serve_optimized_matches_baseline():
    """Fused-gather + last-token-unembed variant returns the same parity
    output the decoder consumes."""
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = T.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 3, 16), 0, cfg.vocab)}
    base = ST.make_coded_serve_step(cfg, k=2, optimized=False)
    opt = ST.make_coded_serve_step(cfg, k=2, optimized=True)
    lb, _ = jax.jit(base)(params, batch)
    lo, _ = jax.jit(opt)(params, batch)
    np.testing.assert_allclose(np.asarray(lb), np.asarray(lo), atol=2e-3)


def test_coded_serve_equals_decoder_identity_for_linear_regime():
    """Embedding-space ParM sanity: summing member embeddings and running the
    *deployed* model approximates sum of logits only after training — but the
    encode itself must be exactly linear: embeds(parity tokens stream) ==
    sum of member embeds."""
    cfg = get_config("smollm-135m", reduced=True)
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 3, 8), 0, cfg.vocab)
    a = jax.vmap(lambda t: T.embed_tokens(cfg, params, t))(toks).sum(0)
    flat = T.embed_tokens(cfg, params, toks.reshape(6, 8))
    b = flat.reshape(2, 3, 8, -1).sum(0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_inference_sharding_rules_drop_fsdp():
    from repro.distributed.sharding import ShardingRules

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 4))

    for fsdp, want in [(True, "data"), (False, None)]:
        r = ShardingRules.__new__(ShardingRules)
        r.mesh = FakeMesh()
        r.axis_sizes = {"data": 4, "model": 4}
        r.tp = "model"
        r.fsdp = "data" if fsdp else None
        r.fsdp_params = fsdp
        r.batch_axes = ("data",)
        spec = r.param_spec(
            ((jax.tree_util.DictKey("wq"),)),
            jax.ShapeDtypeStruct((64, 64), jnp.float32))
        assert spec[0] == want, (fsdp, spec)


def test_unembed_last_only():
    cfg = get_config("olmo-1b", reduced=True)
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    full, _ = T.forward(cfg, params, tokens=toks)
    last, _ = T.forward(cfg, params, tokens=toks, unembed_last_only=True)
    assert last.shape == (2, 1, cfg.vocab)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)


def test_frontend_with_pallas_kernel_codecs():
    """The threaded frontend can run encode/decode through the Pallas kernel
    wrappers (interpret mode on CPU) instead of plain jnp."""
    from repro.kernels import ops
    from repro.serving.runtime import ParMFrontend

    W = jnp.asarray(np.random.default_rng(0).normal(size=(8, 5)),
                    jnp.float32)

    def fwd(p, x):
        return x @ p

    def encode_fn(queries):                    # [k, 1, 8]
        c = jnp.ones((queries.shape[0],))
        return np.asarray(ops.parity_encode_op(jnp.asarray(queries), c))[None]

    def decode_fn(parity_out, outs, j):        # outs [k, 1, 5]
        return np.asarray(ops.parity_decode_op(
            jnp.asarray(parity_out), jnp.asarray(outs), j))

    slow = {0}
    fe = ParMFrontend(fwd, W, parity_params=W, k=2, m=2, strategy="parm",
                      delay_fn=lambda i: 0.4 if i in slow else 0.0,
                      encode_fn=encode_fn, decode_fn=decode_fn)
    try:
        xs = [np.random.default_rng(i).normal(size=(1, 8)).astype(np.float32)
              for i in range(4)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        for q, x in zip(qs, xs):
            np.testing.assert_allclose(q.result, np.asarray(fwd(W, x)),
                                       atol=1e-3)
        assert any(q.completed_by == "parity" for q in qs)
    finally:
        fe.shutdown()


def test_frontend_r2_two_concurrent_stragglers():
    """Paper §3.5 in the runtime: with r=2 parity models, a coding group can
    lose BOTH member predictions and still be reconstructed exactly for a
    linear deployed model."""
    from repro.serving.runtime import ParMFrontend

    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    def fwd(p, x):
        return x @ p

    # ideal parity models for a linear F: F itself scaled per Vandermonde row
    # row0 = [1,1] -> F;  row1 = [1,2]: F_P1(x1 + 2 x2) = F(x1) + 2 F(x2) = F
    parity_models = [W, W]

    slow = {0, 1}                      # BOTH deployed instances straggle

    def delay(iid):
        # generous straggle: the first decode pays one-time jnp trace cost
        return 2.5 if iid in slow else 0.0

    fe = ParMFrontend(fwd, W, parity_params=parity_models, k=2, r=2, m=2,
                      strategy="parm", delay_fn=delay)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(2)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        n_parity = sum(q.completed_by == "parity" for q in qs)
        assert n_parity == 2, [q.completed_by for q in qs]
        for q, x in zip(qs, xs):
            np.testing.assert_allclose(q.result, np.asarray(fwd(W, x)),
                                       atol=1e-2)
    finally:
        fe.shutdown()


def test_decoder_partial_parity_availability():
    """decode() with a straggling parity model: exact when
    #available parities >= #missing."""
    from repro.core.codes import LinearDecoder, vandermonde
    rng = np.random.default_rng(1)
    k, r = 3, 2
    outs_true = rng.normal(size=(k, 4)).astype(np.float32)
    C = vandermonde(k, r)
    parity_outs = (C @ outs_true).astype(np.float32)
    dec = LinearDecoder(k, r)
    miss = np.array([True, False, False])
    pa = np.array([False, True])       # parity 0 unavailable
    got = np.asarray(dec.decode(jnp.asarray(parity_outs),
                                jnp.asarray(np.where(miss[:, None], 99.0,
                                                     outs_true)),
                                jnp.asarray(miss), jnp.asarray(pa)))
    np.testing.assert_allclose(got, outs_true, atol=1e-3)
