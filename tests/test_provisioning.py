"""The scheme-owned provisioning API (DESIGN.md §14): ``capabilities()``
dispatch, ``provision_parity`` hooks, and the two training-free schemes —
fisher (checkpoint merging) and invnet (invertible-coupling encode)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.core.fisher import FisherScheme, diag_fisher
from repro.core.invnet import InvNetScheme, init_coupling_params
from repro.core.parity import ParityTrainContext, train_parity_models
from repro.core.scheme import (Capabilities, get_scheme, list_schemes,
                               scheme_capabilities)
from repro.models.linear import init_linear, linear_fwd


def _boom(key):
    raise AssertionError("training-free provisioning must never "
                         "initialise a parity model")


# ---------------------------------------------------------- capabilities ---
def test_declared_capabilities_surface():
    """Every built-in scheme declares its flags through capabilities()."""
    expected = {
        "sum": Capabilities(),
        "concat": Capabilities(),
        "replication": Capabilities(),
        "fisher": Capabilities(),
        "approx_backup": Capabilities(fixes_k=True, approximate=True),
        "learned": Capabilities(trainable=True),
        "approxifer": Capabilities(model_agnostic=True, detects_errors=True,
                                   dynamic_arity=True),
        "invnet": Capabilities(model_agnostic=True),
    }
    assert set(expected) <= set(list_schemes())
    for name, want in expected.items():
        got = scheme_capabilities(get_scheme(name, k=2))
        assert got == want, name


def test_legacy_attribute_reads_warn_but_work():
    """The pre-capabilities() attribute spellings stay readable one release
    with a DeprecationWarning."""
    aix = get_scheme("approxifer", k=2)
    for attr in ("model_agnostic", "detects_errors", "dynamic_arity"):
        with pytest.warns(DeprecationWarning, match="scheme_capabilities"):
            assert getattr(aix, attr) is True
    with pytest.warns(DeprecationWarning, match="scheme_capabilities"):
        assert get_scheme("learned", k=2).trainable is True
    with pytest.warns(DeprecationWarning, match="scheme_capabilities"):
        assert get_scheme("approx_backup", k=2).fixes_k is True


def test_attribute_style_scheme_falls_back_with_warning():
    """A third-party scheme still declaring boolean attributes (no
    capabilities() method) gets them collected, with a warning."""
    class Legacy:
        name, k, r = "legacy", 2, 1
        model_agnostic = True
    with pytest.warns(DeprecationWarning, match="capabilities"):
        caps = scheme_capabilities(Legacy())
    assert caps == Capabilities(model_agnostic=True)


def test_flagless_scheme_defaults_silently():
    class Bare:
        name, k, r = "bare", 2, 1
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert scheme_capabilities(Bare()) == Capabilities()


# -------------------------------------------------------------- provision ---
def test_provision_context_caches_deployed_outputs():
    x = np.random.default_rng(0).normal(size=(16, 6)).astype(np.float32)
    p = init_linear(jax.random.PRNGKey(0), 6, 3)
    calls = []

    def counting_fwd(pp, xx):
        calls.append(1)
        return linear_fwd(pp, xx)

    ctx = ParityTrainContext(fwd=counting_fwd, init_fn=None, x_train=x)
    a = ctx.deployed_outputs(p)
    b = ctx.deployed_outputs(p)
    assert a is b and len(calls) == 1


def test_model_agnostic_provisioning_returns_deployed_refs():
    """approxifer and invnet never train: r references to the deployed
    params, init_fn untouched."""
    x = np.random.default_rng(0).normal(size=(32, 6)).astype(np.float32)
    W = init_linear(jax.random.PRNGKey(0), 6, 3)
    for name in ("approxifer", "invnet"):
        pp, scheme = train_parity_models(
            W, linear_fwd, _boom, x, k=2, r=2, scheme=name)
        assert scheme.name == name and len(pp) == 2
        assert all(p is W for p in pp), name


# ------------------------------------------------------------------ fisher ---
def test_fisher_coeffs_are_row_stochastic():
    for k, r in ((2, 1), (3, 2), (4, 3)):
        C = np.asarray(get_scheme("fisher", k=k, r=r).coeffs)
        assert C.shape == (r, k)
        assert (C > 0).all()
        np.testing.assert_allclose(C.sum(axis=1), 1.0, atol=1e-6)


def test_diag_fisher_matches_explicit_per_example_grads():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 5)).astype(np.float32)
    p = init_linear(jax.random.PRNGKey(1), 5, 4)
    fish = diag_fisher(linear_fwd, p, x)

    def nll(pp, xi):
        logits = linear_fwd(pp, xi[None])[0]
        return -jax.nn.log_softmax(logits)[int(np.argmax(logits))]

    grads = [jax.grad(lambda q: nll(q, jnp.asarray(xi)))(p) for xi in x]
    manual = jax.tree.map(
        lambda *gs: np.mean([np.square(np.asarray(g)) for g in gs], axis=0),
        *grads)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), b, atol=1e-5),
        fish, manual)


def test_weighted_merge_scalar_weights_is_convex_combination():
    rng = np.random.default_rng(0)
    a = {"w": rng.normal(size=(4, 3)).astype(np.float32)}
    b = {"w": rng.normal(size=(4, 3)).astype(np.float32)}
    merged = ckpt_io.weighted_merge(
        [a, b], [{"w": np.float32(3.0)}, {"w": np.float32(1.0)}])
    np.testing.assert_allclose(np.asarray(merged["w"]),
                               0.75 * a["w"] + 0.25 * b["w"], atol=1e-5)


def test_fisher_provisioning_is_training_free_and_matches_manual_merge():
    """provision_parity merges the member checkpoints leaf-wise by
    c_ji * (F_i + floor) without a single gradient step or parity-model
    init."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(48, 6)).astype(np.float32)
    m0 = init_linear(jax.random.PRNGKey(1), 6, 3)
    m1 = init_linear(jax.random.PRNGKey(2), 6, 3)
    pp, scheme = train_parity_models(
        [m0, m1], linear_fwd, _boom, x, k=2, r=2, scheme="fisher")
    assert isinstance(scheme, FisherScheme) and len(pp) == 2
    C = np.asarray(scheme.coeffs, np.float64)
    floor = scheme.fisher_floor
    f0 = jax.tree.map(np.asarray, diag_fisher(linear_fwd, m0,
                                              x[:scheme.calib_n]))
    f1 = jax.tree.map(np.asarray, diag_fisher(linear_fwd, m1,
                                              x[:scheme.calib_n]))
    for j in range(2):
        w0, w1 = C[j, 0] * (f0["w"] + floor), C[j, 1] * (f1["w"] + floor)
        manual = (w0 * np.asarray(m0["w"]) + w1 * np.asarray(m1["w"])) / \
            (w0 + w1 + 1e-12)
        np.testing.assert_allclose(np.asarray(pp[j]["w"]), manual,
                                   atol=1e-5, err_msg=f"row {j}")


def test_fisher_identical_members_merge_to_deployed_params():
    """One checkpoint deployed across all members (the serving default):
    every merged parity model equals the deployed params."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 6)).astype(np.float32)
    W = init_linear(jax.random.PRNGKey(0), 6, 3)
    pp, _ = train_parity_models(W, linear_fwd, _boom, x, k=3, r=2,
                                scheme="fisher")
    for p in pp:
        np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(W["w"]),
                                   atol=1e-5)


def test_fisher_merged_params_roundtrip_through_checkpoint_io(tmp_path):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 6)).astype(np.float32)
    m0 = init_linear(jax.random.PRNGKey(1), 6, 3)
    m1 = init_linear(jax.random.PRNGKey(2), 6, 3)
    pp, _ = train_parity_models([m0, m1], linear_fwd, _boom, x, k=2, r=1,
                                scheme="fisher")
    path = os.path.join(tmp_path, "fisher_parity.npz")
    ckpt_io.save(path, pp[0], step=0, extra={"scheme": "fisher"})
    loaded, meta = ckpt_io.load(path, like=m0)
    assert meta["extra"]["scheme"] == "fisher"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        loaded, pp[0])


def test_fisher_rejects_wrong_member_count():
    x = np.zeros((8, 6), np.float32)
    m = init_linear(jax.random.PRNGKey(0), 6, 3)
    with pytest.raises(ValueError, match="per member"):
        train_parity_models([m, m, m], linear_fwd, _boom, x, k=2,
                            scheme="fisher")


# ------------------------------------------------------------------ invnet ---
def test_invnet_g_roundtrips_for_odd_and_even_features():
    for f in (6, 7, 16):
        iv = InvNetScheme(k=2, r=1)
        x = np.random.default_rng(f).normal(size=(5, f)).astype(np.float32)
        y = iv.g_forward(x)
        back = np.asarray(iv.g_inverse(y))
        assert not np.allclose(np.asarray(y), x)   # g is not the identity
        np.testing.assert_allclose(back, x, atol=1e-5)


def test_invnet_decode_bit_exact_on_integer_substrate():
    """Acceptance: invnet decode is BIT-exact on its invertible substrate.
    Integer coupling params, queries and head weights keep every fp32 op
    exact (all values far below 2**24), so reconstruction must be
    np.array_equal — not merely allclose."""
    coupling = [{"w1": jnp.asarray([2.0, -1.0]),
                 "b1": jnp.asarray([1.0, 3.0]),
                 "w2": jnp.asarray([[1.0], [2.0]])},
                {"w1": jnp.asarray([-1.0, 1.0]),
                 "b1": jnp.asarray([0.0, 2.0]),
                 "w2": jnp.asarray([[2.0], [1.0]])}]
    iv = InvNetScheme(k=2, r=1, coupling_params=coupling)
    rng = np.random.default_rng(0)
    x = rng.integers(-4, 5, size=(2, 3, 8)).astype(np.float32)   # [k, B, F]
    W = rng.integers(-3, 4, size=(8, 4)).astype(np.float32)

    def F(q):                                   # substrate: factors through g
        return np.asarray(iv.g_forward(q)) @ W

    parity = np.asarray(iv.encode(x))                            # [1, B, 8]
    g_back = np.asarray(iv.g_inverse(iv.g_forward(x[0])))
    assert np.array_equal(g_back, x[0])         # inversion itself bit-exact
    outs = np.stack([F(x[0]), F(x[1])])                          # [k, B, V]
    p_out = F(parity[0])
    # r=1 Vandermonde row is all-ones: F(p) == F(x0) + F(x1) exactly
    assert np.array_equal(p_out, outs[0] + outs[1])
    for j in range(2):
        rec = np.asarray(iv.decode_one(jnp.asarray(p_out), jnp.asarray(outs),
                                       j))
        assert np.array_equal(rec, outs[j]), f"member {j}"


def test_invnet_pallas_backend_matches_jnp():
    params = init_coupling_params(hidden=8, seed=3)
    a = InvNetScheme(k=2, r=2, backend="jnp", coupling_params=params)
    b = InvNetScheme(k=2, r=2, backend="pallas", coupling_params=params)
    x = np.random.default_rng(1).normal(size=(2, 4, 16)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(a.encode(x)),
                               np.asarray(b.encode(x)), atol=1e-4)


def test_invnet_encode_is_not_fused():
    """The overridden (non-linear) encode must route fused_parity_outputs to
    the exact unfused fallback, with no serving-layer special case."""
    from repro.core import parity as parity_mod
    iv = get_scheme("invnet", k=2, r=1)
    W = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(8, 3)).astype(np.float32))}

    def fwd(p, q):
        return iv.g_forward(q) @ p["w"]

    x = np.random.default_rng(1).normal(size=(2, 4, 8)).astype(np.float32)
    out = np.asarray(parity_mod.fused_parity_outputs(iv, x, [W], fwd))
    manual = np.asarray(fwd(W, iv.encode(x)[0]))[None]
    np.testing.assert_allclose(out, manual, atol=1e-5)
    old = parity_mod._FORCE_FUSED
    try:
        parity_mod._FORCE_FUSED = True
        with pytest.raises(ValueError, match="not fusable"):
            parity_mod.fused_parity_outputs(iv, x, [W], fwd)
    finally:
        parity_mod._FORCE_FUSED = old


def test_invnet_with_params_swaps_couplings():
    base = get_scheme("invnet", k=2, r=1)
    other = init_coupling_params(hidden=8, seed=99)
    swapped = base.with_params(other)
    x = np.random.default_rng(2).normal(size=(3, 10)).astype(np.float32)
    assert not np.allclose(np.asarray(base.g_forward(x)),
                           np.asarray(swapped.g_forward(x)))
    np.testing.assert_allclose(
        np.asarray(swapped.g_inverse(swapped.g_forward(x))), x, atol=1e-5)
