"""The learned coding scheme end-to-end (DESIGN.md §7): joint
encoder+parity training, frozen-encoder serving through both backends and
the threaded frontend, encoder-param serialization, the DES registry sweep,
and the ROADMAP acceptance bar — learned >= sum reconstruction accuracy on
the resnet18_cifar family with one unavailable query per group."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.learned import LearnedScheme, init_encoder_params
from repro.core.parity import train_parity_models
from repro.core.scheme import get_scheme
from repro.models.linear import init_linear, linear_fwd
from repro.serving.runtime import ParMFrontend
from repro.serving.simulator import SimConfig, simulate


def _linear_task(n=256, d=6, v=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    W = init_linear(jax.random.PRNGKey(seed), d, v)
    return x, W


# ------------------------------------------------------------- training ----
def test_joint_training_returns_trained_frozen_encoder():
    """train_parity_models on a trainable scheme must optimise encoder and
    parity models together: the returned scheme carries encoder params that
    moved off their init, and the parity model fits the joint objective."""
    x, W = _linear_task()
    pp, scheme = train_parity_models(
        W, linear_fwd, lambda k: init_linear(k, 6, 3), x, k=2,
        scheme="learned", epochs=20, seed=0)
    assert isinstance(scheme, LearnedScheme) and len(pp) == 1
    fresh = get_scheme("learned", k=2)
    moved = any(
        not np.allclose(np.asarray(scheme.enc_params[key]),
                        np.asarray(fresh.enc_params[key]))
        for key in scheme.enc_params)
    assert moved, "joint training left the encoder at its initialisation"
    # the trained pair must serve the code: F_P(E(X)) ~= sum of outputs
    groups = x[:64].reshape(-1, 2, 6)
    target = np.asarray(linear_fwd(W, jnp.asarray(
        x[:64]))).reshape(-1, 2, 3).sum(1)
    parity_out = np.asarray(linear_fwd(pp[0], scheme.encode(
        jnp.asarray(np.moveaxis(groups, 1, 0)))[0]))
    err = np.abs(parity_out - target).mean()
    assert err < 0.2, err


def test_joint_training_beats_fresh_parity_on_objective():
    """The joint objective must actually descend: a trained (encoder,
    parity) pair fits the targets far better than an untrained one."""
    x, W = _linear_task(seed=1)
    pp, scheme = train_parity_models(
        W, linear_fwd, lambda k: init_linear(k, 6, 3), x, k=2,
        scheme="learned", epochs=15, seed=1)
    groups = np.moveaxis(x[:128].reshape(-1, 2, 6), 1, 0)
    target = np.asarray(linear_fwd(W, jnp.asarray(x[:128]))).reshape(
        -1, 2, 3).sum(1)

    def mse(params, schm):
        out = np.asarray(linear_fwd(params, schm.encode(
            jnp.asarray(groups))[0]))
        return float(((out - target) ** 2).mean())

    trained = mse(pp[0], scheme)
    untrained = mse(init_linear(jax.random.PRNGKey(99), 6, 3),
                    get_scheme("learned", k=2))
    assert trained < untrained * 0.1, (trained, untrained)


# ------------------------------------------------- serving, both layers ----
def test_trained_learned_scheme_through_threaded_runtime():
    """A jointly trained learned scheme (instance, not name) serves coded
    traffic through ParMFrontend: the straggler's prediction is
    reconstructed from the learned parity query's output."""
    x, W = _linear_task()
    pp, scheme = train_parity_models(
        W, linear_fwd, lambda k: init_linear(k, 6, 3), x, k=2,
        scheme="learned", epochs=30, seed=0)
    fe = ParMFrontend(linear_fwd, W, parity_params=pp, k=2, m=2,
                      strategy="parm", scheme=scheme,
                      delay_fn=lambda i: {0: 0.5, 1: 0.1}.get(i, 0.0))
    try:
        xs = [x[i:i + 1] for i in range(4)]
        qs = [fe.submit(i, xi) for i, xi in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        assert any(q.completed_by == "parity" for q in qs)
        for q, xi in zip(qs, xs):
            np.testing.assert_allclose(
                q.result, np.asarray(linear_fwd(W, jnp.asarray(xi))),
                atol=0.35)
    finally:
        fe.shutdown()


def test_learned_scheme_through_simulator():
    """The DES serves the learned scheme by name: registry resolution,
    MDS recoverability, r parity pools — no simulator edits."""
    cfg = SimConfig(n_queries=4000, qps=250, m=8, k=2, seed=0)
    res = simulate(cfg, "parm", scheme="learned")
    assert res["scheme"] == "learned"
    assert res["reconstructions"] > 0
    r2 = simulate(SimConfig(n_queries=4000, qps=250, m=8, k=2, r=2, seed=0),
                  "parm", scheme="learned")
    assert r2["scheme"] == "learned"


def test_learned_pallas_backend_matches_jnp_with_trained_encoder():
    """Frozen-encoder inference: the Pallas fast path (base-code kernel +
    final-projection kernel) must match the jnp path with a NONZERO residual
    — the trained regime, not just the zero-init shortcut."""
    enc = init_encoder_params(3, 2, hidden=16, seed=4, alpha=0.35)
    jnp_s = get_scheme("learned", k=3, r=2, backend="jnp").with_params(enc)
    pal_s = get_scheme("learned", k=3, r=2,
                       backend="pallas").with_params(enc)
    rng = np.random.default_rng(0)
    for shape in [(3, 2, 130), (3, 16), (3, 2, 8, 8, 1)]:
        q = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        np.testing.assert_allclose(np.asarray(jnp_s.encode(q)),
                                   np.asarray(pal_s.encode(q)),
                                   atol=1e-5, rtol=1e-5)


# --------------------------------------------------------- serialization ----
def test_encoder_params_checkpoint_roundtrip(tmp_path):
    """DESIGN.md §7: encoder params are a plain pytree — checkpoint io
    serialises them, and with_params rebuilds an identical serving scheme."""
    from repro.checkpoint import io
    x, W = _linear_task()
    _, scheme = train_parity_models(
        W, linear_fwd, lambda k: init_linear(k, 6, 3), x, k=2,
        scheme="learned", epochs=3, seed=0)
    path = str(tmp_path / "encoder.npz")
    io.save(path, scheme.enc_params, extra={"scheme": scheme.name,
                                            "k": scheme.k, "r": scheme.r})
    loaded, meta = io.load(path, like=scheme.enc_params)
    assert meta["extra"]["scheme"] == "learned"
    restored = get_scheme("learned", k=meta["extra"]["k"],
                          r=meta["extra"]["r"]).with_params(loaded)
    q = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 4, 6)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(scheme.encode(q)),
                               np.asarray(restored.encode(q)), atol=1e-6)


# ------------------------------------------------- accuracy acceptance -----
@pytest.mark.slow
def test_learned_at_least_matches_sum_on_resnet18_cifar():
    """ROADMAP acceptance: on the resnet18_cifar family with one unavailable
    query per coding group, the jointly-trained learned code reconstructs at
    least as accurately as the paper's sum code (it starts AT the sum code —
    zero-init residual — and trains away only when that lowers the parity
    objective)."""
    from repro.eval.unavailability import accuracy_under_unavailability
    res = accuracy_under_unavailability(
        schemes=("sum", "learned"), n_train=3000, n_test=300, noise=0.8,
        deployed_epochs=4, parity_epochs=6, seed=0)
    assert res["A_a"] > 0.8, res            # deployed model actually learned
    a_sum, a_learned = res["schemes"]["sum"], res["schemes"]["learned"]
    assert a_learned >= a_sum, res
    assert a_sum > 0.3, res                 # parity training was meaningful


# ----------------------------------------------------------- LM substrate --
@pytest.mark.slow
def test_lm_joint_parity_step_loss_decreases():
    """Embedding-space joint encoder+parity training on the LM substrate
    (make_joint_parity_train_step): loss must drop and the encoder must
    participate (its params receive nonzero updates)."""
    from repro.configs.base import get_config
    from repro.models import transformer as T
    from repro.training.optim import AdamConfig, adam_init
    from repro.training.train_lib import make_joint_parity_train_step

    cfg = get_config("smollm-135m", reduced=True)
    scheme = get_scheme("learned", k=2)
    deployed = T.init_params(cfg, jax.random.PRNGKey(0))
    params = {"enc": scheme.enc_params,
              "parity": [T.init_params(cfg, jax.random.PRNGKey(1))]}
    opt = AdamConfig(lr=1e-3)
    step = jax.jit(make_joint_parity_train_step(cfg, opt, scheme))
    state = adam_init(params, opt)

    k, B, S = 2, 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(10), (k, B, S), 0,
                              cfg.vocab)
    embeds = jnp.stack([T.embed_tokens(cfg, deployed, t) for t in toks])
    teacher = jnp.stack([T.forward(cfg, deployed, tokens=t)[0]
                         for t in toks])
    batch = {"embeds": embeds, "teacher": teacher}
    losses = []
    for _ in range(12):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses
    assert float(np.abs(np.asarray(params["enc"]["alpha"]))) > 0
    # the trained encoder snaps back into a serving scheme
    served = scheme.with_params(params["enc"])
    assert served.encode(embeds).shape == (1,) + embeds.shape[1:]
