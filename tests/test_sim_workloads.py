"""Trace-driven workloads, multi-tenant serving, and the vectorized DES
hot path: arrival processes (TraceArrivals / diurnal / flash_crowd),
weighted-fair queueing with per-tenant SLO breakdowns, the Trace <->
SimConfig schema lock, the dropped-query completeness error, and — the
load-bearing one — bit-equality between the inlined fast loop and the
general event loop on every eligible configuration class."""
import dataclasses

import numpy as np
import pytest

import repro.serving.simulator as sim_mod
from repro.serving.api import Trace
from repro.serving.scenarios import (DiurnalArrivals, FlashCrowd,
                                     TenantClass, TraceArrivals)
from repro.serving.simulator import SimConfig, simulate


@pytest.fixture
def force_path():
    """Context helper: run simulate() with the fast/general path forced,
    restoring auto-selection afterwards."""
    def run(path, cfg, **kw):
        sim_mod._FORCE_PATH = path
        try:
            return simulate(cfg, **kw)
        finally:
            sim_mod._FORCE_PATH = None
    return run


def _key(rep):
    """Every observable a fast/general divergence could leak through."""
    return (rep.median_ms, rep.p99_ms, rep.p999_ms, rep.mean_ms, rep.max_ms,
            rep.reconstructions, rep.cancelled_queries,
            rep.cancelled_parities, rep.batches, rep.parity_served,
            rep.events, tuple(sorted(rep.completed_by.items())))


# ---------------------------------------------------------------- fast path

FAST_CASES = [
    dict(strategy="parm", scheme="sum", scenario="calm"),
    dict(strategy="parm", scheme="sum", scenario="diurnal"),
    dict(strategy="parm", scheme="sum", scenario="flash_crowd"),
    dict(strategy="parm", scheme="replication", scenario="calm"),
    dict(strategy="parm", scheme="approxifer", scenario="calm"),
    dict(strategy="approx_backup", scenario="calm"),
    dict(strategy="equal_resources", scheme="sum", scenario="calm"),
    dict(strategy="none", scenario="calm"),
]


@pytest.mark.parametrize("case", FAST_CASES,
                         ids=lambda c: f"{c['strategy']}-"
                                       f"{c.get('scheme')}-{c['scenario']}")
def test_fast_path_bit_equal_to_general_loop(case, force_path):
    """The inlined hot loop must be indistinguishable from the general
    event loop — identical RNG draw order, dispatch order and float
    arithmetic — across every recoverability predicate (mds / row / count)
    and the pure arrival-process scenarios.  _FORCE_PATH='fast' raises if
    the config silently fell off the fast path, so eligibility cannot rot
    either."""
    cfg = SimConfig(n_queries=6000, seed=3)
    fast = force_path("fast", cfg, **case)
    general = force_path("general", cfg, **case)
    assert _key(fast) == _key(general)


def test_hazard_scenarios_are_not_fast_eligible(force_path):
    """Configs with realized hazard windows (bursty carries
    NetworkShuffles) must take the general loop."""
    cfg = SimConfig(n_queries=2000, seed=1)
    with pytest.raises(ValueError, match="not eligible"):
        force_path("fast", cfg, strategy="parm", scenario="bursty")


def test_event_count_identity():
    """events = arrivals + finish pops: on a hazard-free run with no
    controller that is n + main batches + parity items served — the
    derived counters the fast path reports must satisfy the same identity
    the general loop counts out event by event (their bit-equality is
    asserted above; this pins what the number MEANS)."""
    for strat in ("parm", "none"):
        rep = simulate(SimConfig(n_queries=4000, seed=1), strat,
                       scenario="calm")
        assert rep.events == rep.n + rep.batches + rep.parity_served


# ---------------------------------------------------------- arrival processes

def test_trace_arrivals_validation():
    rng = np.random.default_rng(0)
    cfg = SimConfig(n_queries=4)
    with pytest.raises(ValueError, match="non-empty"):
        TraceArrivals(times_ms=()).arrival_times(cfg, rng)
    with pytest.raises(ValueError, match="non-decreasing"):
        TraceArrivals(times_ms=(5.0, 3.0)).arrival_times(cfg, rng)


def test_trace_arrivals_cycles_short_trace():
    """A trace shorter than n_queries tiles cyclically: the inter-arrival
    pattern repeats with period = span + mean gap, and the resulting
    timeline stays non-decreasing."""
    from repro.serving.scenarios import Scenario, register_scenario
    times = (0.0, 1.0, 10.0, 11.0)
    register_scenario(Scenario("_test_trace", (TraceArrivals(times),)))
    cfg = SimConfig(n_queries=12, seed=0)
    rep = simulate(cfg, "none", scenario="_test_trace")
    assert rep.n == 12                               # all 12 served
    # reconstruct the expected tiling directly
    arr = TraceArrivals(times).arrival_times(cfg, np.random.default_rng(0))
    assert arr.shape == (12,)
    assert np.all(np.diff(arr) >= 0)
    base = np.asarray(times)
    period = (base[-1] - base[0]) + np.diff(base).mean()
    np.testing.assert_allclose(arr[4:8], base + period)
    np.testing.assert_allclose(arr[8:12], base + 2 * period)


def test_trace_arrivals_no_cycle_requires_enough_timestamps():
    proc = TraceArrivals((0.0, 1.0), cycle=False)
    with pytest.raises(ValueError, match="cycle"):
        proc.arrival_times(SimConfig(n_queries=5), np.random.default_rng(0))


@pytest.mark.parametrize("scen", ["diurnal", "flash_crowd"])
def test_nonhomogeneous_arrivals_complete_and_shift_tail(scen):
    """The NHPP scenarios answer every query and produce a worse tail than
    the constant-rate calm run at the same mean load — the whole point of
    modelling diurnal/spike shapes."""
    cfg = SimConfig(n_queries=8000, seed=2)
    shaped = simulate(cfg, "parm", scenario=scen)
    calm = simulate(cfg, "parm", scenario="calm")
    assert shaped.n == cfg.n_queries
    assert shaped.p999_ms > calm.p999_ms


def test_diurnal_period_shapes_arrivals():
    """Arrivals under the diurnal process cluster at the sinusoid peak:
    the busiest period-slice must hold measurably more arrivals than the
    quietest one."""
    proc = DiurnalArrivals(period_ms=10_000.0, amplitude=0.9)
    arr = proc.arrival_times(SimConfig(n_queries=20000, qps=270.0),
                             np.random.default_rng(7))
    phase = np.mod(arr, 10_000.0)
    counts, _ = np.histogram(phase, bins=10, range=(0, 10_000.0))
    assert counts.max() > 2 * max(counts.min(), 1)


def test_flash_crowd_spikes_recur():
    """FlashCrowd piles arrivals into the decay window after each spike
    onset, every ``every_ms``."""
    proc = FlashCrowd(spike_mult=10.0, every_ms=5_000.0, decay_ms=500.0)
    arr = proc.arrival_times(SimConfig(n_queries=20000, qps=200.0),
                             np.random.default_rng(7))
    phase = np.mod(arr, 5_000.0)
    in_spike = (phase < 1_000.0).mean()
    assert in_spike > 0.4          # >2x the 20% a flat process would put


# ------------------------------------------------------------- multi-tenant

def test_wfq_tenants_breakdown_and_priority():
    """Two classes under load: the report's per_tenant block carries the
    breakdown, shares land near their targets, and the 4x-weight class
    sees a strictly better median AND tail than the best-effort class."""
    cfg = SimConfig(n_queries=20000, qps=460, m=12, k=2, seed=1,
                    tenants=(TenantClass("gold", share=0.3, weight=4.0,
                                         slo_ms=60.0),
                             TenantClass("free", share=0.7, weight=1.0)))
    rep = simulate(cfg, "parm")
    assert set(rep.per_tenant) == {"gold", "free"}
    gold, free = rep.per_tenant["gold"], rep.per_tenant["free"]
    assert gold["n"] + free["n"] == cfg.n_queries
    assert abs(gold["share"] - 0.3) < 0.02
    assert gold["median_ms"] < free["median_ms"]
    assert gold["p999_ms"] < free["p999_ms"]
    # per-class SLO: gold is judged against its own 60 ms deadline, free
    # against the config default (200 ms)
    assert gold["slo_ms"] == 60.0 and free["slo_ms"] == cfg.slo_ms
    assert gold["slo_violations"] > 0 and free["slo_violations"] == 0


def test_tenant_class_validation():
    with pytest.raises(ValueError):
        TenantClass("bad", share=0.0)
    with pytest.raises(ValueError):
        TenantClass("bad", weight=0.0)
    with pytest.raises(ValueError):
        TenantClass("bad", slo_ms=-1.0)


def test_tenants_accept_dicts_and_roundtrip_through_trace():
    """A Trace carrying TenantClass entries replays through the sim engine
    (deploy(...).replay flattens dataclasses — dict entries must rehydrate
    to the same classes)."""
    cfg = SimConfig(n_queries=3000, seed=1,
                    tenants=({"name": "a", "share": 0.5, "weight": 2.0},
                             {"name": "b", "share": 0.5}))
    rep = simulate(cfg, "parm")
    assert set(rep.per_tenant) == {"a", "b"}


def test_single_tenant_report_has_empty_breakdown():
    rep = simulate(SimConfig(n_queries=2000, seed=1), "parm")
    assert rep.per_tenant == {}


# ------------------------------------------------------------- schema lock

def test_trace_fields_all_exist_on_simconfig_with_equal_defaults():
    """Every Trace field mirrors a SimConfig field with the same default —
    the two surfaces are one workload schema, and a field added to Trace
    without its SimConfig half would silently drop on replay."""
    sim_fields = {f.name: f for f in dataclasses.fields(SimConfig)}
    for f in dataclasses.fields(Trace):
        assert f.name in sim_fields, (
            f"Trace.{f.name} has no SimConfig counterpart")
        assert f.default == getattr(SimConfig, f.name), (
            f"Trace.{f.name} default {f.default!r} != "
            f"SimConfig default {getattr(SimConfig, f.name)!r}")


# -------------------------------------------------- completeness / futures

def test_dropped_queries_raise_runtime_error_naming_qids():
    """The completeness check is a RuntimeError (not an assert stripped by
    ``python -O``) and names the unanswered qids — the regression test for
    the silent-percentile-over-short-array failure mode."""
    cfg = SimConfig(n_queries=6)
    strat = sim_mod.get_strategy("none")
    latency = np.array([1.0, np.inf, 2.0, np.inf, 3.0, 4.0])
    with pytest.raises(RuntimeError) as ei:
        sim_mod._finalize_report(
            cfg, strat, {"schm": None, "gk": 1, "r": 0, "enc_ms": 0.0},
            None, None, 0, (), latency, np.zeros(6, np.int8),
            0, 0, 6, 6, 0, 0, 0, 12)
    msg = str(ei.value)
    assert "dropped 2 of 6" in msg
    assert "unanswered qids: 1, 3" in msg


def test_prediction_future_repr_states():
    """repr shows pending while unresolved, the completion path once
    fulfilled — and 'pending' (not an empty string) for a done-but-
    unattributed future, the operator-precedence regression."""
    from repro.serving.api import PredictionFuture
    from repro.serving.runtime import Query

    q = Query(qid=7, data=np.zeros(1))
    fut = PredictionFuture(q)
    assert repr(fut) == "PredictionFuture(qid=7, pending)"
    q.fulfill(np.zeros(1), "model")
    assert repr(fut) == "PredictionFuture(qid=7, model)"

    q2 = Query(qid=8, data=np.zeros(1))
    fut2 = PredictionFuture(q2)
    q2.fulfill(np.zeros(1), "default")
    assert repr(fut2) == "PredictionFuture(qid=8, default)"

    # done but completed_by never attributed: must render as pending, not
    # as "PredictionFuture(qid=9, )" (the old `or` mis-parse)
    q3 = Query(qid=9, data=np.zeros(1))
    q3.event.set()
    assert repr(PredictionFuture(q3)) == "PredictionFuture(qid=9, pending)"


# ---------------------------------------------------------------- windows

def test_controller_windows_deterministic_and_bucketed_once():
    """The ordered completion ring buffer behind ctl events: windows and
    the adjustment log are identical across reruns, and every completion
    is bucketed into exactly one window (counts across windows sum to at
    most n, never more — the double-rebuild bug double-counted)."""
    cfg = SimConfig(n_queries=8000, seed=1)
    a = simulate(cfg, "parm", scenario="bursty", controller="threshold")
    b = simulate(cfg, "parm", scenario="bursty", controller="threshold")
    assert a.adjustments == b.adjustments and len(a.adjustments) >= 1
    assert a.windows == b.windows and a.windows > 0
    assert _key(a) == _key(b)
