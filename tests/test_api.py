"""The declarative serving surface (repro.serving.api): DeploymentSpec
validation, deploy() engine selection, PredictionFuture semantics, the
typed ServingReport, and the legacy-constructor shims."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.api import (BatchingPolicy, DeploymentSpec, SimSession,
                               Trace, deploy)
from repro.serving.report import ServingReport
from repro.serving.runtime import ParMFrontend


def _linear_fwd(p, x):
    return x @ p


def _spec(**kw):
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    base = dict(fwd=_linear_fwd, params=W, parity_params=W, strategy="parm",
                k=2, m=2)
    base.update(kw)
    return DeploymentSpec(**base)


# ------------------------------------------------------------ validation ----
def test_spec_is_frozen_and_replace_copies():
    spec = _spec()
    with pytest.raises(AttributeError):
        spec.m = 12
    spec2 = spec.replace(m=12, batching=BatchingPolicy(max_size=4))
    assert spec2.m == 12 and spec2.batching.max_size == 4
    assert spec.m == 2 and spec.batching.max_size == 1    # original untouched


def test_spec_rejects_bad_values():
    with pytest.raises(ValueError, match="k and m"):
        _spec(k=0)
    with pytest.raises(TypeError, match="BatchingPolicy"):
        _spec(batching=4)
    with pytest.raises(ValueError, match="max_size"):
        BatchingPolicy(max_size=0)
    with pytest.raises(ValueError, match="max_delay_ms"):
        BatchingPolicy(max_delay_ms=-1.0)


def test_deploy_rejects_unknown_engine_and_non_spec():
    with pytest.raises(ValueError, match="unknown engine"):
        deploy(_spec(), engine="cloud")
    with pytest.raises(TypeError, match="DeploymentSpec"):
        deploy({"strategy": "parm"})


def test_threads_engine_requires_model():
    with pytest.raises(ValueError, match="fwd= and params="):
        deploy(DeploymentSpec(strategy="parm"), engine="threads")
    # ... but the sim engine deliberately does not
    rep = deploy(DeploymentSpec(strategy="parm", k=2, m=4),
                 engine="sim").replay(Trace(n_queries=200, qps=200, seed=0))
    assert rep["n"] == 200


# ------------------------------------------------------- threads session ----
def test_threads_session_submit_futures_and_context_manager():
    rng = np.random.default_rng(0)
    spec = _spec()
    with deploy(spec) as sess:
        assert sess.engine == "threads"
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(4)]
        futs = [sess.submit(x) for x in xs]
        assert [f.qid for f in futs] == [0, 1, 2, 3]     # auto-assigned qids
        for f, x in zip(futs, xs):
            np.testing.assert_allclose(
                f.result(timeout=10.0),
                np.asarray(_linear_fwd(spec.params, x)), atol=1e-4)
            assert f.done() and f.completed_by in ("model", "parity")
            assert f.latency_ms > 0
        rep = sess.stats()
        assert isinstance(rep, ServingReport) and rep.engine == "threads"
        workers = sess.frontend.workers
    # the with-block shut the session down: every worker retired
    assert all(not w.is_alive() for w in workers)


def test_future_result_timeout_raises():
    spec = _spec(strategy="none", m=1,
                 delay_fn=lambda i: 0.5)         # the lone worker is stuck
    with deploy(spec) as sess:
        fut = sess.submit(np.ones((1, 8), np.float32))
        assert not fut.done()
        with pytest.raises(TimeoutError, match="unanswered"):
            fut.result(timeout=0.05)
        np.testing.assert_allclose(
            fut.result(timeout=10.0),
            np.asarray(_linear_fwd(spec.params, np.ones((1, 8)))), atol=1e-3)


def test_future_deadline_state_with_slo():
    default = np.zeros((1, 5), np.float32)
    spec = _spec(strategy="default_slo", m=1, slo_ms=50.0,
                 default_prediction=default, delay_fn=lambda i: 0.4)
    with deploy(spec) as sess:
        fut = sess.submit(np.ones((1, 8), np.float32))
        assert fut.deadline_exceeded is False            # still pending
        res = fut.result(timeout=5.0)
        np.testing.assert_allclose(res, default)
        assert fut.completed_by == "default"
        assert fut.deadline_exceeded is True


def test_future_deadline_not_exceeded_for_fast_query():
    spec = _spec(strategy="none", slo_ms=5000.0)
    with deploy(spec) as sess:
        fut = sess.submit(np.ones((1, 8), np.float32))
        fut.result(timeout=10.0)
        assert fut.deadline_exceeded is False


# ----------------------------------------------------------- sim session ----
def test_sim_session_replay_and_stats():
    spec = DeploymentSpec(strategy="parm", k=2, m=12)
    sess = deploy(spec, engine="sim")
    assert isinstance(sess, SimSession)
    with pytest.raises(RuntimeError, match="no replay has run"):
        sess.stats()
    with pytest.raises(RuntimeError, match="trace-driven"):
        sess.submit(np.ones((1, 8)))
    rep = sess.replay(Trace(n_queries=2000, qps=270, seed=1))
    assert rep is sess.stats()
    assert rep.engine == "sim" and rep.strategy == "parm"
    assert rep.n == 2000 and rep.reconstructions > 0
    # keyword overrides patch the trace for one-off replays
    rep2 = sess.replay(Trace(n_queries=2000, qps=270, seed=1), qps=150)
    assert rep2.median_ms <= rep.median_ms


def test_sim_session_consumes_spec_knobs():
    """m/k/r, slo and the batching policy must reach the SimConfig."""
    spec = DeploymentSpec(strategy="parm", k=2, r=2, m=12,
                          batching=BatchingPolicy(max_size=4))
    rep = deploy(spec, engine="sim").replay(
        Trace(n_queries=2000, qps=520, seed=1))
    assert rep.mean_batch_size > 1.0            # overload formed batches
    slo_spec = DeploymentSpec(strategy="default_slo", k=2, m=2, slo_ms=40.0)
    rep = deploy(slo_spec, engine="sim").replay(
        Trace(n_queries=2000, qps=400, seed=1))
    assert rep.completed_by.get("default", 0) > 0
    assert rep.max_ms <= 40.0 + 1e-6            # every late answer defaulted


# -------------------------------------------------------------- report ------
def test_report_mapping_protocol():
    rep = ServingReport(engine="sim", strategy="parm", n=3,
                        completed_by={"model": 3})
    assert rep["strategy"] == "parm" and rep["n"] == 3
    assert "p999_ms" in rep and "nope" not in rep
    with pytest.raises(KeyError):
        rep["nope"]
    assert set(rep) >= {"engine", "strategy", "cancelled_queries",
                        "mean_batch_size"}
    assert len(rep) == len(list(rep))
    assert dict(rep)["completed_by"] == {"model": 3}
    assert rep.cancellations == 0
    assert "parm" in rep.summary()


def test_report_equality_is_field_wise():
    a = ServingReport(engine="sim", strategy="parm", n=1)
    b = ServingReport(engine="sim", strategy="parm", n=1)
    assert a == b
    assert a != ServingReport(engine="threads", strategy="parm", n=1)


# ------------------------------------------------------------ legacy shims --
def test_frontend_legacy_kwargs_fold_into_spec():
    W = jnp.ones((4, 3), jnp.float32)
    fe = ParMFrontend(_linear_fwd, W, parity_params=W, k=2, m=2,
                      strategy="parm")
    try:
        assert isinstance(fe.spec, DeploymentSpec)
        assert fe.spec.k == 2 and fe.spec.m == 2
        assert fe.spec.batching.max_size == 1
    finally:
        fe.shutdown()


def test_frontend_rejects_spec_plus_legacy_kwargs():
    W = jnp.ones((4, 3), jnp.float32)
    spec = _spec()
    with pytest.raises(TypeError, match="not both"):
        ParMFrontend(_linear_fwd, W, spec=spec)


def test_frontend_mode_kwarg_raises_through_spec_path():
    W = jnp.ones((4, 3), jnp.float32)
    with pytest.raises(TypeError, match="strategy="):
        ParMFrontend(_linear_fwd, W, k=2, m=1, mode="none")


def test_threads_and_sim_sessions_share_one_spec_object():
    """The core redesign contract in miniature: one spec object, two
    engines, coherent reports."""
    spec = _spec(m=2)
    sim = deploy(spec, engine="sim").replay(
        Trace(n_queries=100, qps=300, seed=0, n_shuffles=0))
    with deploy(spec, engine="threads") as sess:
        futs = [sess.submit(np.ones((1, 8), np.float32)) for _ in range(4)]
        assert sess.wait_all(timeout=20)
        del futs
        rt = sess.stats()
    assert (sim.strategy, sim.scheme) == (rt.strategy, rt.scheme)
    assert sim.engine == "sim" and rt.engine == "threads"


def test_threads_batching_respects_max_delay_budget():
    """max_delay_ms bounds how long a worker holds a batch open: a lone
    query must not wait out a large max_size."""
    spec = _spec(strategy="none", m=1,
                 batching=BatchingPolicy(max_size=64, max_delay_ms=30.0))
    with deploy(spec) as sess:
        t0 = time.perf_counter()
        fut = sess.submit(np.ones((1, 8), np.float32))
        fut.result(timeout=10.0)
        # one query, batch held open <= ~30ms + inference, not unbounded
        assert time.perf_counter() - t0 < 2.0
        assert sess.stats().completed_by == {"model": 1}


# ------------------------------------------------- review-hardening cases ---
def test_submit_rejects_duplicate_qid_and_counter_skips_past_explicit():
    spec = _spec(strategy="none")
    with deploy(spec) as sess:
        f3 = sess.submit(np.ones((1, 8), np.float32), qid=3)
        assert f3.qid == 3
        with pytest.raises(ValueError, match="already submitted"):
            sess.submit(np.ones((1, 8), np.float32), qid=3)
        f4 = sess.submit(np.ones((1, 8), np.float32))
        assert f4.qid == 4                  # auto counter skipped past 3
        assert f3.result(10.0) is not None and f4.result(10.0) is not None


def test_frontend_requires_model_at_construction():
    """A missing fwd/params must fail at construction, not as a silent
    worker-thread crash with futures hanging until timeout."""
    with pytest.raises(ValueError, match="fwd= and"):
        ParMFrontend(_linear_fwd)           # deployed_params forgotten
    with pytest.raises(ValueError, match="fwd= and"):
        ParMFrontend(spec=DeploymentSpec(strategy="none"))


def test_frontend_rejects_any_stray_legacy_kwarg_next_to_spec():
    spec = _spec(strategy="none")
    with pytest.raises(TypeError, match="slo_ms"):
        ParMFrontend(spec=spec, slo_ms=100.0)
    with pytest.raises(TypeError, match="strategy"):
        ParMFrontend(spec=spec, strategy="default_slo")


def test_trace_defaults_are_simconfig_defaults():
    """The calibration constants live in ONE place: Trace's defaults must
    track SimConfig's field for field."""
    from dataclasses import fields
    from repro.serving.simulator import SimConfig
    sim_defaults = {f.name: f.default for f in fields(SimConfig)}
    for f in fields(Trace):
        assert f.default == sim_defaults[f.name], f.name


def test_report_is_hashable():
    """The frozen report is a value object: hashing must work (the dict
    field is excluded from the generated __hash__, not from equality)."""
    a = ServingReport(engine="sim", strategy="parm", n=1,
                      completed_by={"model": 1})
    b = ServingReport(engine="sim", strategy="parm", n=1,
                      completed_by={"model": 1})
    assert hash(a) == hash(b)
    assert len({a, b}) == 1
    assert a != ServingReport(engine="sim", strategy="parm", n=1,
                              completed_by={"parity": 1})


def test_slo_none_disables_deadline_on_both_engines():
    """default_slo with slo_ms left None must behave identically on both
    engines: NO deadline (the threads engine arms no timers, so the sim
    must not invent the SimConfig default)."""
    spec = DeploymentSpec(strategy="default_slo", k=2, m=2)
    rep = deploy(spec, engine="sim").replay(
        Trace(n_queries=500, qps=300, seed=0, n_shuffles=0))
    assert "default" not in rep.completed_by
    assert rep.completed_by["model"] == 500
    # plain SimConfig users keep the calibrated 200 ms default
    from repro.serving.simulator import SimConfig, simulate
    direct = simulate(SimConfig(n_queries=500, qps=300, m=2, k=2, seed=0,
                                service_ms=300.0, n_shuffles=0),
                      "default_slo")
    assert direct.completed_by.get("default", 0) > 0


def test_report_mapping_view_is_fields_plus_cancellations_only():
    rep = ServingReport(engine="sim", strategy="parm",
                        cancelled_queries=2, cancelled_parities=1)
    assert rep["cancellations"] == 3
    assert "cancellations" in rep and dict(rep)["cancellations"] == 3
    for not_a_key in ("summary", "keys", "items", "_key_names"):
        assert not_a_key not in rep
        with pytest.raises(KeyError):
            rep[not_a_key]


def test_submit_after_shutdown_fails_fast():
    """No futures that hang until timeout: a closed session/frontend must
    reject new work immediately."""
    spec = _spec(strategy="none")
    sess = deploy(spec)
    sess.submit(np.ones((1, 8), np.float32)).result(timeout=10.0)
    sess.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        sess.submit(np.ones((1, 8), np.float32))
    fe = ParMFrontend(_linear_fwd, jnp.ones((4, 3), jnp.float32), k=2, m=1,
                      strategy="none")
    fe.shutdown()
    with pytest.raises(RuntimeError, match="shut down"):
        fe.submit(0, np.ones((1, 4), np.float32))


def test_batching_mixed_shapes_serve_per_shape_group():
    """A mixed-shape backlog must not kill the batching worker: same-shape
    queries stack into one call, the odd one out gets its own call, and
    every future resolves exactly."""

    def sum_fwd(p, x):                      # shape-polymorphic model
        del p
        return np.asarray(x).sum(axis=1, keepdims=True)

    spec = DeploymentSpec(fwd=sum_fwd, params=np.zeros(1), strategy="none",
                          m=1, delay_fn=lambda i: 0.15,
                          batching=BatchingPolicy(max_size=8))
    with deploy(spec) as sess:
        xs = [np.ones((1, 8), np.float32), np.ones((1, 8), np.float32),
              np.ones((1, 4), np.float32), np.ones((1, 8), np.float32)]
        futs = [sess.submit(x) for x in xs]
        for f, x in zip(futs, xs):
            np.testing.assert_allclose(f.result(timeout=15.0),
                                       x.sum(axis=1, keepdims=True))
        assert sess.stats().completed_by == {"model": 4}


def test_backend_validated_identically_by_both_engines():
    """spec.backend reaches get_scheme on BOTH engines: a bogus backend must
    fail the same way, and a valid one must deploy on both."""
    bad = _spec(backend="nope")
    with pytest.raises(ValueError, match="backend"):
        deploy(bad, engine="threads")
    with pytest.raises(ValueError, match="backend"):
        deploy(bad, engine="sim").replay(Trace(n_queries=50, qps=200))
    ok = DeploymentSpec(strategy="parm", k=2, m=4, backend="pallas")
    rep = deploy(ok, engine="sim").replay(Trace(n_queries=200, qps=200,
                                                seed=0, n_shuffles=0))
    assert rep.scheme == "sum" and rep.n == 200
    # ... including under a NON-coded strategy, where the code is never
    # used: an undeployable spec must not replay silently
    for bad_noncoded in (DeploymentSpec(strategy="none", backend="bogus"),
                         DeploymentSpec(strategy="none", scheme="nope")):
        with pytest.raises((ValueError, KeyError)):
            deploy(bad_noncoded, engine="sim").replay(
                Trace(n_queries=50, qps=200))


def test_legacy_kwarg_surface_warns_toward_deploy():
    W = jnp.ones((4, 3), jnp.float32)
    with pytest.warns(DeprecationWarning, match="DeploymentSpec"):
        fe = ParMFrontend(_linear_fwd, W, k=2, m=1, strategy="none")
    fe.shutdown()
    # the canonical spec path stays silent
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        fe = ParMFrontend(spec=_spec(strategy="none"))
    fe.shutdown()


def test_flushed_future_never_reports_deadline_exceeded():
    """A shutdown-flushed query's finish time is a teardown artifact: the
    future must not turn it into a phantom SLO violation."""
    spec = _spec(slo_ms=0.001, delay_fn=lambda i: 0.3, m=1)
    sess = deploy(spec)
    fut = sess.submit(np.ones((1, 8), np.float32))  # partial group of 1
    sess.shutdown()
    assert fut.completed_by == "flushed"
    assert fut.deadline_exceeded is False
