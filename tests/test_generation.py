"""Coded autoregressive LM serving (serving/generation.py).

The exactness substrate is a running-sum linear model: the "KV cache" is
one state vector per slot, ``state += embed(token)`` per step, ``logits =
state @ W``.  Logits are linear in the input embeddings, so embedding-space
encode + logit-space decode is EXACT — a reconstructed step must emit the
same token the straggler would have, and the continuous-batching invariants
(slot isolation, batched == sequential) must hold bit-for-bit.
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.api import BatchingPolicy, deploy_lm
from repro.serving.generation import (GenerationSpec, LMSimSession,
                                      token_service_ms)
from repro.serving.scenarios import instance_id

V, D = 29, 8


def _linear_substrate(seed=0):
    rng = np.random.default_rng(seed)
    emb = jnp.asarray(rng.normal(size=(V, D)).astype(np.float32))
    W = jnp.asarray(rng.normal(size=(D, V)).astype(np.float32))
    params = {"embed": emb, "W": W}

    def embed_fn(p, tokens):
        return p["embed"][jnp.asarray(tokens)]

    def prefill_fn(p, tokens=None, embeds=None, cache_len=0):
        e = embeds if embeds is not None else embed_fn(p, tokens)
        state = jnp.sum(e, axis=1)                       # [B, D]
        return (state @ p["W"])[:, None], {"state": state[None]}

    def decode_fn(p, cache, pos, token=None, embed=None):
        e = embed if embed is not None else embed_fn(p, token)   # [B, 1, D]
        state = cache["state"] + e[None, :, 0]           # [1, B, D]
        return (state[0] @ p["W"])[:, None], {"state": state}

    def init_cache_fn(p, batch, cache_len):
        return {"state": jnp.zeros((1, batch, D), jnp.float32)}

    return params, dict(prefill_fn=prefill_fn, decode_fn=decode_fn,
                        embed_fn=embed_fn, init_cache_fn=init_cache_fn)


def _spec(params, fns, **kw):
    defaults = dict(params=params, k=2, r=1, scheme="sum",
                    batching=BatchingPolicy(max_size=2), max_seq_len=64,
                    max_new_tokens=5, straggle_ms=2_000.0, **fns)
    defaults.update(kw)
    return GenerationSpec(**defaults)


def _prompts(n, seed=3, lo=2, hi=9):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(0, V, rng.integers(lo, hi))]
            for _ in range(n)]


def _run(spec, prompts, poll=None):
    with deploy_lm(spec, engine="threads") as sess:
        futs = []
        for i, p in enumerate(prompts):
            futs.append(sess.submit(p))
            if poll:
                poll(i, futs)
        assert sess.wait_all(60.0)
        toks = [f.result(1.0) for f in futs]
        return toks, sess.stats(), futs


def _reference(params, fns, prompt, n_tokens):
    """Uncoded greedy loop straight on the substrate."""
    logits, cache = fns["prefill_fn"](params,
                                      tokens=jnp.asarray([prompt], jnp.int32))
    out = [int(np.argmax(np.asarray(logits[0, -1])))]
    for _ in range(n_tokens - 1):
        logits, cache = fns["decode_fn"](
            params, cache, None, token=jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(np.argmax(np.asarray(logits[0, 0]))))
    return out


# -------------------------------------------------------------------------
# correctness: coded serving == uncoded greedy decode
# -------------------------------------------------------------------------
def test_matches_reference_greedy_decode():
    params, fns = _linear_substrate()
    prompts = _prompts(3)
    toks, report, _ = _run(_spec(params, fns), prompts)
    for p, t in zip(prompts, toks):
        assert t == _reference(params, fns, p, 5)
    assert report.n == 3 * 5
    assert report.reconstructed_steps == 0


def test_reconstructed_steps_emit_the_stragglers_tokens():
    """Member 0 misses every per-step deadline; parity reconstruction must
    keep its streams flowing with the exact tokens it would have emitted."""
    params, fns = _linear_substrate()
    slow = instance_id("main", 0)

    def delay(iid):
        return 0.3 if iid == slow else 0.0

    prompts = _prompts(2)
    spec = _spec(params, fns, batching=BatchingPolicy(max_size=1),
                 straggle_ms=50.0, delay_fn=delay)
    toks, report, futs = _run(spec, prompts)
    for p, t in zip(prompts, toks):
        assert t == _reference(params, fns, p, 5)
    # request 0 landed on member 0 (members fill first): its decode steps
    # were served from parity
    assert report.reconstructed_steps > 0
    assert futs[0].reconstructed_steps > 0
    assert report.completed_by.get("parity", 0) == report.reconstructed_steps


def test_irrecoverable_step_blocks_but_stays_correct():
    """More stragglers than parities: the step must block for the straggler
    (no silent wrong answer) and still emit the right tokens."""
    params, fns = _linear_substrate()

    members = {instance_id("main", 0), instance_id("main", 1)}

    def delay(iid):                     # both members slow, parity fast
        return 0.1 if iid in members else 0.0

    prompts = _prompts(2, seed=11)
    spec = _spec(params, fns, straggle_ms=20.0, delay_fn=delay,
                 max_new_tokens=3)
    toks, report, _ = _run(spec, prompts)
    for p, t in zip(prompts, toks):
        assert t == _reference(params, fns, p, 3)
    assert report.reconstructed_steps == 0


# -------------------------------------------------------------------------
# continuous-batching invariants
# -------------------------------------------------------------------------
def test_batched_equals_sequential_bit_equal():
    """Submitting everything upfront (continuous batching) and one-at-a-time
    (sequential) must produce bit-identical token streams."""
    params, fns = _linear_substrate(seed=5)
    prompts = _prompts(5, seed=7)
    spec = _spec(params, fns)
    batched, _, _ = _run(spec, prompts)

    sequential = []
    with deploy_lm(spec, engine="threads") as sess:
        for p in prompts:
            fut = sess.submit(p)
            sequential.append(fut.result(30.0))
    assert batched == sequential


def test_mid_flight_join_does_not_perturb_resident_stream():
    """A stream that joins mid-generation must not change a resident
    stream's remaining tokens (slot isolation, bit-equal)."""
    params, fns = _linear_substrate(seed=2)
    [pa, pb] = _prompts(2, seed=13)
    spec = _spec(params, fns, max_new_tokens=8)

    solo, _, _ = _run(spec, [pa])

    with deploy_lm(spec, engine="threads") as sess:
        fa = sess.submit(pa)
        deadline = time.monotonic() + 30.0
        while len(fa.tokens_so_far) < 3:        # genuinely mid-generation
            assert time.monotonic() < deadline
            time.sleep(1e-3)
        fb = sess.submit(pb)
        a, b = fa.result(30.0), fb.result(30.0)
    assert a == solo[0]
    assert b == _reference(params, fns, pb, 8)


def test_slot_recycling_under_oversubscription():
    """9 requests through 2x2 slots: every one completes, slots recycle."""
    params, fns = _linear_substrate(seed=4)
    prompts = _prompts(9, seed=17)
    spec = _spec(params, fns, max_new_tokens=3)
    toks, report, futs = _run(spec, prompts)
    assert len(toks) == 9
    for p, t in zip(prompts, toks):
        assert t == _reference(params, fns, p, 3)
    assert sorted(f.rid for f in futs) == list(range(9))
    assert report.n == 9 * 3


# -------------------------------------------------------------------------
# report + transformer substrate + sim engine
# -------------------------------------------------------------------------
def test_report_per_token_fields():
    params, fns = _linear_substrate()
    _, report, futs = _run(_spec(params, fns), _prompts(2))
    assert report.engine == "threads"
    assert report.tokens_per_s > 0
    assert report.inter_token_p50_ms == report.median_ms
    assert np.isfinite(report.inter_token_p999_ms)
    assert report["reconstructed_steps"] == 0       # Mapping protocol
    for f in futs:
        gaps = f.inter_token_ms
        assert len(gaps) == 5 and all(g >= 0 for g in gaps)


@pytest.mark.slow
def test_transformer_substrate_end_to_end():
    import jax
    from repro.configs.base import get_config
    from repro.models import transformer as T
    cfg = get_config("qwen2-0.5b", reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    spec = GenerationSpec(cfg=cfg, params=params, k=2, r=1, scheme="sum",
                          batching=BatchingPolicy(max_size=2),
                          max_seq_len=32, max_new_tokens=3,
                          straggle_ms=10_000.0)
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    with deploy_lm(spec, engine="threads") as sess:
        futs = [sess.submit(p) for p in prompts]
        assert sess.wait_all(120.0)
        toks = [f.result(1.0) for f in futs]
    # reference greedy loop on the raw model
    for p, t in zip(prompts, toks):
        logits, cache = T.prefill(cfg, params,
                                  tokens=jnp.asarray([p], jnp.int32),
                                  cache_len=32)
        ref = [int(np.argmax(np.asarray(logits[0, -1])))]
        pos = len(p)
        for _ in range(2):
            logits, cache = T.decode_step(
                cfg, params, cache, pos,
                token=jnp.asarray([[ref[-1]]], jnp.int32))
            ref.append(int(np.argmax(np.asarray(logits[0, 0]))))
            pos += 1
        assert t == ref


@pytest.mark.parametrize("scenario", ["bursty", "storm"])
def test_sim_engine_coded_beats_uncoded_tail(scenario):
    """Roofline-calibrated token-level DES on a big config: below the
    capacity knee the coded and uncoded medians match (both ~ the roofline
    step time) and coded generation's inter-token p999 beats the uncoded
    equal-resources baseline (the PR's acceptance criterion, CI-gated at
    smoke scale)."""
    from repro.configs.base import get_config
    cfg = get_config("qwen3-moe-235b-a22b")
    base = GenerationSpec(cfg=cfg, k=4, r=1, m=12, utilization=0.3,
                          kv_len=4096, tp=8, scenario=scenario)
    step_ms = token_service_ms(base)
    assert 1.0 < step_ms < 100.0                     # calibration sanity
    coded = deploy_lm(base, engine="sim").replay(n_tokens=20_000, seed=1)
    uncoded = deploy_lm(base.replace(strategy="equal_resources"),
                        engine="sim").replay(n_tokens=20_000, seed=1)
    assert coded.reconstructed_steps > 0
    assert coded.inter_token_p50_ms == pytest.approx(
        uncoded.inter_token_p50_ms, rel=0.15)        # "at the same median"
    assert coded.inter_token_p999_ms < uncoded.inter_token_p999_ms
    assert coded.tokens_per_s > 0


def test_deploy_lm_rejects_bad_engine_and_spec():
    params, fns = _linear_substrate()
    spec = _spec(params, fns)
    with pytest.raises(ValueError):
        deploy_lm(spec, engine="carrier-pigeon")
    with pytest.raises(TypeError):
        deploy_lm({"not": "a spec"})
    with pytest.raises(ValueError):
        GenerationSpec(params=params, k=0, **fns)
    with pytest.raises(RuntimeError):
        LMSimSession(spec).stats()
