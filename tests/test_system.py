"""End-to-end system test: the full ParM pipeline from the paper —
train a deployed model, learn a parity model, serve through the coded
frontend with an injected straggler, and verify (a) reconstructions rescue
the straggler's predictions with above-default accuracy and (b) overall
accuracy follows Eq. (1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codes import vandermonde
from repro.core.metrics import (degraded_accuracy, overall_accuracy,
                                topk_accuracy)
from repro.core.parity import train_parity_models
from repro.data.pipeline import batched, cluster_images
from repro.models.cnn import build
from repro.serving.runtime import ParMFrontend
from repro.training.loss import softmax_xent
from repro.training.optim import AdamConfig, adam_init, adam_update


@pytest.fixture(scope="module")
def trained_system():
    x, y, tmpl = cluster_images(1500, noise=1.5, seed=0,
                                image_shape=(8, 8, 1))
    xt, yt, _ = cluster_images(400, noise=1.5, seed=1, templates=tmpl,
                               image_shape=(8, 8, 1))
    params, fwd = build("mlp", jax.random.PRNGKey(0),
                        image_shape=(8, 8, 1))
    opt = AdamConfig(lr=1e-3)
    st = adam_init(params, opt)

    @jax.jit
    def step(p, s, xb, yb):
        l, g = jax.value_and_grad(
            lambda p: softmax_xent(fwd(p, xb), yb))(p)
        p, s = adam_update(g, s, p, opt)
        return p, s, l

    for xb, yb in batched(x, y, 64, epochs=3):
        params, st, _ = step(params, st, xb, yb)
    pp, scheme = train_parity_models(
        params, fwd, lambda k: build("mlp", k, image_shape=(8, 8, 1))[0],
        x, k=2, scheme="sum", epochs=4, seed=0)
    return params, fwd, pp, scheme, (x, y, xt, yt)


def test_degraded_accuracy_beats_default(trained_system):
    params, fwd, pp, scheme, (x, y, xt, yt) = trained_system
    k = 2
    a_a = topk_accuracy(np.asarray(fwd(params, jnp.asarray(xt))), yt)
    rng = np.random.default_rng(2)
    n = (len(xt) // k) * k
    order = rng.permutation(len(xt))[:n]
    groups = xt[order].reshape(-1, k, *xt.shape[1:])
    glabels = yt[order].reshape(-1, k)
    member = np.asarray(fwd(params, jnp.asarray(
        groups.reshape(n, *xt.shape[1:])))).reshape(-1, k, 10)
    C = vandermonde(k, 1)
    parity_q = np.einsum("k,gk...->g...", C[0], groups)
    parity_out = np.asarray(fwd(pp[0], jnp.asarray(parity_q)))[:, None]
    a_d = degraded_accuracy(parity_out, member, glabels, scheme)
    assert a_a > 0.8, a_a
    assert a_d > 0.5, a_d                     # >> default 0.1
    # paper Eq (1): overall accuracy at f_u=0.1
    a_o = overall_accuracy(a_a, a_d, 0.1)
    assert a_o > overall_accuracy(a_a, 0.1, 0.1)


def test_served_parm_pipeline(trained_system):
    """Straggler-injected threaded serving: reconstructed predictions are the
    decoder outputs and most are correct."""
    params, fwd, pp, scheme, (x, y, xt, yt) = trained_system
    jfwd = jax.jit(fwd)
    slow = {1}

    def delay(iid):
        return 0.4 if iid in slow else 0.0

    fe = ParMFrontend(jfwd, params, parity_params=pp[0], k=2, m=2,
                      strategy="parm", scheme=scheme, delay_fn=delay)
    try:
        n = 12
        qs = [fe.submit(i, xt[i:i + 1]) for i in range(n)]
        assert fe.wait_all(timeout=60)
        stats = fe.stats()
        assert stats["n"] == n
        assert stats["completed_by"].get("parity", 0) >= 1
        correct = sum(int(np.argmax(q.result) == yt[q.qid]) for q in qs)
        assert correct / n > 0.5
    finally:
        fe.shutdown()


@pytest.mark.slow
def test_lm_parity_training_loss_decreases():
    """The paper's technique on the LM substrate (embedding-space encoder):
    parity-distillation loss must drop during training."""
    from repro.configs.base import get_config
    from repro.models import transformer as T
    from repro.training.train_lib import make_parity_train_step

    cfg = get_config("smollm-135m", reduced=True)
    key = jax.random.PRNGKey(0)
    deployed = T.init_params(cfg, key)
    parity = T.init_params(cfg, jax.random.PRNGKey(1))
    opt = AdamConfig(lr=1e-3)
    step = jax.jit(make_parity_train_step(cfg, opt))
    opt_state = adam_init(parity, opt)

    k, B, S = 2, 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(10), (k, B, S),
                              0, cfg.vocab)
    embeds = jnp.stack([T.embed_tokens(cfg, deployed, t) for t in toks])
    teacher = jnp.stack(
        [T.forward(cfg, deployed, tokens=t)[0] for t in toks])
    batch = {"embeds": embeds, "teacher": teacher}
    losses = []
    for i in range(25):
        parity, opt_state, m = step(parity, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])
