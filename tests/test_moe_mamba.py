"""Unit tests for the MoE dispatch and Mamba2/SSD layers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import mamba as M
from repro.models import moe as MOE

# ~20s of SSD/MoE reference sweeps: full-suite lane only
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _moe_cfg(**kw):
    base = get_config("deepseek-moe-16b", reduced=True)
    return base.replace(**kw)


def test_moe_matches_dense_reference():
    """With no capacity drops, scatter-dispatch MoE == explicit per-token
    top-k einsum."""
    cfg = _moe_cfg(capacity_factor=8.0)
    p = MOE.init_moe(cfg, KEY)
    x = 0.1 * jax.random.normal(KEY, (2, 8, cfg.d_model))
    got, aux = MOE.moe_fwd(cfg, p, x)

    xt = x.reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(xt @ p["router"], -1)
    gv, gi = jax.lax.top_k(probs, cfg.moe_top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    want = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((cfg.d_model,))
        for j in range(cfg.moe_top_k):
            e = int(gi[t, j])
            h = jax.nn.silu(xt[t] @ p["w1"][e]) * (xt[t] @ p["w3"][e])
            acc = acc + gv[t, j] * (h @ p["w2"][e])
        want = want.at[t].set(acc)
    sp = p["shared"]
    want = want + jax.nn.silu(xt @ sp["w1"]) * (xt @ sp["w3"]) @ sp["w2"]
    np.testing.assert_allclose(np.asarray(got.reshape(-1, cfg.d_model)),
                               np.asarray(want), atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity 8 slots/expert and many tokens, overflow tokens get only
    the shared-expert (or zero) contribution — no NaNs, bounded norms."""
    cfg = _moe_cfg(n_shared_experts=0)
    p = MOE.init_moe(cfg, KEY)
    x = 0.1 * jax.random.normal(KEY, (4, 64, cfg.d_model))
    out, aux = MOE.moe_fwd(cfg, p, x, capacity=8)
    assert bool(jnp.isfinite(out).all())


def test_moe_aux_loss_uniform_routing():
    """Perfectly uniform routing gives aux ~= 1 (E * sum(1/E * 1/E) * E)."""
    cfg = _moe_cfg()
    p = MOE.init_moe(cfg, KEY)
    p = dict(p, router=jnp.zeros_like(p["router"]))   # uniform probs
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    _, aux = MOE.moe_fwd(cfg, p, x)
    assert 0.9 < float(aux) < 1.3


def test_ssd_matches_stepwise_recurrence():
    """Chunked SSD (training path) == token-by-token decode recurrence."""
    cfg = get_config("mamba2-780m", reduced=True).replace(ssm_chunk=4)
    p = M.init_mamba(cfg, KEY)
    B, L = 2, 12
    x = 0.1 * jax.random.normal(KEY, (B, L, cfg.d_model))
    y_full, _ = M.ssd_fwd(cfg, p, x)

    cache = M.init_ssm_cache(cfg, B)
    ys = []
    for t in range(L):
        y, cache = M.ssd_decode(cfg, p, x[:, t:t + 1], cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=2e-4)


def test_ssd_prefill_state_handoff():
    """ssd_fwd(return_state) then ssd_decode continues exactly."""
    cfg = get_config("mamba2-780m", reduced=True).replace(ssm_chunk=4)
    p = M.init_mamba(cfg, KEY)
    B, L = 1, 8
    x = 0.1 * jax.random.normal(KEY, (B, L + 1, cfg.d_model))
    y_full, _ = M.ssd_fwd(cfg, p, x)
    _, state = M.ssd_fwd(cfg, p, x[:, :L], return_state=True)
    y_next, _ = M.ssd_decode(cfg, p, x[:, L:L + 1], state)
    np.testing.assert_allclose(np.asarray(y_next), np.asarray(y_full[:, L:]),
                               atol=2e-4)


@pytest.mark.parametrize("L,seed", [
    (1, 0), (3, 7), (4, 13), (7, 21), (11, 29), (15, 37), (16, 50),
])
def test_ssd_chunk_padding_invariance(L, seed):
    """Output is independent of chunk-size / padding choices."""
    cfg = get_config("mamba2-780m", reduced=True)
    p = M.init_mamba(cfg, jax.random.PRNGKey(seed))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                (1, L, cfg.d_model))
    y1, _ = M.ssd_fwd(cfg.replace(ssm_chunk=4), p, x)
    y2, _ = M.ssd_fwd(cfg.replace(ssm_chunk=16), p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
