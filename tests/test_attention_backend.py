"""Backend routing for attention: Pallas kernels vs the XLA paths.

Satellite of the coded-LM-serving PR: `models/layers.py` routes
prefill/decode attention through the Pallas kernels when
``cfg.attn_backend == "pallas"`` (interpret mode off-TPU), with the XLA
online-softmax paths as default and fallback.  These tests pin

* numerical equivalence of the two backends on the layer entry points,
* the q_offset fallback (kernel lacks the feature -> XLA path, bit-equal),
* the per-row ``pos`` vector decode path (slot-batched continuous
  decoding) against a per-row scalar loop, on both backends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import layers as L


def _cfg(**kw):
    cfg = get_config("qwen2-0.5b", reduced=True)
    return dataclasses.replace(cfg, **kw) if kw else cfg


def _attn_inputs(cfg, B, S, seed=0):
    key = jax.random.PRNGKey(seed)
    kp, kx = jax.random.split(key)
    p = L.init_attention(cfg, kp)
    x = jax.random.normal(kx, (B, S, cfg.d_model), cfg.dtype)
    rope = L.rope_tables(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)
    return p, x, rope


def test_prefill_backend_equivalence():
    cfg = _cfg()
    p, x, rope = _attn_inputs(cfg, B=2, S=24)
    o_jnp, (k_j, v_j) = L.self_attention_fwd(cfg, p, x, rope)
    o_pl, (k_p, v_p) = L.self_attention_fwd(cfg, p, x, rope,
                                            backend="pallas")
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_pl),
                               atol=2e-5, rtol=2e-5)
    # k/v are computed before the backend split — identical
    np.testing.assert_array_equal(np.asarray(k_j), np.asarray(k_p))
    np.testing.assert_array_equal(np.asarray(v_j), np.asarray(v_p))


def test_prefill_backend_from_config():
    base = _cfg()
    p, x, rope = _attn_inputs(base, B=1, S=16)
    cfg_pl = dataclasses.replace(base, attn_backend="pallas")
    o_kw, _ = L.self_attention_fwd(base, p, x, rope, backend="pallas")
    o_cfg, _ = L.self_attention_fwd(cfg_pl, p, x, rope)
    np.testing.assert_array_equal(np.asarray(o_kw), np.asarray(o_cfg))


def test_prefill_q_offset_falls_back_to_xla():
    cfg = _cfg()
    p, x, rope = _attn_inputs(cfg, B=1, S=8)
    rope_off = L.rope_tables(4 + jnp.arange(8), cfg.resolved_head_dim,
                             cfg.rope_theta)
    o_pl, _ = L.self_attention_fwd(cfg, p, x, rope_off, q_offset=4,
                                   backend="pallas")
    o_jnp, _ = L.self_attention_fwd(cfg, p, x, rope_off, q_offset=4)
    # the kernel has no q_offset — "pallas" must take the XLA path, bit-equal
    np.testing.assert_array_equal(np.asarray(o_pl), np.asarray(o_jnp))


def _decode_inputs(cfg, B, S, seed=1):
    key = jax.random.PRNGKey(seed)
    kp, kx, kc = jax.random.split(key, 3)
    p = L.init_attention(cfg, kp)
    x = jax.random.normal(kx, (B, 1, cfg.d_model), cfg.dtype)
    KV, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    kk, kv_ = jax.random.split(kc)
    cache = {"k": jax.random.normal(kk, (B, S, KV, hd), cfg.dtype),
             "v": jax.random.normal(kv_, (B, S, KV, hd), cfg.dtype)}
    return p, x, cache


def test_decode_backend_equivalence_scalar_pos():
    cfg = _cfg()
    B, S, pos = 2, 16, 7
    p, x, cache = _decode_inputs(cfg, B, S)
    rope = L.rope_tables(jnp.full((1,), pos), cfg.resolved_head_dim,
                         cfg.rope_theta)
    o_jnp, c_jnp = L.self_attention_decode(cfg, p, x, cache, pos, rope)
    o_pl, c_pl = L.self_attention_decode(cfg, p, x, cache, pos, rope,
                                         backend="pallas")
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_pl),
                               atol=2e-5, rtol=2e-5)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(c_jnp[key]),
                                      np.asarray(c_pl[key]))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_decode_vector_pos_matches_per_row(backend):
    """Slot-batched decode (pos [B]) == independent per-row scalar decodes."""
    cfg = _cfg()
    B, S = 3, 16
    p, x, cache = _decode_inputs(cfg, B, S)
    pos = jnp.array([2, 9, 5], jnp.int32)
    rope_vec = L.rope_tables(pos, cfg.resolved_head_dim, cfg.rope_theta)
    o_vec, c_vec = L.self_attention_decode(cfg, p, x, cache, pos, rope_vec,
                                           backend=backend)
    for b in range(B):
        xb = x[b:b + 1]
        cb = {k: v[b:b + 1] for k, v in cache.items()}
        rope_b = L.rope_tables(pos[b:b + 1], cfg.resolved_head_dim,
                               cfg.rope_theta)
        o_b, c_b = L.self_attention_decode(cfg, p, xb, cb, int(pos[b]),
                                           rope_b, backend=backend)
        np.testing.assert_allclose(np.asarray(o_vec[b]), np.asarray(o_b[0]),
                                   atol=2e-5, rtol=2e-5)
        for key in ("k", "v"):
            np.testing.assert_allclose(np.asarray(c_vec[key][b]),
                                       np.asarray(c_b[key][0]),
                                       atol=2e-6, rtol=2e-6)


def test_decode_vector_pos_backend_equivalence():
    cfg = _cfg()
    B, S = 2, 12
    p, x, cache = _decode_inputs(cfg, B, S, seed=3)
    pos = jnp.array([4, 11], jnp.int32)
    rope = L.rope_tables(pos, cfg.resolved_head_dim, cfg.rope_theta)
    o_jnp, _ = L.self_attention_decode(cfg, p, x, cache, pos, rope)
    o_pl, _ = L.self_attention_decode(cfg, p, x, cache, pos, rope,
                                      backend="pallas")
    np.testing.assert_allclose(np.asarray(o_jnp), np.asarray(o_pl),
                               atol=2e-5, rtol=2e-5)


def test_attention_decode_xla_vector_pos_matches_scalar():
    """The XLA decode mask with pos [B] equals per-row scalar masking."""
    key = jax.random.PRNGKey(7)
    kq, kk, kv = jax.random.split(key, 3)
    B, S, H, KV, hd = 3, 10, 4, 2, 8
    q = jax.random.normal(kq, (B, 1, H, hd), jnp.float32)
    kc = jax.random.normal(kk, (B, S, KV, hd), jnp.float32)
    vc = jax.random.normal(kv, (B, S, KV, hd), jnp.float32)
    pos = jnp.array([0, 5, 9], jnp.int32)
    o_vec = L.attention_decode_xla(q, kc, vc, pos)
    for b in range(B):
        o_b = L.attention_decode_xla(q[b:b + 1], kc[b:b + 1], vc[b:b + 1],
                                     int(pos[b]))
        np.testing.assert_array_equal(np.asarray(o_vec[b]),
                                      np.asarray(o_b[0]))
