"""Per-kernel shape/dtype sweeps against the pure-jnp oracles in
repro.kernels.ref (interpret mode on CPU; identical math on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dt):
    return 2e-2 if dt == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("k,B,F,dt", [
    (2, 4, 512, jnp.float32),
    (3, 1, 128, jnp.float32),
    (4, 8, 1000, jnp.bfloat16),
    (6, 2, 257, jnp.float32),
])
def test_parity_encode(k, B, F, dt):
    key = jax.random.PRNGKey(k * 31 + B)
    q = jax.random.normal(key, (k, B, F), dt)
    c = jnp.arange(1.0, k + 1.0)
    got = ops.parity_encode_op(q, c)
    want = ref.parity_encode_ref(q, c)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dt), rtol=_tol(dt))


@pytest.mark.parametrize("H,r,B,F,dt", [
    (8, 1, 4, 512, jnp.float32),
    (16, 2, 1, 128, jnp.float32),
    (16, 3, 2, 257, jnp.float32),
    (32, 2, 8, 1000, jnp.bfloat16),
])
def test_learned_project(H, r, B, F, dt):
    """Learned-encoder final projection kernel vs the einsum oracle,
    including non-128-aligned feature dims and the r>1 grid axis."""
    key = jax.random.PRNGKey(H * 13 + r)
    h = jax.random.normal(key, (H, B, F), dt)
    w = jax.random.normal(jax.random.PRNGKey(3), (H, r), jnp.float32)
    got = ops.learned_project_op(h, w)
    want = jnp.einsum("hr,hbf->rbf", w, h.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dt) * 4, rtol=_tol(dt) * 4)
    # higher-rank trailing feature shapes ride the same reshape path
    h4 = jax.random.normal(key, (H, B, 4, 6), jnp.float32)
    got4 = ops.learned_project_op(h4, w)
    want4 = jnp.einsum("hr,hbxy->rbxy", w, h4)
    np.testing.assert_allclose(np.asarray(got4), np.asarray(want4),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("k,B,V,dt", [
    (2, 4, 100, jnp.float32),
    (4, 2, 1000, jnp.float32),
    (3, 8, 513, jnp.bfloat16),
])
def test_parity_decode(k, B, V, dt):
    key = jax.random.PRNGKey(7)
    outs = jax.random.normal(key, (k, B, V), dt)
    par = jax.random.normal(jax.random.PRNGKey(8), (B, V), dt)
    c = jnp.arange(1.0, k + 1.0)
    for j in range(k):
        got = ops.parity_decode_op(par, outs, j, coeffs=c)
        avail = jnp.asarray(np.array(c) * (np.arange(k) != j))
        want = ref.parity_decode_ref(par, outs, avail, 1.0 / float(c[j]))
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   atol=_tol(dt) * k, rtol=2e-2)


@pytest.mark.parametrize("B,Sq,H,KV,hd,causal,window,dt", [
    (2, 128, 4, 2, 64, True, 0, jnp.float32),
    (1, 256, 4, 4, 64, True, 64, jnp.float32),
    (2, 100, 2, 1, 32, False, 0, jnp.float32),
    (1, 128, 8, 2, 128, True, 0, jnp.bfloat16),
])
def test_flash_attention(B, Sq, H, KV, hd, causal, window, dt):
    ks = jax.random.split(jax.random.PRNGKey(B * 7 + Sq), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dt)
    k = jax.random.normal(ks[1], (B, Sq, KV, hd), dt)
    v = jax.random.normal(ks[2], (B, Sq, KV, hd), dt)
    got = ops.flash_attention_op(q, k, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2 if dt == jnp.bfloat16 else 2e-5)


@pytest.mark.parametrize("B,S,H,KV,hd,pos,dt", [
    (2, 512, 4, 2, 64, 100, jnp.float32),
    (1, 1024, 8, 1, 32, 1023, jnp.float32),
    (3, 256, 2, 2, 64, 0, jnp.float32),
    (2, 384, 4, 4, 128, 200, jnp.bfloat16),
])
def test_decode_attention(B, S, H, KV, hd, pos, dt):
    ks = jax.random.split(jax.random.PRNGKey(S + pos), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dt)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), dt)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), dt)
    got = ops.decode_attention_op(q, kc, vc, pos)
    want = ref.decode_attention_ref(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2 if dt == jnp.bfloat16 else 2e-5)


def test_flash_attention_matches_model_layer():
    """The XLA fallback in repro.models.layers and the Pallas kernel agree."""
    from repro.models.layers import flash_attention_xla
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, KV, hd = 2, 96, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    a = ops.flash_attention_op(q, k, v, causal=True)
    b = flash_attention_xla(q, k, v, causal=True, block=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
