"""Tests for the CodingScheme / ResilienceStrategy plugin API (DESIGN.md):
registry round-trips, jnp-vs-pallas backend equivalence, r=2 decode under a
straggling *parity* instance, and the replication scheme running end-to-end
through both serving layers without touching either.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheme import (CodingScheme, LinearScheme, available_schemes,
                               get_scheme, register_scheme)
from repro.serving.runtime import ParMFrontend
from repro.serving.simulator import SimConfig, simulate
from repro.serving.strategy import (ResilienceStrategy, available_strategies,
                                    get_strategy, register_strategy)


# ------------------------------------------------------------- registry ----
def test_scheme_registry_round_trips():
    """Every registered name resolves, satisfies the protocol, and encodes
    with the shape contract [k, ...] -> [r, ...].  A ``fixes_k`` scheme
    (approx_backup) owns its group size: the caller's k is the redundancy
    budget and is NOT imposed on the scheme."""
    from repro.core.scheme import scheme_capabilities
    assert {"sum", "concat", "replication", "approx_backup",
            "learned", "fisher", "invnet"} <= set(available_schemes())
    for name in available_schemes():
        s = get_scheme(name, k=4)
        assert isinstance(s, CodingScheme), name
        assert s.name == name
        if scheme_capabilities(s).fixes_k:
            assert s.k == 1, name            # approx_backup: k=1 groups
        else:
            assert s.k == 4, name
        assert np.asarray(s.coeffs).shape == (s.r, s.k)
        q = jnp.ones((s.k, 2, 16, 16, 1)) if name == "concat" else \
            jnp.arange(s.k * 2 * 8, dtype=jnp.float32).reshape(s.k, 2, 8)
        p = s.encode(q)
        assert p.shape[0] == s.r and p.shape[1:] == q.shape[1:], name


def test_get_scheme_passthrough_and_errors():
    s = get_scheme("sum", k=3, r=2)
    assert get_scheme(s) is s                    # instances pass through
    assert get_scheme(s, k=3, r=2) is s          # matching ask is fine
    with pytest.raises(KeyError, match="unknown coding scheme"):
        get_scheme("nope", k=2)
    with pytest.raises(ValueError, match="requires k"):
        get_scheme("sum")
    with pytest.raises(ValueError, match="backend"):
        get_scheme("sum", k=2, backend="tpu-magic")
    # the unknown-name error lists every registered name — the operator
    # reads valid options straight off the traceback
    with pytest.raises(KeyError) as ei:
        get_scheme("nope", k=2)
    for name in available_schemes():
        assert name in str(ei.value)


def test_register_duplicate_scheme_requires_override():
    """Registering a DIFFERENT factory under a taken name must raise; the
    same factory (module re-import) and override=True pass."""
    from repro.core.scheme import _SCHEMES
    register_scheme("sum", _SCHEMES["sum"])      # idempotent: same factory
    with pytest.raises(ValueError, match="already registered"):
        register_scheme("sum", lambda **kw: None)
    assert get_scheme("sum", k=2).name == "sum"  # registry untouched
    try:
        register_scheme("dup-test", lambda **kw: LinearScheme(k=kw["k"]))
        replacement = lambda **kw: LinearScheme(k=kw["k"], name="dup-test")
        with pytest.raises(ValueError, match="override=True"):
            register_scheme("dup-test", replacement)
        register_scheme("dup-test", replacement, override=True)
        assert get_scheme("dup-test", k=2).name == "dup-test"
    finally:
        _SCHEMES.pop("dup-test", None)


def test_register_duplicate_strategy_requires_override():
    from repro.serving.strategy import _STRATEGIES
    register_strategy(get_strategy("parm"))      # idempotent: equal instance
    with pytest.raises(ValueError, match="already registered"):
        register_strategy(ResilienceStrategy("parm", mirror=3))
    assert get_strategy("parm").mirror == 1      # registry untouched
    try:
        register_strategy(ResilienceStrategy("dup-strat"))
        with pytest.raises(ValueError, match="override=True"):
            register_strategy(ResilienceStrategy("dup-strat", mirror=2))
        register_strategy(ResilienceStrategy("dup-strat", mirror=2),
                          override=True)
        assert get_strategy("dup-strat").mirror == 2
    finally:
        _STRATEGIES.pop("dup-strat", None)


def test_get_scheme_validates_instances_against_explicit_ask():
    """Passing an instance along with explicit k/r/backend must not silently
    ignore a mismatch — the caller would train or serve the wrong code."""
    s = get_scheme("sum", k=2, r=1)
    with pytest.raises(ValueError, match="k=2"):
        get_scheme(s, k=4)
    with pytest.raises(ValueError, match="r=1"):
        get_scheme(s, k=2, r=2)
    with pytest.raises(ValueError, match="backend"):
        get_scheme(s, k=2, backend="pallas")
    # and through the frontend / trainer entry points
    with pytest.raises(ValueError, match="r=1"):
        ParMFrontend(lambda p, x: x @ p, jnp.ones((4, 3)), k=2, r=2,
                     scheme=s)


def test_custom_encode_override_is_used_for_training_data():
    """A scheme overriding encode() (the DESIGN.md learned-encoder extension
    point) must have its real encode feed the parity training set — no
    silent coeffs-product shortcut."""
    from repro.core.parity import group_queries, make_parity_dataset

    class ShiftedSum(LinearScheme):
        def encode(self, queries):
            return super().encode(queries) + 1.0

    s = ShiftedSum(k=2, r=1, name="shifted")
    rng = np.random.default_rng(0)
    x = rng.normal(size=(8, 3)).astype(np.float32)
    fx = rng.normal(size=(8, 4)).astype(np.float32)
    pq, _ = make_parity_dataset(x, fx, 2, s, 0, np.random.default_rng(1))
    groups, _ = group_queries(x, 2, np.random.default_rng(1))
    want = np.asarray(s.encode(np.moveaxis(groups, 1, 0)))[0]
    np.testing.assert_allclose(pq, want, atol=1e-6)   # includes the +1 shift


def test_strategy_registry_round_trips():
    assert {"parm", "equal_resources", "replication", "default_slo",
            "approx_backup", "none"} <= set(available_strategies())
    for name in available_strategies():
        st = get_strategy(name)
        assert st.name == name
        lay = st.layout(m=12, k=3)
        assert lay.main >= 12
    assert get_strategy("parm").layout(12, 2).parity == 6
    assert get_strategy("equal_resources").layout(12, 2).main == 18
    obj = get_strategy("parm")
    assert get_strategy(obj) is obj
    with pytest.raises(KeyError, match="unknown resilience strategy"):
        get_strategy("nope")


# ------------------------------------------- pallas / jnp backend parity ----
@pytest.mark.parametrize("k,r,B,F", [(2, 1, 1, 128), (3, 2, 2, 130),
                                     (4, 1, 8, 1000)])
def test_backend_equivalence_encode(k, r, B, F):
    rng = np.random.default_rng(k * 10 + r)
    q = jnp.asarray(rng.normal(size=(k, B, F)).astype(np.float32))
    jnp_s = get_scheme("sum", k=k, r=r, backend="jnp")
    pal_s = get_scheme("sum", k=k, r=r, backend="pallas")
    np.testing.assert_allclose(np.asarray(jnp_s.encode(q)),
                               np.asarray(pal_s.encode(q)),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("k,B,V", [(2, 1, 100), (3, 2, 513), (4, 4, 1000)])
def test_backend_equivalence_decode_one(k, B, V):
    rng = np.random.default_rng(k)
    outs = jnp.asarray(rng.normal(size=(k, B, V)).astype(np.float32))
    par = jnp.asarray(rng.normal(size=(B, V)).astype(np.float32))
    jnp_s = get_scheme("sum", k=k, r=1, backend="jnp")
    pal_s = get_scheme("sum", k=k, r=1, backend="pallas")
    for j in range(k):
        np.testing.assert_allclose(np.asarray(jnp_s.decode_one(par, outs, j)),
                                   np.asarray(pal_s.decode_one(par, outs, j)),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("k,r", [(2, 2), (3, 2), (4, 3)])
def test_backend_equivalence_masked_decode_partial_parity(k, r):
    """The general least-squares decode with a straggling parity model
    (partial ``parity_avail``) must be bitwise-close across backends — the
    pallas backend routes encode/decode_one through kernels but decode
    through the same jnp solve, and must not drift."""
    rng = np.random.default_rng(k * 7 + r)
    jnp_s = get_scheme("sum", k=k, r=r, backend="jnp")
    pal_s = get_scheme("sum", k=k, r=r, backend="pallas")
    outs_true = rng.normal(size=(k, 2, 7)).astype(np.float32)
    parity = np.einsum("rk,k...->r...", np.asarray(jnp_s.coeffs), outs_true)
    miss = np.zeros(k, bool)
    miss[0] = True
    pa = np.ones(r, bool)
    pa[-1] = False                       # last parity model straggles
    corrupted = np.where(miss[:, None, None], 99.0, outs_true)
    a = np.asarray(jnp_s.decode(jnp.asarray(parity), jnp.asarray(corrupted),
                                jnp.asarray(miss), jnp.asarray(pa)))
    b = np.asarray(pal_s.decode(jnp.asarray(parity), jnp.asarray(corrupted),
                                jnp.asarray(miss), jnp.asarray(pa)))
    np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
    np.testing.assert_allclose(b, outs_true, atol=1e-3)


@pytest.mark.parametrize("shape", [(3, 100), (3, 2, 257), (2, 2, 4, 4, 10)])
def test_backend_equivalence_decode_one_shapes(shape):
    """decode_one across the pallas reshape paths: unbatched [k, F], batched
    [k, B, F], and higher-rank [k, B, H, W, C] outputs."""
    k = shape[0]
    rng = np.random.default_rng(sum(shape))
    outs = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    par = jnp.asarray(rng.normal(size=shape[1:]).astype(np.float32))
    jnp_s = get_scheme("sum", k=k, r=1, backend="jnp")
    pal_s = get_scheme("sum", k=k, r=1, backend="pallas")
    for j in range(k):
        np.testing.assert_allclose(
            np.asarray(jnp_s.decode_one(par, outs, j)),
            np.asarray(pal_s.decode_one(par, outs, j)),
            atol=1e-4, rtol=1e-4)


def test_concat_grid_divisibility_edge_cases():
    """§4.2.3 grid code: g = ceil(sqrt(k)); H and W must divide by g —
    non-square k values and indivisible shapes are the edge cases."""
    # k=3 -> 2x2 grid: 16x16 divides, 15x15 must fail fast
    s3 = get_scheme("concat", k=3)
    p = s3.encode(jnp.ones((3, 2, 16, 16, 1)))
    assert p.shape == (1, 2, 16, 16, 1)
    with pytest.raises(ValueError, match="divisible"):
        s3.encode(jnp.ones((3, 2, 15, 15, 1)))
    # k=5 -> 3x3 grid: 15x15 divides by 3, 16x16 does not
    s5 = get_scheme("concat", k=5)
    assert s5.encode(jnp.ones((5, 1, 15, 15, 2))).shape == (1, 1, 15, 15, 2)
    with pytest.raises(ValueError, match="divisible"):
        s5.encode(jnp.ones((5, 1, 16, 16, 2)))
    # r > 1 is rejected at construction, not mid-serve
    with pytest.raises(ValueError, match="r=1"):
        get_scheme("concat", k=2, r=2)


def test_concat_pallas_backend_decode_matches_jnp():
    """ConcatScheme's *output* code is still addition, so its decode_one on
    the pallas backend rides the subtraction kernel; results must match the
    jnp backend bitwise-close."""
    k = 4
    rng = np.random.default_rng(0)
    outs = jnp.asarray(rng.normal(size=(k, 2, 10)).astype(np.float32))
    par = jnp.asarray(outs.sum(0))        # ideal parity output for coeffs 1
    jnp_s = get_scheme("concat", k=k, backend="jnp")
    pal_s = get_scheme("concat", k=k, backend="pallas")
    for j in range(k):
        a = np.asarray(jnp_s.decode_one(par, outs, j))
        b = np.asarray(pal_s.decode_one(par, outs, j))
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(a, np.asarray(outs[j]), atol=1e-4)
    # encode is the (jnp) grid downsample on both backends
    q = jnp.asarray(rng.normal(size=(k, 1, 8, 8, 1)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(jnp_s.encode(q)),
                               np.asarray(pal_s.encode(q)), atol=1e-6)


def test_replication_scheme_accepts_r_none_and_validates():
    """The r=0 placeholder wart is gone: construction takes r=None (or the
    true r=k); anything else is rejected."""
    from repro.core.scheme import ReplicationScheme
    assert ReplicationScheme(k=3).r == 3
    assert ReplicationScheme(k=3, r=3).r == 3
    with pytest.raises(ValueError, match="r == k"):
        ReplicationScheme(k=3, r=2)
    with pytest.raises(ValueError, match="r == k"):
        ReplicationScheme(k=3, r=0)      # the old placeholder is invalid now
    # registry round-trip still ignores the generic caller's r
    assert get_scheme("replication", k=4, r=1).r == 4


# --------------------------------------------- r=2, straggling parity ------
def test_r2_decode_with_straggling_parity_instance():
    """§3.5 with a parity straggler: decode is exact whenever #available
    parities >= #missing, exercised through the scheme's parity_avail path."""
    k, r = 3, 2
    rng = np.random.default_rng(1)
    scheme = get_scheme("sum", k=k, r=r)
    outs_true = rng.normal(size=(k, 4)).astype(np.float32)
    parity_outs = (np.asarray(scheme.coeffs) @ outs_true).astype(np.float32)
    miss = np.array([True, False, False])
    for lost_parity in range(r):
        pa = np.ones(r, bool)
        pa[lost_parity] = False                  # that parity never arrived
        got = np.asarray(scheme.decode(
            jnp.asarray(parity_outs),
            jnp.asarray(np.where(miss[:, None], 99.0, outs_true)),
            jnp.asarray(miss), jnp.asarray(pa)))
        np.testing.assert_allclose(got, outs_true, atol=1e-3)


def test_frontend_r2_straggling_parity_instance():
    """Threaded runtime: one of the two parity models straggles forever; the
    group must still decode one missing member from the surviving parity."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    def fwd(p, x):
        return x @ p

    # instance ids: main = 0..m-1; parity queue j workers = 1000 + 100*j + i.
    # Straggle main instance 0 AND the whole parity-0 queue; give the fast
    # main instance a small service time so it cannot drain the whole queue
    # before the straggler picks up its item.
    def delay(iid):
        return {0: 2.0, 1: 0.25, 1000: 2.0}.get(iid, 0.0)

    fe = ParMFrontend(fwd, W, parity_params=[W, W], k=2, r=2, m=2,
                      strategy="parm", delay_fn=delay)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(2)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        assert any(q.completed_by == "parity" for q in qs)
        for q, x in zip(qs, xs):
            np.testing.assert_allclose(q.result, np.asarray(fwd(W, x)),
                                       atol=1e-2)
    finally:
        fe.shutdown()


def test_train_parity_models_encoder_kind_removed():
    """The PR-1-era encoder_kind= alias is removed: TypeError pointing at
    scheme=."""
    from repro.core.parity import train_parity_models
    from repro.models.linear import init_linear, linear_fwd
    import jax
    x = np.random.default_rng(0).normal(size=(64, 6)).astype(np.float32)
    p = init_linear(jax.random.PRNGKey(0), 6, 3)
    with pytest.raises(TypeError, match="scheme="):
        train_parity_models(
            p, linear_fwd, lambda key: init_linear(key, 6, 3), x, k=2,
            encoder_kind="sum", epochs=1)


# -------------------------------------- replication scheme, end-to-end -----
def test_replication_scheme_through_threaded_runtime():
    """The replication *scheme* (registered in core/scheme.py only) runs
    through the coded serving path untouched: replicas are the parity
    queries, decode is a passthrough."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    def fwd(p, x):
        return x @ p

    fe = ParMFrontend(fwd, W, k=2, m=2, strategy="parm", scheme="replication",
                      delay_fn=lambda i: {0: 0.5, 1: 0.1}.get(i, 0.0))
    try:
        assert fe.r == 2                     # scheme fixed r = k
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(4)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        assert any(q.completed_by == "parity" for q in qs)
        for q, x in zip(qs, xs):
            np.testing.assert_allclose(q.result, np.asarray(fwd(W, x)),
                                       atol=1e-4)
    finally:
        fe.shutdown()


def test_approx_backup_scheme_through_threaded_runtime():
    """§5.2.6 as a scheme: the approx_backup strategy rides the CODED path —
    k=1 groups, a cheap backup model in the parity pool (different params
    AND a different architecture via parity_fwd), passthrough decode.  A
    straggling main instance is answered by the backup's degraded-quality
    output; fast queries keep the deployed model's exact output."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    W_cheap = np.asarray(W) + 0.05 * rng.normal(size=W.shape).astype(
        np.float32)

    def fwd(p, x):
        return x @ p

    def cheap_fwd(p, x):                     # "different architecture"
        return np.tanh(x) @ p

    fe = ParMFrontend(fwd, W, parity_params=[jnp.asarray(W_cheap)], k=2, m=2,
                      strategy="approx_backup", parity_fwd=cheap_fwd,
                      delay_fn=lambda i: 0.5 if i == 0 else 0.0)
    try:
        assert fe.scheme.name == "approx_backup"
        assert fe.group_k == 1 and fe.r == 1     # one cheap query per group
        assert fe.k == 2                         # budget k still sizes pools
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(2)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        st = fe.stats()
        assert st["scheme"] == "approx_backup"
        # the straggler (served by main instance 0) got the backup's
        # approximate answer, bit-exact w.r.t. the cheap model
        straggled = [q for q in qs if q.completed_by == "parity"]
        assert straggled
        for q in straggled:
            np.testing.assert_allclose(
                q.result, cheap_fwd(jnp.asarray(W_cheap), xs[q.qid]),
                atol=1e-5)
    finally:
        fe.shutdown()


def test_new_strategy_registered_elsewhere_runs_in_des_and_runtime():
    """Acceptance: registering a strategy in ONE place makes it runnable
    through both serving layers with no edits to either."""
    register_strategy(ResilienceStrategy("triplication", mirror=3))
    try:
        r = simulate(SimConfig(n_queries=1500, qps=120, m=9, k=2, seed=0),
                     "triplication")
        assert r["strategy"] == "triplication"

        W = jnp.ones((4, 3), jnp.float32)
        fe = ParMFrontend(lambda p, x: x @ p, W, k=2, m=3,
                          strategy="triplication",
                          delay_fn=lambda i: 0.3 if i < 2 else 0.0)
        try:
            qs = [fe.submit(i, np.ones((1, 4), np.float32))
                  for i in range(4)]
            assert fe.wait_all(timeout=15)
            assert all(q.completed_by == "model" for q in qs)
        finally:
            fe.shutdown()
    finally:
        from repro.serving import strategy as _strat
        _strat._STRATEGIES.pop("triplication", None)


def test_new_scheme_registered_elsewhere_runs_in_runtime():
    """Same for schemes: a doubled-sum code registered here (not in the
    serving layer) serves coded traffic immediately."""
    class DoubledSum(LinearScheme):
        @property
        def coeffs(self):
            return 2.0 * LinearScheme.coeffs.fget(self)

    register_scheme(
        "doubled-sum",
        lambda k, r=1, backend="jnp", **kw: DoubledSum(
            k=k, r=r, backend=backend, name="doubled-sum"))
    try:
        W = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 5)).astype(np.float32))

        def fwd(p, x):
            return x @ p

        # ideal parity model for coeffs [2, 2]: F_P(2x1 + 2x2) = 2F(x1)+2F(x2)
        fe = ParMFrontend(fwd, W, parity_params=W, k=2, m=2,
                          strategy="parm", scheme="doubled-sum",
                          delay_fn=lambda i: {0: 0.5, 1: 0.1}.get(i, 0.0))
        try:
            xs = [np.random.default_rng(i).normal(
                size=(1, 8)).astype(np.float32) for i in range(4)]
            qs = [fe.submit(i, x) for i, x in enumerate(xs)]
            assert fe.wait_all(timeout=30)
            assert any(q.completed_by == "parity" for q in qs)
            for q, x in zip(qs, xs):
                np.testing.assert_allclose(q.result, np.asarray(fwd(W, x)),
                                           atol=1e-3)
        finally:
            fe.shutdown()
    finally:
        from repro.core import scheme as _scheme
        _scheme._SCHEMES.pop("doubled-sum", None)
