"""Differential battery: the anti-drift contract of DESIGN.md §1.

Both serving layers — the threaded runtime and the DES — consume the same
``ResilienceStrategy`` / ``CodingScheme`` / ``Scenario`` objects, and since
the ``DeploymentSpec`` redesign they consume them through the SAME
declarative spec: every test here builds ONE ``DeploymentSpec`` and drives it
through ``deploy(spec, engine="threads")`` and ``deploy(spec, engine="sim")``
for every registered strategy (and, for coded strategies, every relevant
scheme including the r=2 Vandermonde code and replication), asserting the
two engines make the same recoverability decision, perform the same number
of reconstructions, AND cancel the same redundant work (tombstoned
originals / dropped parity queries).

The unavailability pattern is expressed once as a ``Scenario`` of
``DeterministicSlowdown`` hazards on (pool, server) coordinates; the DES
applies it as service-time windows and the runtime applies it through the
fault-injecting ``delay_fn`` adapter — so the battery also proves the
adapter maps instance ids onto the same coordinates the simulator uses.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.api import BatchingPolicy, DeploymentSpec, Trace, deploy
from repro.serving.scenarios import DeterministicSlowdown, Scenario
from repro.serving.simulator import SimConfig, simulate
from repro.serving.strategy import available_strategies, get_strategy

# wall-clock / sim-ms straggle budget: members straggle for MEMBER_MS, lost
# parity models for PARITY_MS (long enough that an unrecoverable group
# completes via its members first, so no late decode sneaks in).  Every
# other main server gets BASE_MS: with k queries submitted back-to-back and
# every worker busy for >= BASE_MS, each of the runtime's k main workers
# deterministically serves exactly one group member — the same one-member-
# per-server assignment the DES's free-list dispatch produces.  Every LIVE
# parity/backup pool gets PARITY_BASE_MS: a decode can then never land
# before an idle main worker has provably dequeued its query (the runtime's
# dequeue is near-instant but not instant — without this floor a ~ms-fast
# backup reconstruction occasionally tombstones a main-queue item the DES
# considers already in service), while still finishing far below BASE_MS
# so every in-time decode stays in time.
MEMBER_MS = 700.0
PARITY_MS = 1800.0
BASE_MS = 300.0
PARITY_BASE_MS = 100.0


def _pattern_scenario(k, slow_main, slow_parity_pools):
    hazards = []
    slow = tuple(("main", s) for s in slow_main)
    base = tuple(("main", s) for s in range(k) if s not in slow_main)
    lost = tuple((f"parity{j}", 0) for j in slow_parity_pools)
    # slow every live parity pool the battery can spawn (r <= 4 here);
    # hazards on pools that don't exist are never consulted
    live = tuple((f"parity{j}", 0) for j in range(4)
                 if j not in slow_parity_pools)
    if slow:
        hazards.append(DeterministicSlowdown(targets=slow, add_ms=MEMBER_MS))
    if base:
        hazards.append(DeterministicSlowdown(targets=base, add_ms=BASE_MS))
    if lost:
        hazards.append(DeterministicSlowdown(targets=lost, add_ms=PARITY_MS))
    if live:
        hazards.append(DeterministicSlowdown(targets=live,
                                             add_ms=PARITY_BASE_MS))
    return Scenario("diff-pattern", tuple(hazards))


def _linear_fwd(p, x):
    return x @ p


def _make_spec(scheme, k, r, scenario, *, m=None, strategy="parm"):
    """ONE DeploymentSpec consumed verbatim by BOTH engines.  The deployed
    model is linear, so W itself is an exact parity model for ANY linear
    combination — every Vandermonde row is served exactly.  For invnet the
    deployed model factors through the scheme's own coupling network
    (fwd = g(x) @ W), which makes the deployed model an exact parity model
    on the g^-1-space parity queries."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    fwd = _linear_fwd
    if scheme == "invnet":
        from repro.core.scheme import get_scheme
        inst = get_scheme("invnet", k=k, r=r)

        def fwd(p, x, _g=inst.g_forward):
            return _g(x) @ p
        scheme = inst
    parity_params = None if scheme == "replication" else \
        [W] * (r if r else 1)
    spec = DeploymentSpec(fwd=fwd, params=W,
                          parity_params=parity_params, strategy=strategy,
                          scheme=scheme, k=k, r=r,
                          m=k if m is None else m, scenario=scenario)
    return spec, W


def _run_runtime(spec, W, n, gap_s=0.0):
    """``n`` queries through the threads engine; checks every answer is the
    exact linear prediction, then returns the post-shutdown report (shutdown
    also settles the redundant-work accounting for abandoned backlog).
    ``gap_s`` spaces submissions so an idle worker provably dequeues each
    query before the next exists (mirrors the DES, where a free server takes
    an arrival immediately)."""
    import time as _time
    rng = np.random.default_rng(0)
    sess = deploy(spec, engine="threads")
    try:
        fe = sess.frontend
        if fe.strategy.coded:
            # warm the encode JIT before timing matters: the DES charges a
            # fixed sub-ms encode cost, so a first-call compile pause here
            # would skew the wall-clock pattern the battery relies on
            fe.encode_fn(np.zeros((fe.group_k, 1, 8), np.float32))
        xs = [rng.normal(size=(1, 8)).astype(np.float32) for _ in range(n)]
        futs = []
        for x in xs:
            futs.append(sess.submit(x))
            if gap_s:
                _time.sleep(gap_s)
        assert sess.wait_all(timeout=30)
        for f, x in zip(futs, xs):
            np.testing.assert_allclose(f.result(timeout=1.0),
                                       np.asarray(spec.fwd(W, x)),
                                       atol=1e-2)
    finally:
        sess.shutdown()
    return sess.stats()


def _run_sim(spec, n):
    """The same spec through the sim engine: m = k main servers means each
    member lands on its own server, exactly like the runtime above."""
    return deploy(spec, engine="sim").replay(
        Trace(n_queries=n, qps=1000.0, seed=0, n_shuffles=0))


# (scheme, k, r, slow main servers, slow parity pools,
#  expected reconstructions, in_time) — ``in_time`` is the recoverability
# *decision*: whether the pattern decodes before the stragglers return.
# When it doesn't, both layers still agree on the late behavior: as soon as
# enough member outputs arrive, the remaining stragglers become decodable
# and ARE reconstructed (late), identically in runtime and DES.
CODED_CASES = [
    # r=1 addition code: one straggler decodes in time; two exceed the MDS
    # budget, so the group only decodes the 2nd straggler after the 1st
    # returns on its own
    ("sum", 2, 1, (0,), (), 1, True),
    ("sum", 2, 1, (0, 1), (), 1, False),
    # r=2 Vandermonde (§3.5): TWO concurrent stragglers in ONE group decode
    ("sum", 2, 2, (0, 1), (), 2, True),
    # ... but not when one of the two parity models is itself lost — the
    # group waits out one straggler, then late-decodes the other
    ("sum", 2, 2, (0, 1), (1,), 1, False),
    # one straggler + one lost parity still decodes from the survivor
    ("sum", 2, 2, (0,), (0,), 1, True),
    ("sum", 3, 2, (0, 1), (), 2, True),
    # replication-as-a-scheme: per-row rule — a member is recoverable iff
    # its OWN replica pool delivered
    ("replication", 2, None, (0, 1), (), 2, True),
    ("replication", 2, None, (0, 1), (0,), 1, False),
    ("replication", 2, None, (0, 1), (0, 1), 0, False),
    # learned scheme: fresh from the registry the encoder's residual path is
    # zero-initialised, so the base Vandermonde code is served exactly and
    # the MDS recoverability rule must match sum's — including r=2 decoding
    # two concurrent stragglers in one group
    ("learned", 2, 1, (0,), (), 1, True),
    ("learned", 2, 1, (0, 1), (), 1, False),
    ("learned", 2, 2, (0, 1), (), 2, True),
    # approxifer: the rational-interpolation code with a dynamic-arity
    # decoder — recoverability is a COUNT (arrived responses >= k), not a
    # fixed mask rule, and the "parity model" is the deployed model itself
    # (model_agnostic), so the linear battery model serves it exactly
    ("approxifer", 2, 1, (0,), (), 1, True),
    ("approxifer", 2, 1, (0, 1), (), 1, False),
    # two concurrent stragglers decode from the two extra responses, with
    # zero retraining
    ("approxifer", 2, 2, (0, 1), (), 2, True),
    ("approxifer", 3, 2, (0, 1), (), 2, True),
    # one straggler + one lost extra response: k - 1 members + the
    # surviving extra response still reach arity k
    ("approxifer", 2, 2, (0,), (1,), 1, True),
    # fisher: the linear output code with row-stochastic coefficients —
    # provisioning merges checkpoints instead of training, but the serving
    # contract is plain linear, so the battery's exact-linear model serves
    # every convex parity row exactly
    ("fisher", 2, 1, (0,), (), 1, True),
    ("fisher", 2, 1, (0, 1), (), 1, False),
    ("fisher", 2, 2, (0, 1), (), 2, True),
    # invnet: the code is conducted in the coupling network's latent space;
    # the battery's deployed model factors through g (fwd = g(x) @ W), so
    # the deployed model IS an exact parity model (model_agnostic) and the
    # linear output-code decode is exact
    ("invnet", 2, 1, (0,), (), 1, True),
    ("invnet", 2, 1, (0, 1), (), 1, False),
    ("invnet", 2, 2, (0, 1), (), 2, True),
    # approx_backup-as-a-scheme: k=1 groups mean EVERY query has a cheap
    # replica in flight; with all mains slowed past the backup's service
    # time, both layers answer every query from the backup pool ("parity")
    ("approx_backup", 2, None, (0,), (), 2, True),
    # ... and with the backup pool itself lost, nothing reconstructs — the
    # stragglers show in both layers' tails identically, and the second
    # backup query (still queued when its group finishes on the mains) is
    # tombstoned as redundant work by BOTH layers
    ("approx_backup", 2, None, (0,), (0,), 0, False),
]


@pytest.mark.parametrize("scheme,k,r,slow_main,slow_par,expected,in_time",
                         CODED_CASES,
                         ids=[f"{c[0]}-k{c[1]}-r{c[2]}-m{len(c[3])}-p{len(c[4])}"
                              for c in CODED_CASES])
def test_runtime_and_simulator_agree_on_recoverability(
        scheme, k, r, slow_main, slow_par, expected, in_time):
    scen = _pattern_scenario(k, slow_main, slow_par)
    spec, W = _make_spec(scheme, k, r, scen)
    sim = _run_sim(spec, n=k)
    rt = _run_runtime(spec, W, n=k)
    # identical reconstruction counts and identical recoverability decision
    assert sim["reconstructions"] == expected, sim
    assert rt["reconstructions"] == expected, rt
    assert (sim["reconstructions"] > 0) == (rt["reconstructions"] > 0)
    # identical redundant-work accounting: tombstoned originals and dropped
    # parity queries match across the two engines, case by case
    assert sim["cancelled_queries"] == rt["cancelled_queries"], (sim, rt)
    assert sim["cancelled_parities"] == rt["cancelled_parities"], (sim, rt)
    if in_time:
        # every straggler was decoded before it returned, in both layers
        assert sim["p999_ms"] < MEMBER_MS, sim
        assert any(c == "parity" for c in _completions(rt))
    else:
        # the pattern was not recoverable in time: the straggle shows in the
        # tail of both layers
        assert sim["max_ms"] >= MEMBER_MS, sim
        assert rt["max_ms"] >= MEMBER_MS * 0.9, rt  # wall-clock jitter


def _completions(stats):
    return [k for k, v in stats["completed_by"].items() for _ in range(v)]


# ---------------------------------------------------- cancellation battery --
# (label, strategy, scheme, k, r, m, n, scenario,
#  expected cancelled_queries, expected cancelled_parities, expected recon)
CANCELLATION_CASES = [
    # ONE main server stuck with q0 while q1 waits behind it; both replicas
    # arrive fast and reconstruct both queries, so the queued original q1 is
    # tombstoned at dequeue in both engines (q0 was already in service —
    # in-flight work is never cancelled, only queued work)
    ("queued-original-tombstoned", "parm", "replication", 2, None, 1, 2,
     Scenario("diff-cancel-a",
              (DeterministicSlowdown(targets=(("main", 0),),
                                     add_ms=MEMBER_MS),
               # replica pools idle a beat first, so the main worker has
               # provably dequeued q0 before the decode fulfills it
               DeterministicSlowdown(targets=(("parity0", 0),
                                              ("parity1", 0)),
                                     add_ms=PARITY_BASE_MS))),
     1, 0, 2),
    # the single parity server is stuck serving group 0's parity while
    # group 1's parity waits behind it; the mains (pinned busy for BASE_MS
    # so group 0 is demonstrably unavailable when its parity is dequeued)
    # answer every original, so the undispatched parity query is dropped by
    # both engines — and ONLY that one: group 0's parity was already in
    # service, and in-flight work is never cancelled
    ("undispatched-parity-dropped", "parm", "sum", 2, 1, 2, 4,
     Scenario("diff-cancel-b",
              (DeterministicSlowdown(targets=(("parity0", 0),),
                                     add_ms=PARITY_MS),
               DeterministicSlowdown(targets=(("main", 0), ("main", 1)),
                                     add_ms=BASE_MS))),
     0, 1, 0),
    # mirror replication (non-coded): the second copy of an already-answered
    # query is redundant work — skipped at dequeue by both engines
    ("mirror-copy-tombstoned", "replication", None, 2, None, 1, 1,
     Scenario("diff-cancel-c", ()),
     1, 0, 0),
]


@pytest.mark.parametrize(
    "label,strategy,scheme,k,r,m,n,scen,exp_cq,exp_cp,exp_recon",
    CANCELLATION_CASES, ids=[c[0] for c in CANCELLATION_CASES])
def test_redundant_work_cancellation_matches_across_engines(
        label, strategy, scheme, k, r, m, n, scen, exp_cq, exp_cp,
        exp_recon):
    spec, W = _make_spec(scheme, k, r, scen, m=m, strategy=strategy)
    sim = _run_sim(spec, n=n)
    rt = _run_runtime(spec, W, n=n)
    for rep in (sim, rt):
        assert rep["cancelled_queries"] == exp_cq, (label, rep)
        assert rep["cancelled_parities"] == exp_cp, (label, rep)
        assert rep["reconstructions"] == exp_recon, (label, rep)
    assert sim["completed_by"].keys() == rt["completed_by"].keys()


def test_approxifer_survives_loss_of_all_extra_responses():
    """e = 2 of r = 2 extra responses lost (both parity pools straggle):
    every query is still answered exactly from the uncoded originals, no
    reconstruction happens, and BOTH engines agree — the deployment
    tolerates losing ALL its redundancy with zero retraining."""
    scen = _pattern_scenario(2, (), (0, 1))
    spec, W = _make_spec("approxifer", 2, 2, scen)
    sim = _run_sim(spec, n=2)
    rt = _run_runtime(spec, W, n=2)
    for rep in (sim, rt):
        assert rep["reconstructions"] == 0, rep
        assert rep["completed_by"] == {"model": 2}, rep
    assert sim["p999_ms"] < MEMBER_MS, sim


def test_byzantine_detection_matches_across_engines():
    """Deterministic Byzantine pattern through BOTH engines: main server 1
    is corrupt and slow, so by the time its garbage arrives the group holds
    1 clean member + 2 extra responses — surplus enough to vote it out.
    The affected query was already served from a clean reconstruction, so
    both engines report detected = corrected = 1, and the threads engine's
    answers are all exact (the reconstruction replaced real numerical
    garbage at CORRUPTION_SCALE)."""
    from repro.serving.scenarios import DeterministicCorruption
    # ordering the test depends on, with wide margins so load-skewed
    # thread scheduling cannot reorder it: clean member (50 ms) << extra
    # responses (300 ms) << corrupt member (700 ms)
    scen = Scenario(
        "diff-byzantine",
        (DeterministicCorruption(targets=(("main", 1),), add_ms=MEMBER_MS),
         # keep the clean main busy ~50 ms so each worker deterministically
         # takes one member (the DES free-list assignment)
         DeterministicSlowdown(targets=(("main", 0),), add_ms=50.0),
         DeterministicSlowdown(targets=(("parity0", 0), ("parity1", 0)),
                               add_ms=300.0)))
    spec, W = _make_spec("approxifer", 2, 2, scen)
    sim = _run_sim(spec, n=2)
    rt = _run_runtime(spec, W, n=2)
    for rep in (sim, rt):
        assert rep["corrupted_detected"] == 1, rep
        assert rep["corrected"] == 1, rep
        assert rep["reconstructions"] == 1, rep
        assert rep["completed_by"] == {"model": 1, "parity": 1}, rep


def test_byzantine_late_detection_matches_across_engines():
    """The opposite ordering: the garbage arrives FIRST, while the group
    has no voting surplus, so both engines accept and serve it (silently
    wrong); when the extra responses land, the re-vote catches it — too
    late to correct.  Both engines must agree: detected = 1, corrected =
    0, and no reconstruction (the evicted member's query was already
    answered by its own garbage)."""
    from repro.serving.scenarios import DeterministicCorruption
    scen = Scenario(
        "diff-byzantine-late",
        # both mains busy ~30 ms (so each deterministically takes one
        # member, like the DES free-list); the extra responses arrive
        # 500 ms — far — AFTER the corrupt one, so even under load-skewed
        # scheduling the vote can only fire retroactively
        (DeterministicCorruption(targets=(("main", 1),), add_ms=30.0),
         DeterministicSlowdown(targets=(("main", 0),), add_ms=30.0),
         DeterministicSlowdown(targets=(("parity0", 0), ("parity1", 0)),
                               add_ms=500.0)))
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))
    spec = DeploymentSpec(fwd=_linear_fwd, params=W, parity_params=[W, W],
                          strategy="parm", scheme="approxifer", k=2, r=2,
                          m=2, scenario=scen)
    sim = _run_sim(spec, n=2)
    # threads engine by hand: one of the two answers IS the garbage, so
    # _run_runtime's exactness assertion does not apply here
    sess = deploy(spec, engine="threads")
    try:
        if sess.frontend.strategy.coded:
            sess.frontend.encode_fn(np.zeros((2, 1, 8), np.float32))
        for _ in range(2):
            sess.submit(rng.normal(size=(1, 8)).astype(np.float32))
        assert sess.wait_all(timeout=30)
        # the queries are answered (with the garbage) long before the
        # extra responses land and the re-vote fires: poll, don't sleep
        import time as _time
        deadline = _time.time() + 15.0
        while sess.stats()["corrupted_detected"] == 0 and \
                _time.time() < deadline:
            _time.sleep(0.02)
    finally:
        sess.shutdown()
    rt = sess.stats()
    for rep in (sim, rt):
        assert rep["corrupted_detected"] == 1, rep
        assert rep["corrected"] == 0, rep
        assert rep["reconstructions"] == 0, rep
        assert rep["completed_by"] == {"model": 2}, rep


def test_byzantine_silent_for_non_detecting_schemes():
    """The same corrupt window under ``sum``: no detection machinery runs,
    the reports stay at zero, and latency accounting is unaffected (a
    corrupt response completes like any other)."""
    from repro.serving.scenarios import DeterministicCorruption
    scen = Scenario(
        "diff-byzantine-sum",
        (DeterministicCorruption(targets=(("main", 1),)),))
    spec, W = _make_spec("sum", 2, 1, scen)
    sim = _run_sim(spec, n=2)
    assert sim["corrupted_detected"] == 0 and sim["corrected"] == 0
    assert sim["n"] == 2


def test_batching_policy_flows_through_both_engines():
    """A spec with adaptive batching enabled must serve the same
    deterministic pattern with the same reconstruction/cancellation counts:
    with one member per idle server no batch ever exceeds 1, so batching
    must not perturb the recoverability decision in either engine.
    (``max_delay_ms`` stays 0 — the DES models the size cap only; the
    runtime spaces submissions so each idle worker provably takes one
    member, the assignment the DES's free-list dispatch produces.)"""
    scen = _pattern_scenario(2, (0,), ())
    spec, W = _make_spec("sum", 2, 1, scen)
    spec = spec.replace(batching=BatchingPolicy(max_size=4))
    sim = _run_sim(spec, n=2)
    rt = _run_runtime(spec, W, n=2, gap_s=0.05)
    assert sim["reconstructions"] == rt["reconstructions"] == 1
    assert sim["cancelled_queries"] == rt["cancelled_queries"] == 0
    assert sim["mean_batch_size"] == rt["mean_batch_size"] == 1.0


def test_batched_group_mates_complete_as_model_in_both_engines():
    """Batch-atomic completion: when BOTH members of a coding group are
    served in ONE batched inference call (they queued behind a slowed
    single server while their parity arrived long before), neither engine
    may 'reconstruct' one of them — the exact outputs land together.
    Pattern: m=1, k=2, n=4.  q0 is slowed 600 ms; q1 is decoded from parity
    the moment q0's output arrives (1 reconstruction) and its queued
    original is tombstoned (1 cancellation); q2+q3 — one whole group — are
    then served as a single batch and must BOTH complete as 'model', even
    though their group's parity arrived while they waited."""
    scen = Scenario("diff-batch-mates",
                    (DeterministicSlowdown(targets=(("main", 0),),
                                           add_ms=600.0, t0=0.0, t1=600.0),))
    spec, W = _make_spec("sum", 2, 1, scen, m=1)
    spec = spec.replace(batching=BatchingPolicy(max_size=2))
    sim = _run_sim(spec, n=4)
    # the gap lets the lone worker take q0 alone (as the DES's free server
    # does) before q1..q3 queue up behind its 600 ms straggle
    rt = _run_runtime(spec, W, n=4, gap_s=0.05)
    for rep in (sim, rt):
        assert rep["reconstructions"] == 1, rep
        assert rep["cancelled_queries"] == 1, rep
        assert rep["completed_by"] == {"model": 3, "parity": 1}, rep
    assert sim["mean_batch_size"] > 1.0 and rt["mean_batch_size"] > 1.0


def test_identical_spec_accepted_by_both_engines_for_every_registration():
    """Acceptance: deploy(spec, "threads") and deploy(spec, "sim") take the
    IDENTICAL DeploymentSpec for every registered strategy x scheme.  Image-
    shaped queries keep the shape-specialized concat code servable; the
    deployed model is linear over the flattened image."""
    from repro.core.scheme import available_schemes
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))

    def fwd(p, x):
        return x.reshape(x.shape[0], -1) @ p

    scen = Scenario("diff-sweep", ())
    for strat_name in available_strategies():
        coded = get_strategy(strat_name).coded
        for scheme in (available_schemes() if coded else [None]):
            spec = DeploymentSpec(
                fwd=fwd, params=W, parity_params=None, strategy=strat_name,
                scheme=scheme, k=2, m=2, scenario=scen, slo_ms=500.0,
                default_prediction=np.zeros((1, 3), np.float32))
            sim = deploy(spec, engine="sim").replay(
                Trace(n_queries=50, qps=400.0, seed=0, n_shuffles=0))
            assert sim["strategy"] == strat_name
            assert sim["n"] == 50
            sess = deploy(spec, engine="threads")
            try:
                futs = [sess.submit(
                    rng.normal(size=(1, 4, 4, 1)).astype(np.float32))
                    for _ in range(4)]
                assert sess.wait_all(timeout=30), (strat_name, scheme)
                assert all(f.done() for f in futs)
            finally:
                sess.shutdown()
            rt = sess.stats()
            assert rt["strategy"] == sim["strategy"] == strat_name
            assert rt["scheme"] == sim["scheme"]
            assert rt["scenario"] == sim["scenario"] == "diff-sweep"
            assert rt["engine"] == "threads" and sim["engine"] == "sim"


def test_noncoded_strategies_never_reconstruct():
    """Every registered non-coded strategy must agree across both layers:
    zero reconstructions, all queries answered, under the same slowdown."""
    scen = Scenario("diff-noncoded",
                    (DeterministicSlowdown(targets=(("main", 0),),
                                           add_ms=400.0),))
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    for name in available_strategies():
        strat = get_strategy(name)
        if strat.coded:
            continue
        spec = DeploymentSpec(fwd=_linear_fwd, params=W, strategy=name,
                              k=2, m=2, scenario=scen)
        sim = deploy(spec, engine="sim").replay(
            Trace(n_queries=4, qps=500.0, seed=0, n_shuffles=0))
        assert sim["reconstructions"] == 0, name
        sess = deploy(spec, engine="threads")
        try:
            futs = [sess.submit(np.ones((1, 4), np.float32))
                    for _ in range(4)]
            assert sess.wait_all(timeout=15), name
            st = sess.stats()
            assert st["reconstructions"] == 0, (name, st)
            assert st["n"] == 4, (name, st)
            del futs
        finally:
            sess.shutdown()


def test_simulator_resolves_schemes_through_registry():
    """simulate() must go through get_scheme — unknown names fail fast and
    the resolved scheme's identity is reported."""
    cfg = SimConfig(n_queries=100, qps=200, m=4, k=2, seed=0)
    with pytest.raises(KeyError, match="unknown coding scheme"):
        simulate(cfg, "parm", scheme="nope")
    r = simulate(cfg, "parm", scheme="replication")
    assert r["scheme"] == "replication"
    assert simulate(cfg, "parm")["scheme"] == "sum"   # strategy default
    assert simulate(cfg, "none")["scheme"] is None    # non-coded: no scheme
    # a scheme INSTANCE carries its own r and must pass through even when it
    # differs from cfg.r — the same contract ParMFrontend honors
    from repro.core.scheme import get_scheme
    for inst in (get_scheme("replication", k=2), get_scheme("sum", k=2, r=2)):
        r = simulate(cfg, "parm", scheme=inst)
        assert r["scheme"] == inst.name


def test_controller_adjustment_sequence_matches_across_engines():
    """Closed-loop battery case: ONE ``DeploymentSpec`` with a threshold
    controller through BOTH engines must make the IDENTICAL decision
    sequence — escalate to (approxifer, r=2) after a hot window, drop back
    to the deployment base after a calm one — with matching
    reconstruction / cancellation / parity-work accounting.

    The pattern (all times in scenario ms, ``window_ms=500``):

    * arrivals at 0, 5, 600, 605 (``DeterministicArrivals`` read by the
      DES; the threads side paces its submits to the same schedule);
    * window 0: main0 straggles (+300) inside [0, 500), so q0 is served by
      a parity reconstruction — straggler_rate 0.5 >= 0.45 -> HOT ->
      escalate at the window-0 boundary, BEFORE group 1 assembles;
    * window 1: all mains healthy (+60), group 1 runs under the escalated
      (approxifer, r=2) knobs and completes via its originals —
      straggler_rate 0, tail ratio ~1 -> CALM -> de-escalate to base.

    Expected on BOTH engines: adjustments ((0, 'approxifer', 2, 1),
    (1, 'sum', 1, 1)), 2 windows, 1 reconstruction, 0 cancellations, and
    3 parity inferences served (group 0's one sum parity + group 1's two
    approxifer extras, all dequeued while their groups were incomplete)."""
    import time as _time

    from repro.core.scheme import get_scheme
    from repro.serving.controller import ThresholdController
    from repro.serving.scenarios import DeterministicArrivals

    scen = Scenario(
        "diff-controller",
        (DeterministicArrivals(times_ms=(0.0, 5.0, 600.0, 605.0)),
         # window 0: main0 is the straggler; window 1 onward: healthy
         DeterministicSlowdown(targets=(("main", 0),), add_ms=300.0,
                               t0=0.0, t1=500.0),
         DeterministicSlowdown(targets=(("main", 0),), add_ms=60.0,
                               t0=500.0),
         DeterministicSlowdown(targets=(("main", 1),), add_ms=60.0),
         # parity pools answer in 100 ms — after the healthy mains' 60 ms,
         # before the straggler's 300 ms.  parity0 is the deployment's
         # trained sum pool; parity1/parity2 are the controller's
         # escalation pools (deployed params), where escalated approxifer
         # groups route
         DeterministicSlowdown(targets=(("parity0", 0), ("parity1", 0),
                                        ("parity2", 0)),
                               add_ms=100.0)))
    ctl = ThresholdController(window_ms=500.0, escalate_batch_max=1,
                              down_windows=1)
    spec, W = _make_spec("sum", 2, 1, scen)
    spec = spec.replace(controller=ctl)
    expected_adj = ((0, "approxifer", 2, 1), (1, "sum", 1, 1))

    sim = _run_sim(spec, n=4)

    # threads engine by hand: pace submits to the arrival schedule.  Warm
    # every XLA path first (deployed fwd, both schemes' encodes at the
    # exact serving shapes) so no first-call compile skews the schedule,
    # then rebase the frontend's controller clock AND the fault adapters'
    # wall-clock origin to "now", making scenario-ms == wall-ms from the
    # first submit.
    zq = np.zeros((2, 1, 8), np.float32)
    np.asarray(get_scheme("sum", k=2, r=1).encode(zq))
    np.asarray(get_scheme("approxifer", k=2, r=2).encode(zq))
    np.asarray(_linear_fwd(W, np.zeros((1, 8), np.float32)))
    rng = np.random.default_rng(0)
    sess = deploy(spec, engine="threads")
    try:
        fe = sess.frontend
        fe.encode_fn(zq)
        pool_sizes = {"main": 2, "parity0": 1, "parity1": 1, "parity2": 1}
        delay_fn, _ = fe.scenario.adapters(
            pool_sizes, seed=spec.scenario_seed,
            horizon_ms=spec.scenario_horizon_ms,
            time_scale=spec.scenario_time_scale)
        for w in fe.workers:
            w.delay_fn = delay_fn
        fe._origin = _time.perf_counter()
        t0 = _time.perf_counter()
        for i, at_ms in enumerate((0.0, 5.0, 600.0, 605.0)):
            lag = t0 + at_ms / 1e3 - _time.perf_counter()
            if lag > 0:
                _time.sleep(lag)
            sess.submit(rng.normal(size=(1, 8)).astype(np.float32))
        assert sess.wait_all(timeout=30)
    finally:
        sess.shutdown()
    rt = sess.stats()

    for rep in (sim, rt):
        assert rep["controller"] == "threshold", rep
        assert rep["windows"] == 2, rep
        assert tuple(rep["adjustments"]) == expected_adj, rep
        assert rep["reconstructions"] == 1, rep
        assert rep["cancelled_queries"] == 0, rep
        assert rep["cancelled_parities"] == 0, rep
        assert rep["parity_served"] == 3, rep
        assert rep["completed_by"] == {"model": 3, "parity": 1}, rep
    # the two engines' decision sequences are compared VERBATIM
    assert tuple(sim["adjustments"]) == tuple(rt["adjustments"])


def test_instance_id_round_trips_and_rejects_collisions():
    """The shared (pool, server) <-> instance-id mapping must be a bijection
    over its encodable range and refuse coordinates that would collide."""
    from repro.serving.scenarios import instance_id, pool_of_iid
    for pool, server in [("main", 0), ("main", 999), ("parity0", 0),
                         ("parity1", 99), ("parity9", 5), ("backup", 3)]:
        assert pool_of_iid(instance_id(pool, server)) == (pool, server)
    with pytest.raises(ValueError, match="parity pool"):
        instance_id("parity0", 100)       # would alias parity1 server 0
    with pytest.raises(ValueError, match="parity pools"):
        instance_id("parity10", 0)        # would alias backup server 0
    with pytest.raises(ValueError, match="out of range"):
        instance_id("main", 1000)         # would alias parity0 server 0


def test_every_strategy_scheme_scenario_combination_runs():
    """Smoke the full registered cross-product through the DES (the runtime
    end of each axis is covered by the targeted tests above): every
    (strategy x scheme x scenario) combination must complete all queries."""
    from repro.core.scheme import available_schemes
    from repro.serving.scenarios import available_scenarios
    cfg = SimConfig(n_queries=200, qps=300, m=4, k=4, seed=1)
    for strat_name in available_strategies():
        coded = get_strategy(strat_name).coded
        schemes = available_schemes() if coded else [None]
        for scheme in schemes:
            for scen in available_scenarios():
                r = simulate(cfg, strat_name, scheme=scheme, scenario=scen)
                assert r["strategy"] == strat_name
                assert np.isfinite(r["p999_ms"]), (strat_name, scheme, scen)
