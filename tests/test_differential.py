"""Differential battery: the anti-drift contract of DESIGN.md §1.

Both serving layers — the threaded ``ParMFrontend`` and the DES
``simulate`` — consume the same ``ResilienceStrategy`` / ``CodingScheme`` /
``Scenario`` objects.  These tests drive the SAME unavailability pattern
through both layers for every registered strategy (and for coded strategies,
every relevant scheme including the r=2 Vandermonde code and replication)
and assert they make the same recoverability decision and perform the same
number of reconstructions.

The pattern is expressed once as a ``Scenario`` of ``DeterministicSlowdown``
hazards on (pool, server) coordinates; the DES applies it as service-time
windows and the runtime applies it through the fault-injecting ``delay_fn``
adapter — so the test also proves the adapter maps instance ids onto the
same coordinates the simulator uses.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.runtime import ParMFrontend
from repro.serving.scenarios import DeterministicSlowdown, Scenario
from repro.serving.simulator import SimConfig, simulate
from repro.serving.strategy import available_strategies, get_strategy

# wall-clock / sim-ms straggle budget: members straggle for MEMBER_MS, lost
# parity models for PARITY_MS (long enough that an unrecoverable group
# completes via its members first, so no late decode sneaks in).  Every
# other main server gets BASE_MS: with k queries submitted back-to-back and
# every worker busy for >= BASE_MS, each of the runtime's k main workers
# deterministically serves exactly one group member — the same one-member-
# per-server assignment the DES's free-list dispatch produces.
MEMBER_MS = 700.0
PARITY_MS = 1800.0
BASE_MS = 150.0


def _pattern_scenario(k, slow_main, slow_parity_pools):
    hazards = []
    slow = tuple(("main", s) for s in slow_main)
    base = tuple(("main", s) for s in range(k) if s not in slow_main)
    lost = tuple((f"parity{j}", 0) for j in slow_parity_pools)
    if slow:
        hazards.append(DeterministicSlowdown(targets=slow, add_ms=MEMBER_MS))
    if base:
        hazards.append(DeterministicSlowdown(targets=base, add_ms=BASE_MS))
    if lost:
        hazards.append(DeterministicSlowdown(targets=lost, add_ms=PARITY_MS))
    return Scenario("diff-pattern", tuple(hazards))


def _run_runtime(scheme, k, r, scenario, n=None):
    """One coding group (k queries) through the threaded frontend with
    m = k main instances (one per member) and 1 instance per parity pool."""
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

    def fwd(p, x):
        return x @ p

    # linear deployed model: W itself is an exact parity model for ANY
    # linear combination, so every Vandermonde row is served exactly
    parity_params = None if scheme == "replication" else \
        [W] * (r if r else 1)
    fe = ParMFrontend(fwd, W, parity_params=parity_params, k=k, r=r, m=k,
                      strategy="parm", scheme=scheme, scenario=scenario)
    try:
        xs = [rng.normal(size=(1, 8)).astype(np.float32)
              for _ in range(n or k)]
        qs = [fe.submit(i, x) for i, x in enumerate(xs)]
        assert fe.wait_all(timeout=30)
        for q, x in zip(qs, xs):
            np.testing.assert_allclose(q.result, np.asarray(fwd(W, x)),
                                       atol=1e-2)
        return fe.stats()
    finally:
        fe.shutdown()


def _run_sim(scheme, k, r, scenario, n=None):
    """The same single coding group through the DES: m = k main servers, so
    each member lands on its own server, exactly like the runtime above."""
    cfg = SimConfig(n_queries=n or k, qps=1000.0, m=k, k=k,
                    r=r if r else 1, seed=0, n_shuffles=0)
    return simulate(cfg, "parm", scheme=scheme, scenario=scenario)


# (scheme, k, r, slow main servers, slow parity pools,
#  expected reconstructions, in_time) — ``in_time`` is the recoverability
# *decision*: whether the pattern decodes before the stragglers return.
# When it doesn't, both layers still agree on the late behavior: as soon as
# enough member outputs arrive, the remaining stragglers become decodable
# and ARE reconstructed (late), identically in runtime and DES.
CODED_CASES = [
    # r=1 addition code: one straggler decodes in time; two exceed the MDS
    # budget, so the group only decodes the 2nd straggler after the 1st
    # returns on its own
    ("sum", 2, 1, (0,), (), 1, True),
    ("sum", 2, 1, (0, 1), (), 1, False),
    # r=2 Vandermonde (§3.5): TWO concurrent stragglers in ONE group decode
    ("sum", 2, 2, (0, 1), (), 2, True),
    # ... but not when one of the two parity models is itself lost — the
    # group waits out one straggler, then late-decodes the other
    ("sum", 2, 2, (0, 1), (1,), 1, False),
    # one straggler + one lost parity still decodes from the survivor
    ("sum", 2, 2, (0,), (0,), 1, True),
    ("sum", 3, 2, (0, 1), (), 2, True),
    # replication-as-a-scheme: per-row rule — a member is recoverable iff
    # its OWN replica pool delivered
    ("replication", 2, None, (0, 1), (), 2, True),
    ("replication", 2, None, (0, 1), (0,), 1, False),
    ("replication", 2, None, (0, 1), (0, 1), 0, False),
    # learned scheme: fresh from the registry the encoder's residual path is
    # zero-initialised, so the base Vandermonde code is served exactly and
    # the MDS recoverability rule must match sum's — including r=2 decoding
    # two concurrent stragglers in one group
    ("learned", 2, 1, (0,), (), 1, True),
    ("learned", 2, 1, (0, 1), (), 1, False),
    ("learned", 2, 2, (0, 1), (), 2, True),
    # approx_backup-as-a-scheme: k=1 groups mean EVERY query has a cheap
    # replica in flight; with all mains slowed past the backup's service
    # time, both layers answer every query from the backup pool ("parity")
    ("approx_backup", 2, None, (0,), (), 2, True),
    # ... and with the backup pool itself lost, nothing reconstructs — the
    # stragglers show in both layers' tails identically
    ("approx_backup", 2, None, (0,), (0,), 0, False),
]


@pytest.mark.parametrize("scheme,k,r,slow_main,slow_par,expected,in_time",
                         CODED_CASES,
                         ids=[f"{c[0]}-k{c[1]}-r{c[2]}-m{len(c[3])}-p{len(c[4])}"
                              for c in CODED_CASES])
def test_runtime_and_simulator_agree_on_recoverability(
        scheme, k, r, slow_main, slow_par, expected, in_time):
    scen = _pattern_scenario(k, slow_main, slow_par)
    sim = _run_sim(scheme, k, r, scen)
    rt = _run_runtime(scheme, k, r, scen)
    # identical reconstruction counts and identical recoverability decision
    assert sim["reconstructions"] == expected, sim
    assert rt["reconstructions"] == expected, rt
    assert (sim["reconstructions"] > 0) == (rt["reconstructions"] > 0)
    if in_time:
        # every straggler was decoded before it returned, in both layers
        assert sim["p999_ms"] < MEMBER_MS, sim
        assert any(c == "parity" for c in _completions(rt))
    else:
        # the pattern was not recoverable in time: the straggle shows in the
        # tail of both layers
        assert sim["max_ms"] >= MEMBER_MS, sim
        assert rt["max_ms"] >= MEMBER_MS * 0.9, rt  # wall-clock jitter


def _completions(stats):
    return [k for k, v in stats["completed_by"].items() for _ in range(v)]


def test_noncoded_strategies_never_reconstruct():
    """Every registered non-coded strategy must agree across both layers:
    zero reconstructions, all queries answered, under the same slowdown."""
    scen = Scenario("diff-noncoded",
                    (DeterministicSlowdown(targets=(("main", 0),),
                                           add_ms=400.0),))
    rng = np.random.default_rng(0)
    W = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    for name in available_strategies():
        strat = get_strategy(name)
        if strat.coded:
            continue
        sim = simulate(SimConfig(n_queries=4, qps=500.0, m=2, k=2, seed=0,
                                 n_shuffles=0), name, scenario=scen)
        assert sim["reconstructions"] == 0, name
        fe = ParMFrontend(lambda p, x: x @ p, W, k=2, m=2, strategy=name,
                          scenario=scen)
        try:
            qs = [fe.submit(i, np.ones((1, 4), np.float32))
                  for i in range(4)]
            assert fe.wait_all(timeout=15), name
            st = fe.stats()
            assert st["reconstructions"] == 0, (name, st)
            assert st["n"] == 4, (name, st)
        finally:
            fe.shutdown()


def test_simulator_resolves_schemes_through_registry():
    """simulate() must go through get_scheme — unknown names fail fast and
    the resolved scheme's identity is reported."""
    cfg = SimConfig(n_queries=100, qps=200, m=4, k=2, seed=0)
    with pytest.raises(KeyError, match="unknown coding scheme"):
        simulate(cfg, "parm", scheme="nope")
    r = simulate(cfg, "parm", scheme="replication")
    assert r["scheme"] == "replication"
    assert simulate(cfg, "parm")["scheme"] == "sum"   # strategy default
    assert simulate(cfg, "none")["scheme"] is None    # non-coded: no scheme
    # a scheme INSTANCE carries its own r and must pass through even when it
    # differs from cfg.r — the same contract ParMFrontend honors
    from repro.core.scheme import get_scheme
    for inst in (get_scheme("replication", k=2), get_scheme("sum", k=2, r=2)):
        r = simulate(cfg, "parm", scheme=inst)
        assert r["scheme"] == inst.name


def test_instance_id_round_trips_and_rejects_collisions():
    """The shared (pool, server) <-> instance-id mapping must be a bijection
    over its encodable range and refuse coordinates that would collide."""
    from repro.serving.scenarios import instance_id, pool_of_iid
    for pool, server in [("main", 0), ("main", 999), ("parity0", 0),
                         ("parity1", 99), ("parity9", 5), ("backup", 3)]:
        assert pool_of_iid(instance_id(pool, server)) == (pool, server)
    with pytest.raises(ValueError, match="parity pool"):
        instance_id("parity0", 100)       # would alias parity1 server 0
    with pytest.raises(ValueError, match="parity pools"):
        instance_id("parity10", 0)        # would alias backup server 0
    with pytest.raises(ValueError, match="out of range"):
        instance_id("main", 1000)         # would alias parity0 server 0


def test_every_strategy_scheme_scenario_combination_runs():
    """Smoke the full registered cross-product through the DES (the runtime
    end of each axis is covered by the targeted tests above): every
    (strategy x scheme x scenario) combination must complete all queries."""
    from repro.core.scheme import available_schemes
    from repro.serving.scenarios import available_scenarios
    cfg = SimConfig(n_queries=200, qps=300, m=4, k=4, seed=1)
    for strat_name in available_strategies():
        coded = get_strategy(strat_name).coded
        schemes = available_schemes() if coded else [None]
        for scheme in schemes:
            for scen in available_scenarios():
                r = simulate(cfg, strat_name, scheme=scheme, scenario=scen)
                assert r["strategy"] == strat_name
                assert np.isfinite(r["p999_ms"]), (strat_name, scheme, scen)
