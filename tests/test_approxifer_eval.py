"""Accuracy acceptance for the approxifer scheme on the resnet18_cifar
task family: degraded-mode accuracy vs the paper's sum code, and the
erroneous-response (Byzantine) sweep.  Both train real models — slow lane.
"""
import pytest

from repro.eval.unavailability import (accuracy_under_errors,
                                       accuracy_under_unavailability)


@pytest.mark.slow
def test_approxifer_degraded_accuracy_within_5_points_of_sum():
    """Acceptance: with one unavailable query per coding group, the
    no-training interpolation decode must land within 5 points of the
    trained sum parity model.  (In practice it lands well above it here:
    the 'parity model' IS the deployed model, so reconstruction quality is
    pure interpolation error, not distillation error.)"""
    res = accuracy_under_unavailability(
        schemes=("sum", "approxifer"), n_train=3000, n_test=300, noise=0.8,
        deployed_epochs=4, parity_epochs=6, seed=0)
    assert res["A_a"] > 0.8, res            # deployed model actually learned
    a_sum = res["schemes"]["sum"]
    a_apx = res["schemes"]["approxifer"]
    assert a_sum > 0.3, res                 # parity training was meaningful
    assert a_apx >= a_sum - 0.05, res       # the acceptance bound


@pytest.mark.slow
def test_error_rate_sweep_shows_byzantine_robustness_gap():
    """Sweeping the per-response error rate: at rate 0 every scheme serves
    the same predictions; as the rate grows, approxifer's vote-and-redecode
    keeps accuracy near the clean level (r=2 extra responses correct one
    corruption per group) while sum degrades roughly linearly with the
    rate."""
    res = accuracy_under_errors(
        schemes=("sum", "approxifer"), error_rates=(0.0, 0.1, 0.25),
        n_train=1500, n_test=400, noise=0.8, k=2, r=2,
        deployed_epochs=3, parity_epochs=4, seed=0)
    s, a = res["schemes"]["sum"], res["schemes"]["approxifer"]
    assert s[0.0] == a[0.0]                 # identical clean predictions
    assert a[0.1] >= a[0.0] - 0.03, res     # near-lossless at 10% errors
    assert a[0.25] > s[0.25] + 0.04, res    # the robustness gap
    assert s[0.1] < s[0.0] - 0.03, res      # sum actually degrades
